"""Paper Fig. 11 — throughput vs GPU-memory budget (slot count sweep).

SiDA's data-aware slots vs the data-unaware PrefetchAll streaming under the
same budget, plus OnDemand.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, get_system, profile_batches, warmed
from repro.core.baselines import OnDemandServer, PrefetchAllServer
from repro.core.engine import SiDAEngine


def run() -> List[Row]:
    rows = []
    E = 16
    cfg, params, hp = get_system(E)
    batches = profile_batches(cfg, "sst2", 4, 8)
    for slots in (2, 4, 8, 16):
        for name, ctor in (
            ("sida", lambda: SiDAEngine(cfg, params, hp, slots_per_layer=slots)),
            ("prefetchall", lambda: PrefetchAllServer(cfg, params, slots_per_layer=slots)),
            ("ondemand", lambda: OnDemandServer(cfg, params, slots_per_layer=slots)),
        ):
            eng = warmed(ctor(), batches)
            m = (
                eng.serve(batches, threaded=True)
                if isinstance(eng, SiDAEngine)
                else eng.serve(batches)
            )
            rows.append(Row(
                f"fig11/slots{slots}/{name}",
                m.wall_s * 1e6 / len(batches),
                tput_tok_s=round(m.throughput, 1),
                budget_frac=round(slots / E, 3),
            ))
    return rows
