"""Beyond-paper serving benches: autoregressive decode engine (incremental
hash prediction), int8 host-store H2D reduction, cache-aware scheduling."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, get_system, profile_batches
from repro.core.decode_engine import SiDADecodeEngine
from repro.core.engine import SiDAEngine


def decode_rows() -> List[Row]:
    rows = []
    E = 8
    cfg, params, hp = get_system(E)
    for quant in ("none", "int8"):
        eng = SiDADecodeEngine(
            cfg, params, hp, slots_per_layer=E // 4, serve_top_k=1,
            host_quant=quant,
        )
        start = np.arange(4, dtype=np.int32) + 1
        eng.generate(start, steps=4, cache_len=64)      # warmup/compile
        eng.store.stats.reset()
        out, m = eng.generate(start, steps=32, cache_len=64)
        rows.append(Row(
            f"decode/quant_{quant}", m.wall_s / max(m.steps, 1) * 1e6,
            tok_s=round(m.tok_s, 1),
            loads_first=m.loads_per_step[0],
            loads_last=m.loads_per_step[-1],
            h2d_mb=round(eng.store.stats.bytes_h2d / 1e6, 3),
        ))
    return rows


def spec_rows() -> List[Row]:
    """Paired spec-vs-sync decode probe on E8 at the SAME slot budget.

    Both engines run async prefetch (depth 2) over all-resident-capable
    slots so the comparison isolates speculation: the sync engine pays one
    predict + one step dispatch (and one prefetch fence) per token, the
    speculative engine pays one draft unroll + one verify per k-token block
    and ships ONE superset ticket per block — the headline is tokens/s at
    equal slots, with `identical=1` asserting byte-identical greedy output
    and `accepted` the mean accepted tokens per verify step (> 1 means the
    draft head is paying for itself)."""
    rows = []
    E = 8
    cfg, params, hp = get_system(E, draft=True)
    start = np.arange(4, dtype=np.int32) + 1
    steps = 48

    def run(**kw):
        eng = SiDADecodeEngine(
            cfg, params, hp, slots_per_layer=E, serve_top_k=1,
            prefetch_depth=2, **kw,
        )
        eng.generate(start, steps=4, cache_len=64)      # warmup/compile
        eng.store.stats.reset()
        if eng.prefetcher is not None:
            eng.prefetcher.stats.reset()
        out, m = eng.generate(start, steps=steps, cache_len=64)
        eng.close()
        return out, m

    out_sync, m_sync = run()
    out_spec, m_spec = run(spec_mode="draft", spec_k=4)
    identical = int(bool((out_sync == out_spec).all()))
    rows.append(Row(
        "decode/spec_sync_ref", m_sync.wall_s / max(m_sync.steps, 1) * 1e6,
        tok_s=round(m_sync.tok_s, 1),
        stall_s=round(m_sync.stall_s, 4),
        loads=sum(m_sync.loads_per_step),
    ))
    rows.append(Row(
        "decode/spec_k4", m_spec.wall_s / max(m_spec.steps, 1) * 1e6,
        tok_s=round(m_spec.tok_s, 1),
        accepted=round(m_spec.mean_accepted, 2),
        acceptance=round(m_spec.acceptance_rate, 3),
        identical=identical,
        stall_s=round(m_spec.stall_s, 4),
        loads=sum(m_spec.loads_per_step),
    ))
    return rows


def scheduling_rows() -> List[Row]:
    rows = []
    E = 16
    cfg, params, hp = get_system(E)
    rng = np.random.default_rng(0)
    batches = []
    for i in range(8):  # alternating domains => cache thrash under FIFO
        lo, hi = (0, cfg.vocab_size // 2) if i % 2 == 0 else (cfg.vocab_size // 2, cfg.vocab_size)
        batches.append(rng.integers(lo, hi, (4, 32)).astype(np.int32))
    for lookahead in (1, 4):
        eng = SiDAEngine(cfg, params, hp, slots_per_layer=4)
        eng.serve(batches[:1], threaded=False)          # warmup
        eng.store.stats.reset()
        m = eng.serve(batches, threaded=True, lookahead=lookahead)
        rows.append(Row(
            f"sched/lookahead{lookahead}", m.wall_s / len(batches) * 1e6,
            tput_tok_s=round(m.throughput, 1),
            loads=eng.store.stats.loads,
            hits=eng.store.stats.hits,
        ))
    return rows


def run() -> List[Row]:
    return decode_rows() + spec_rows() + scheduling_rows()
