"""Paper Eq. 2 + Figs 6 & 7 — sparse cross-embedding dependency.

Corruption study on the trained miniature MoE: corrupt a fraction p of the
other tokens / positions and measure how often token i's expert activation
changes; invert Eq. 2 to estimate ĉ (the paper finds ĉ ∈ [1, 4]).
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import CTX, Row, data_for, get_system
from repro.core.sparsity import corruption_study, estimate_c, expected_phat


def run() -> List[Row]:
    rows = []
    cfg, params, hp = get_system(8)
    data = data_for(cfg, seed=7)
    toks, _, _ = data.sample(2)
    ps = [0.1, 0.3, 0.6, 0.9]
    for mode in ("token", "position"):
        t0 = time.perf_counter()
        res = corruption_study(
            params, cfg, toks, ps, n_positions=4, n_trials=2, mode=mode, ctx=CTX
        )
        us = (time.perf_counter() - t0) * 1e6
        L = toks.shape[1]
        c_hat = estimate_c(list(res), [res[p] for p in res], L)
        rows.append(Row(
            f"fig7/{mode}", us,
            **{f"phat_p{p}": round(res[p], 4) for p in ps},
            c_hat=c_hat,
        ))
    # Fig. 6: Eq. 2 curve samples (pure math)
    t0 = time.perf_counter()
    vals = {f"c{c}_p0.3": round(expected_phat(0.3, c, 512), 4) for c in (1, 2, 4, 8)}
    rows.append(Row("fig6/eq2", (time.perf_counter() - t0) * 1e6, **vals))
    return rows
