"""MoE dispatch-strategy ablation (§Perf evidence, beyond paper).

Compares HLO FLOPs and wall time of the three dispatch strategies on the
same MoE layer: the one-hot einsum (exact but costs blk·E·C·d MACs), the
index gather/scatter, and — under a mesh — the shard_map expert-parallel
path. This is the measurement behind choosing "gather" for the 235B
dry-runs (EXPERIMENTS.md §Perf pair 1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.configs.base import get_config
from repro.launch.hlo_analysis import analyse_hlo
from repro.models.attention import ShardingCtx
from repro.models.moe import init_moe, moe_layer

CTX = ShardingCtx()


def run() -> List[Row]:
    rows = []
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, num_experts=16, top_k=2, d_expert=128),
    )
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256, cfg.d_model)).astype(cfg.dtype)

    for strat in ("einsum", "gather"):
        fn = jax.jit(lambda p, x: moe_layer(p, x, cfg, CTX, dispatch=strat)[0])
        lowered = fn.lower(p, x)
        hlo = analyse_hlo(lowered.compile().as_text())
        out = fn(p, x)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn(p, x))
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(Row(
            f"dispatch/{strat}", us,
            hlo_gflops=round(hlo["flops"] / 1e9, 3),
            hlo_gb=round(hlo["bytes"] / 1e9, 3),
        ))
    return rows
