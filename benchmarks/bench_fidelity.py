"""Paper Tables 3 & 4 — perplexity and task-performance preservation when
the hash function replaces the router (SiDA vs the model's own routing)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CTX, Row, data_for, get_system
from repro.core.engine import SiDAEngine
from repro.models.transformer import forward, lm_loss


def _ppl(logits, labels) -> float:
    return float(jnp.exp(lm_loss(logits, jnp.asarray(labels))))


def run() -> List[Row]:
    rows = []
    for E in (8, 16):
        cfg, params, hp = get_system(E)
        data = data_for(cfg, seed=123)  # held-out stream
        toks, labels, _ = data.sample(16)

        t0 = time.perf_counter()
        ref_logits = forward(params, cfg, CTX, jnp.asarray(toks))["logits"]
        ppl_ref = _ppl(ref_logits, labels)

        eng = SiDAEngine(cfg, params, hp, slots_per_layer=E, serve_top_k=1)
        table = eng.build_table(0, toks)
        sida_logits = eng.infer(toks, table)
        ppl_sida = _ppl(jnp.asarray(np.asarray(sida_logits)), labels)
        us = (time.perf_counter() - t0) * 1e6

        agree = float(
            (np.asarray(sida_logits).argmax(-1) == np.asarray(ref_logits).argmax(-1))[
                np.asarray(labels) >= 0
            ].mean()
        )
        rows.append(Row(
            f"table3_4/E{E}", us,
            ppl_router=round(ppl_ref, 3),
            ppl_sida=round(ppl_sida, 3),
            top1_agreement=round(agree, 4),
            fidelity_pct=round(100 * min(ppl_ref / ppl_sida, 1.0), 2),
        ))

        # quality vs memory budget: the flip side of Fig. 11 — under tight
        # slot budgets some predicted experts are dropped; measure the ppl
        # cost of each budget point.
        for slots in (E // 4, E // 2, E):
            eng_b = SiDAEngine(cfg, params, hp, slots_per_layer=slots, serve_top_k=1)
            tb = eng_b.build_table(0, toks)
            lb = eng_b.infer(toks, tb)
            rows.append(Row(
                f"fidelity_budget/E{E}/slots{slots}", 0.0,
                ppl=round(_ppl(jnp.asarray(np.asarray(lb)), labels), 3),
                ppl_router=round(ppl_ref, 3),
                budget_frac=round(slots / E, 3),
            ))
    return rows
