"""Paper Table 5 — hash hit rate (top-1 / top-3) per dataset profile."""
from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp

from benchmarks.common import CTX, Row, get_system, profile_batches
from repro.core.tkd import evaluate_hash_fn
from repro.models.transformer import forward


def run() -> List[Row]:
    rows = []
    for E in (8, 16):
        cfg, params, hp = get_system(E)
        for profile in ("sst2", "mrpc", "multirc"):
            toks = profile_batches(cfg, profile, 1, 16)[0]
            t0 = time.perf_counter()
            out = forward(
                params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True
            )
            emb = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
            m = evaluate_hash_fn(hp, emb, out["router_logits"], top=3)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(Row(
                f"table5/E{E}/{profile}", us,
                top1_hit=round(m["top1_hit"], 4),
                top3_hit=round(m["top3_hit"], 4),
                chance=round(1.0 / E, 4),
            ))
    return rows
