"""Paper Fig. 3 (MoE overhead breakdown) + Fig. 10 (latency per engine).

Fig. 3 decomposes Standard-serving time into router/dispatch ("MoE
overhead") vs expert compute ("ideal"), by timing the model with the MoE
layers replaced by an oracle lookup (the paper's modified implementation).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CTX, Row, get_system, profile_batches, warmed
from repro.core.baselines import OnDemandServer, PrefetchAllServer, StandardServer
from repro.core.engine import SiDAEngine
from repro.core.hash_table import HashTable
from repro.models.moe import router_topk
from repro.models.transformer import forward


def fig3_moe_overhead() -> List[Row]:
    rows = []
    for E in (4, 8, 16):
        cfg, params, hp = get_system(E)
        toks = profile_batches(cfg, "sst2", 1, 8)[0]

        full = jax.jit(lambda p, t: forward(p, cfg, CTX, t)["logits"])
        # "ideal": routing known in advance (lookup table), router not run
        out = forward(params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True)
        rl = out["router_logits"]
        ids, w = router_topk(rl.reshape(-1, E), cfg.moe.top_k)
        L = rl.shape[0]
        ids = jnp.asarray(np.asarray(ids).reshape(L, *toks.shape, -1))
        w = jnp.asarray(np.asarray(w).reshape(L, *toks.shape, -1))
        ideal = jax.jit(
            lambda p, t, i_, w_: forward(
                p, cfg, CTX, t, routing_override=(i_, w_)
            )["logits"]
        )
        # warmup then time
        jax.block_until_ready(full(params, jnp.asarray(toks)))
        jax.block_until_ready(ideal(params, jnp.asarray(toks), ids, w))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(full(params, jnp.asarray(toks)))
        t_full = (time.perf_counter() - t0) / 3
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(ideal(params, jnp.asarray(toks), ids, w))
        t_ideal = (time.perf_counter() - t0) / 3
        rows.append(Row(
            f"fig3/E{E}", t_full * 1e6,
            ideal_us=round(t_ideal * 1e6, 1),
            moe_overhead_pct=round(100 * (1 - t_ideal / t_full), 2),
        ))
    return rows


def fig10_latency() -> List[Row]:
    rows = []
    E = 16
    cfg, params, hp = get_system(E)
    slots = 4
    for profile in ("sst2", "multirc"):
        batches = profile_batches(cfg, profile, 4, 1)  # paper: batch size 1
        engines = {
            "standard": StandardServer(cfg, params),
            "ondemand": OnDemandServer(cfg, params, slots_per_layer=slots),
            "prefetchall": PrefetchAllServer(cfg, params, slots_per_layer=slots),
            "sida": SiDAEngine(cfg, params, hp, slots_per_layer=slots),
        }
        base = None
        for name, eng in engines.items():
            warmed(eng, batches)
            m = (
                eng.serve(batches, threaded=True)
                if isinstance(eng, SiDAEngine)
                else eng.serve(batches)
            )
            lat = m.mean_latency
            if name == "standard":
                base = lat
            rows.append(Row(
                f"fig10/{profile}/{name}", lat * 1e6,
                latency_vs_standard=round(lat / max(base, 1e-9), 3),
            ))
    return rows


def run() -> List[Row]:
    return fig3_moe_overhead() + fig10_latency()
