"""Paper Table 2 + Fig. 2 + Fig. 4 + Fig. 8 — memory accounting & saving.

Table 2 is exact parameter arithmetic on the full-size Switch configs.
Figs 2/4/8 are measured on the trained miniature systems (activation-driven)
across the three sentence-length profiles (sst2 / mrpc / multirc).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import CTX, Row, get_system, profile_batches
from repro.configs.base import get_config
from repro.core.engine import SiDAEngine
from repro.core.sparsity import (
    effective_memory_utilization,
    routing_ids,
    sentence_sparsity,
)


def table2_memory_occupation() -> List[Row]:
    rows = []
    for e in (8, 64, 128, 256):
        cfg = get_config(f"switch-base-{e}")
        t0 = time.perf_counter()
        c = cfg.param_counts()
        us = (time.perf_counter() - t0) * 1e6
        bpp = cfg.bytes_per_param()
        rows.append(Row(
            f"table2/switch-base-{e}", us,
            model_gb=round(c["total"] * bpp / 1e9, 3),
            moe_gb=round(c["moe"] * bpp / 1e9, 3),
            moe_pct=round(100 * c["moe"] / c["total"], 2),
        ))
    return rows


def fig2_fig4_sparsity() -> List[Row]:
    rows = []
    for E in (4, 8, 16):
        cfg, params, hp = get_system(E)
        for profile in ("sst2", "mrpc", "multirc"):
            toks = profile_batches(cfg, profile, 1, 16)[0]
            t0 = time.perf_counter()
            ids = routing_ids(params, cfg, toks, CTX)
            idle = sentence_sparsity(ids, E)
            us = (time.perf_counter() - t0) * 1e6
            util = effective_memory_utilization(cfg, float(idle.mean()))
            lens = (toks != 0).sum(1)
            rows.append(Row(
                f"fig2_4/E{E}/{profile}", us,
                idle_expert_ratio=round(float(idle.mean()), 4),
                effective_util=round(util["effective_utilization"], 4),
                mean_len=round(float(lens.mean()), 1),
            ))
    return rows


def fig8_memory_reduction() -> List[Row]:
    """SiDA device-expert-memory reduction under a data-aware slot budget:
    slots sized to the measured per-batch active-expert count."""
    rows = []
    for E in (8, 16):
        cfg, params, hp = get_system(E)
        for profile in ("sst2", "mrpc", "multirc"):
            batches = profile_batches(cfg, profile, 2, 8)
            # measure active experts per layer to size the slot pool
            ids = routing_ids(params, cfg, batches[0], CTX)
            active = max(
                len(np.unique(ids[l])) for l in range(ids.shape[0])
            )
            eng = SiDAEngine(cfg, params, hp, slots_per_layer=active)
            t0 = time.perf_counter()
            eng.serve(batches, threaded=False)
            us = (time.perf_counter() - t0) * 1e6
            ms = eng.memory_saving()
            rows.append(Row(
                f"fig8/E{E}/{profile}", us,
                reduction=round(ms["reduction"], 4),
                resident_slots=active,
                experts=E,
            ))
    return rows


def quantized_slot_capacity() -> List[Row]:
    """Beyond paper: int8 device-resident slots vs fp slots at equal slot
    bytes. Reports the per-expert slot-byte ratio (≈4× for f32-weight
    miniatures, ≈2× for bf16 deployments) and the measured hit-rate gain
    when the freed bytes buy extra resident experts — the capacity →
    hit-rate leg of the quantized-slots tradeoff (bench_serving measures
    the latency leg)."""
    from benchmarks.common import quant_capacity_info

    rows = []
    for E in (8, 16):
        cfg, params, hp = get_system(E)
        info = quant_capacity_info(cfg, params, slots=2)
        ratio = info["capacity_ratio_at_equal_bytes"]
        q_slots = info["int8_slots_at_equal_bytes"]

        for name, slots, quant in (("fp", 2, False), ("int8", q_slots, True)):
            eng = SiDAEngine(cfg, params, hp, slots_per_layer=slots,
                             quantized_slots=quant)
            batches = profile_batches(cfg, "mrpc", 4, 8)
            t0 = time.perf_counter()
            eng.serve(batches, threaded=False)
            us = (time.perf_counter() - t0) * 1e6
            st = eng.store.stats
            rows.append(Row(
                f"quant_capacity/E{E}/{name}", us,
                slots=slots,
                slot_bytes_per_expert=eng.store.expert_slot_bytes(),
                capacity_ratio=ratio,
                hit_rate=round(st.hits / max(st.hits + st.loads, 1), 4),
            ))
        # sanity, not acceptance: ~3.9x on f32 miniatures, ~1.9-2x for bf16
        # deployments (scale planes cost 4/d_in relative) — never below this
        assert ratio > 1.5, f"int8 slots should far undercut fp slots: {ratio}"
    return rows


def tiered_slot_capacity() -> List[Row]:
    """Beyond paper: int4 warm-tier slots vs int8 at equal slot bytes. The
    nibble-packed format (plus per-group scale planes) fits ≥1.8x the
    resident experts per byte, and the tiered store spends a split byte
    budget as hot int8 + warm int4 slots — rows report the per-format
    bytes, the equal-byte capacity ratio, and the measured hit rate when
    the freed bytes buy warm residency (bench_serving measures latency)."""
    from benchmarks.common import tier_capacity_info
    from repro.configs.base import TierConfig

    rows = []
    for E in (8, 16):
        cfg, params, hp = get_system(E)
        # slots=4: at tier_split=0.5 the warm half of the byte budget
        # converts 2 int8 slots into 3 int4 slots, so the tiered store
        # holds 5 resident experts in (at most) the int8 store's 4-slot
        # bytes — the capacity win the hit-rate delta below measures
        info = tier_capacity_info(cfg, params, slots=4)
        ratio = info["int4_capacity_ratio_at_equal_bytes"]
        q4_slots = info["int4_slots_at_equal_bytes"]

        runs = (
            ("int8", 4, None),
            ("tiered", 4, TierConfig(int4_slots=True, tier_split=0.5)),
        )
        for name, slots, tier in runs:
            eng = SiDAEngine(cfg, params, hp, slots_per_layer=slots,
                             quantized_slots=True, tier=tier)
            batches = profile_batches(cfg, "mrpc", 4, 8)
            t0 = time.perf_counter()
            eng.serve(batches, threaded=False)
            us = (time.perf_counter() - t0) * 1e6
            st = eng.store.stats
            tb = eng.store.tier_slot_bytes() if tier else {}
            rows.append(Row(
                f"tier_capacity/E{E}/{name}", us,
                hot_slots=eng.store.S8,
                warm_slots=eng.store.S4,
                int4_slots_at_equal_bytes=q4_slots,
                capacity_ratio=ratio,
                warm_slot_bytes=tb.get("warm", 0),
                hit_rate=round(st.hits / max(st.hits + st.loads, 1), 4),
                promotions=st.promotions,
                demotions=st.demotions,
            ))
        # acceptance: the int4 format (scale planes included) must fit at
        # least 1.8x the experts of int8 in the same slot bytes
        assert ratio >= 1.8, f"int4 capacity ratio below 1.8x: {ratio}"
    return rows


def kv_residency_budget() -> List[Row]:
    """Beyond paper: capacity accounting with TWO residency classes. The
    unified ResidencyManager holds expert slots AND the paged K/V pool in
    one HBM budget, so "device bytes" rows must include the K/V pages or
    they under-report serving footprint. Rows report each class's
    allocated bytes at serving geometry plus the split_budget arbitration
    (slots vs pages proportional to predicted α mass) at 1x/2x the
    combined floor."""
    from repro.core.offload import ExpertStore
    from repro.core.residency import KVPagePool, PagedKVConfig, ResidencyManager
    from repro.models.transformer import n_moe_layers

    rows = []
    for E in (8, 16):
        cfg, params, hp = get_system(E)
        t0 = time.perf_counter()
        store = ExpertStore(cfg, params, slots_per_layer=2)
        pool = KVPagePool(cfg, PagedKVConfig(page_size=16, kv_pages=32),
                          n_lanes=4)
        mgr = ResidencyManager(store, pool)
        us = (time.perf_counter() - t0) * 1e6
        total = mgr.device_bytes()
        rows.append(Row(
            f"kv_budget/E{E}", us,
            expert_slot_mb=round(store.device_bytes() / 1e6, 3),
            kv_pool_mb=round(pool.capacity_bytes() / 1e6, 3),
            total_mb=round(total / 1e6, 3),
            kv_share=round(pool.capacity_bytes() / total, 3),
        ))
        for mult in (1, 2):
            slots, pages = ResidencyManager.split_budget(
                mult * total, store.expert_slot_bytes(), pool.page_bytes(),
                n_moe_layers(cfg),
            )
            rows.append(Row(
                f"kv_budget/E{E}/split_{mult}x", 0.0,
                budget_mb=round(mult * total / 1e6, 3),
                slots_per_layer=slots,
                kv_pages=pages,
            ))
    return rows


def run() -> List[Row]:
    return (table2_memory_occupation() + fig2_fig4_sparsity()
            + fig8_memory_reduction() + quantized_slot_capacity()
            + tiered_slot_capacity() + kv_residency_budget())
