"""Request-level serving benchmark (beyond paper — the north-star workload):
Poisson arrivals through the continuous-batching RequestServer vs

* ``server_async``     — the full pipeline: async double-buffered expert
                         prefetch (uploads overlap compute; forward blocks
                         only on ready fences);
* ``server_sync``      — same server, inline synchronous uploads (isolates
                         the async-prefetch win);
* ``server_quant``     — async server with int8 device-resident slots at the
                         SAME slot-byte budget as server_async (so ~2–4×
                         the resident experts; isolates the quantized-slots
                         capacity win — see the ``quantized_slots`` block);
* ``server_tiered``    — server_quant plus hierarchical residency tiers:
                         the slot byte budget splits into int8 hot slots
                         and nibble-packed int4 warm slots (~2× experts
                         per byte), with decayed-α-mass promotion /
                         demotion between tiers (see the ``tiered_slots``
                         block for the per-tier byte math);
* ``server_spec``      — async server with speculative decode: the hash
                         predictor's tied-embedding draft head proposes k
                         tokens per step, one jitted verify accepts a
                         per-lane prefix, and ONE superset prefetch ticket
                         covers all k positions (see the ``speculative``
                         block for the closed-loop spec-vs-async probe);
* ``server_ep``        — async server with expert-parallel sharded slot
                         pools over a 4-device (simulated) "model" mesh:
                         per-shard transfer queues + the expert FFN inside
                         shard_map. At full residency sharded greedy decode
                         is byte-identical to single-device serving
                         (tests/test_ep_serving.py); THIS row runs under a
                         tight shard-even budget (``ep_slots``), where the
                         per-shard partitions may drop different experts
                         than server_async's global pool, so its outputs
                         are a residency variant, not a bit-replica. It
                         records the sharding's latency/stall cost on the
                         simulated mesh (emitted when >= 4 devices);
* ``server_ep_repl``   — server_ep plus hot-expert replication
                         (``replicate_hot=1``: α-hot experts keep copies on
                         several shards, tokens round-robin over the
                         least-loaded copy) and periodic load-aware home
                         rebalancing; adds shard_upload_max_over_mean. The
                         paired deterministic probe is the
                         ``shard_load_balance`` block: fixed-home vs
                         replicated max/mean per-shard uploads on a skewed
                         hot-expert trace (runs at any device count);
* ``sequential``       — same machinery, one lane, FCFS (isolates the win
                         from continuous batching + SLA/affinity scheduling);
* ``ondemand_prefill`` — router-inline OnDemand baseline serving each
                         request's prefill FCFS (no look-ahead, so expert
                         loads stall the forward; prefill-only because the
                         baseline has no offloaded decode path);
* ``prefetchall_prefill`` — data-unaware streaming baseline, same protocol.

Emits JSON (stdout + experiments/bench/serving.json) with p50/p95/p99
latency, TTFT, sustained throughput, expert-cache hit rate, and
upload-stall time per engine, plus an ``async_prefetch`` block comparing
sync vs async stall directly, and a ``server_multitenant`` block (two-tenant
WFQ isolation: a light tenant's SLO attainment solo vs under a heavy
tenant's flood — see multitenant_probe).

    PYTHONPATH=src python -m benchmarks.bench_serving [--requests 16 --rate 8]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, get_system
from repro.core.baselines import OnDemandServer, PrefetchAllServer
from repro.serving import (
    RequestServer,
    ServingConfig,
    Telemetry,
    TenantConfig,
    poisson_requests,
)
from repro.serving.request import Request

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def _requests(cfg, n: int, rate: float, seed: int, slo: float) -> List[Request]:
    rng = np.random.default_rng(seed)
    return poisson_requests(
        rng, n, rate_rps=rate, vocab_size=cfg.vocab_size,
        prompt_len_range=(4, 24), max_new_range=(2, 8), slo_s=slo,
    )


def serve_requests(cfg, params, hp, reqs, slots, lanes, eviction="lru",
                   prefetch_depth=0, realtime=True, quantized_slots=False,
                   tier=None, spec_mode="off", spec_k=4, ep_shards=1,
                   replicate_hot=0, rebalance_interval=0.0,
                   faults=None, fence_timeout_s=None, streams=None):
    from repro.launch.serve import ep_setup

    ctx, sharded = ep_setup(ep_shards, replicate_hot)
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=slots,
        max_lanes=lanes, max_prefill_batch=lanes,
        buckets=(8, 16, 32), cache_len=48, eviction=eviction,
        prefetch_depth=prefetch_depth, quantized_slots=quantized_slots,
        tier=tier, spec_mode=spec_mode, spec_k=spec_k, ctx=ctx,
        sharded=sharded, rebalance_interval=rebalance_interval,
        faults=faults, fence_timeout_s=fence_timeout_s,
    )
    # warm every jit shape outside the timed stream, then reset the clocks
    warm_rng = np.random.default_rng(99)
    warm = poisson_requests(
        warm_rng, 2 * lanes, rate_rps=1e6, vocab_size=cfg.vocab_size,
        prompt_len_range=(4, 24), max_new_range=(2, 8),
    )
    srv.run(warm, realtime=False)
    srv.store.stats.reset()
    if srv.prefetch is not None:
        srv.prefetch.stats.reset()
    srv.telemetry = Telemetry()
    srv.run(reqs, realtime=realtime)
    out = srv.summary()
    if streams is not None:
        # rid -> generated tokens, for differential (chaos) comparisons
        streams.update({r.rid: list(r.generated) for r in srv.completed})
    srv.close()
    return out


def stall_probe(cfg, params, hp, n_requests, slots, lanes, seed, trials=3):
    """Paired sync-vs-async upload-stall measurement under saturating
    (closed-loop) load. Realtime Poisson runs measure latency/SLO behavior
    but their wall-clock sleeps make single-run stall timings noisy; the
    probe serves the identical stream back-to-back per mode and takes the
    per-mode minimum over `trials` (the least-interference observation)."""
    probe = {"async_upload_stall_s": [], "sync_upload_stall_s": [],
             "async_overlap_s": []}
    for t in range(trials):
        reqs = _requests(cfg, n_requests, 1e6, seed + t, None)
        sa = serve_requests(cfg, params, hp, reqs, slots, lanes,
                            prefetch_depth=2, realtime=False)
        reqs = _requests(cfg, n_requests, 1e6, seed + t, None)
        sb = serve_requests(cfg, params, hp, reqs, slots, lanes,
                            realtime=False)
        probe["async_upload_stall_s"].append(sa["upload_stall_s"])
        probe["async_overlap_s"].append(sa["upload_overlap_s"])
        probe["sync_upload_stall_s"].append(sb["upload_stall_s"])
    return {k: min(v) for k, v in probe.items()}


def _decode_requests(cfg, n: int, seed: int) -> List[Request]:
    """Decode-bound stream for the speculative probe: speculation trades
    extra verify positions for fewer per-token dispatches, so its regime is
    decode-heavy serving (long generations), not the prefill-dominated
    2-8-token stream the latency rows use."""
    rng = np.random.default_rng(seed)
    return poisson_requests(
        rng, n, rate_rps=1e6, vocab_size=cfg.vocab_size,
        prompt_len_range=(4, 24), max_new_range=(16, 32), slo_s=None,
    )


def spec_probe(cfg, params, hp, n_requests, slots, lanes, seed,
               trials=5, spec_k=2):
    """Paired spec-vs-async probe under saturating (closed-loop) decode-bound
    load at the SAME slot budget: the identical request stream served
    back-to-back by the async server and the speculative server (async +
    draft/verify). All reported numbers come from the single trial pair
    carrying the median decode-throughput ratio (see the aggregation note
    below) — the headline is spec decode tokens/s >= async with lower
    stall (one fence per verify block instead of one per token), plus the
    acceptance telemetry that explains it. spec_k=2 is the sweet spot on
    the E8 miniature's ~0.7-0.9 draft accuracy: rejected verify positions
    are wasted compute, so k beyond the expected accepted run pays for
    dispatch it can't save; deployments with stronger draft heads raise
    it."""
    pairs = []
    for t in range(trials):
        sa = serve_requests(cfg, params, hp,
                            _decode_requests(cfg, n_requests, seed + t),
                            slots, lanes, prefetch_depth=2, realtime=False)
        sk = serve_requests(cfg, params, hp,
                            _decode_requests(cfg, n_requests, seed + t),
                            slots, lanes, prefetch_depth=2, realtime=False,
                            spec_mode="draft", spec_k=spec_k)
        pairs.append((sa, sk))
    # PAIRED aggregation: host load on a shared CPU box swings absolute
    # wall numbers 2-3x between trials, so any per-mode statistic across
    # trials compares different machine conditions; the back-to-back pair
    # inside one trial shares them, so the per-trial ratio is the
    # noise-robust statistic and EVERY reported number comes from the one
    # pair carrying the median ratio (never mixed across trials).
    # decode_tok_s (tokens per second spent inside decode ticks) is the
    # headline: it isolates the hot loop speculation optimizes from
    # admission/prefill/scheduling wall time.
    def ratio(pair):
        return pair[1]["decode_tok_s"] / max(pair[0]["decode_tok_s"], 1e-9)

    sa, sk = sorted(pairs, key=ratio)[len(pairs) // 2]
    return {
        "spec_k": spec_k,
        "spec_decode_speedup": ratio((sa, sk)),
        "async_decode_tok_s": sa["decode_tok_s"],
        "spec_decode_tok_s": sk["decode_tok_s"],
        "async_tok_s": sa["throughput_tok_s"],
        "spec_tok_s": sk["throughput_tok_s"],
        "async_stall_s": sa["upload_stall_s"],
        "spec_stall_s": sk["upload_stall_s"],
        "spec_acceptance_rate": sk["spec_acceptance_rate"],
        "spec_accepted_per_step": sk["spec_accepted_per_step"],
        "trials": [
            {"async_decode_tok_s": p[0]["decode_tok_s"],
             "spec_decode_tok_s": p[1]["decode_tok_s"],
             "async_stall_s": p[0]["upload_stall_s"],
             "spec_stall_s": p[1]["upload_stall_s"]} for p in pairs
        ],
    }


def longctx_probe(cfg, params, hp, slots, lanes, seed):
    """Long-context serving probe: one prompt far beyond the largest
    prefill bucket (128 tokens vs bucket 32) streams through chunked
    prefill + the paged K/V pool while short requests keep arriving.
    The headline is ``short_tokens_during_long_prefill`` — decode tokens
    the short requests emitted BETWEEN the long request's prefill start
    and its first token, i.e. the continuous batch staying live through
    a long prefill instead of draining behind it. Single closed-loop run,
    compile time included — read the latency columns as relative only;
    the kv_* counters report the page pool's traffic."""
    from repro.core.residency import PagedKVConfig

    srv = RequestServer(
        cfg, params, hp, slots_per_layer=slots,
        max_lanes=lanes, max_prefill_batch=lanes, buckets=(8, 16, 32),
        prefetch_depth=2,
        paged=PagedKVConfig(page_size=16, kv_pages=24, prefill_chunk=16),
    )
    rng = np.random.default_rng(seed)
    P = 128
    long_req = Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, (P,)).astype(np.int32),
        max_new_tokens=4,
    )
    shorts = poisson_requests(
        rng, 2 * lanes, rate_rps=1e6, vocab_size=cfg.vocab_size,
        prompt_len_range=(4, 24), max_new_range=(8, 16),
    )
    stamps: List[float] = []
    for r in shorts:
        r.rid += 1
        r.on_token = lambda tok: stamps.append(time.perf_counter())
    long_first: List[float] = []
    long_req.on_token = lambda tok: long_first.append(time.perf_counter())
    srv.run([long_req] + shorts, realtime=False)
    out = srv.summary()
    srv.close()
    assert len(long_req.generated) == long_req.max_new_tokens, (
        "long request did not complete through chunked prefill"
    )
    # request timestamps are server-clock; the callbacks above are raw
    # perf_counter — recover the offset from the long request's first token
    t0_raw = long_first[0] - long_req.t_first_token
    w0 = t0_raw + long_req.t_prefill
    out["long_prompt_len"] = float(P)
    out["long_ttft_s"] = long_req.ttft_s
    out["short_tokens_during_long_prefill"] = float(
        sum(1 for t in stamps if w0 <= t <= long_first[0])
    )
    return out


def shard_balance_probe(cfg, params, steps=24):
    """Per-shard upload balance on a skewed (hot-expert) trace: fixed-home
    placement vs hot-expert replication + online rebalancing.

    Deterministic store+pipeline-level probe (no mesh needed — the shard
    bookkeeping and per-shard transfer queues are logical): two α-hot
    experts share ONE home shard under block placement with a 1-slot-per-
    shard budget, so fixed homes churn that shard every step (evict one
    hot expert to load the other) while the remaining shards idle —
    max/mean per-shard uploads ≈ shard count. With `replicate_hot` the
    copies land in the idle shards' free slots and promotion-on-eviction
    keeps both hot experts resident, so the upload traffic collapses to
    the initial loads spread over the fleet; rebalancing then separates
    the hot experts' homes. The acceptance bar: the replicated max/mean
    is strictly closer to 1.0 than fixed-home."""
    from repro.core.hash_table import HashTable
    from repro.core.offload import (
        ExpertStore, PrefetchPipeline, ShardedStoreConfig,
    )
    from repro.models.transformer import n_moe_layers

    E, L, shards = cfg.moe.num_experts, n_moe_layers(cfg), 4

    def trace(step):
        # hot expert alternates 0/1 (both homed on shard 0 under block
        # placement); expert 2 rides along as steady background traffic
        ids = np.full((L, 1, 8, 1), step % 2, np.int64)
        ids[:, :, -1, :] = 2
        return HashTable(step, ids, np.ones((L, 1, 8, 1), np.float32))

    def run(replicate: int, rebalance_every: int):
        st = ExpertStore(
            cfg, params, slots_per_layer=shards,   # 1 slot per shard
            eviction="lru",
            sharded=ShardedStoreConfig(
                ep_shards=shards, placement="block", replicate_hot=replicate,
            ),
        )
        pf = PrefetchPipeline(st, depth=2)
        for i in range(steps):
            t = pf.submit(trace(i))
            t.wait()
            t.release()
            if rebalance_every and (i + 1) % rebalance_every == 0:
                st.rebalance_homes()
        ups = [float(pf.stats.uploads_by_shard.get(m, 0))
               for m in range(shards)]
        pf.close()
        mean = sum(ups) / shards
        return {
            "uploads_by_shard": ups,
            "max_over_mean": max(ups) / mean if mean > 0 else 1.0,
            "rebalance_moves": float(st.stats.rebalance_moves),
            "replica_loads": float(st.stats.replica_loads),
        }

    out = {
        "steps": float(steps),
        "fixed_home": run(replicate=0, rebalance_every=0),
        "replicated": run(replicate=1, rebalance_every=8),
    }
    out["balance_improved"] = bool(
        abs(out["replicated"]["max_over_mean"] - 1.0)
        < abs(out["fixed_home"]["max_over_mean"] - 1.0)
    )
    return out


def chaos_probe(cfg, params, hp, n_requests, slots, lanes, seed):
    """Differential fault-tolerance probe: the IDENTICAL closed-loop stream
    served by the async server fault-free, then again under seeded p=0.2
    H2D upload faults. The supervision machinery (bounded retry/backoff;
    on exhaustion, fence poisoning + slot rollback and waiter replanning;
    K consecutive failures -> per-shard degraded sync fallback — see
    core/offload.py) must make the faulted run COMPLETE THE FULL STREAM
    with byte-identical token outputs: faults may only cost throughput,
    never correctness. ``outputs_identical`` is the acceptance headline;
    the retry/poison/fallback counters explain what the run survived, and
    ``chaos_throughput_ratio`` prices it (closed-loop paired runs, same
    shared-host noise caveats as stall_probe — read it as relative)."""
    from repro.core.faults import FaultPlan

    plan_text = "upload:fail,p=0.2"

    def one(plan):
        streams: Dict[int, List[int]] = {}
        out = serve_requests(
            cfg, params, hp, _requests(cfg, n_requests, 1e6, seed, None),
            slots, lanes, prefetch_depth=2, realtime=False,
            faults=plan, fence_timeout_s=5.0, streams=streams,
        )
        return out, streams

    base, base_streams = one(None)
    chaos, chaos_streams = one(FaultPlan.parse(plan_text, seed=seed + 1))
    return {
        "fault_plan": plan_text,
        "outputs_identical": bool(base_streams == chaos_streams),
        "completed_fault_free": base["completed"],
        "completed_chaos": chaos["completed"],
        "upload_retries": chaos["upload_retries"],
        "upload_failures": chaos["upload_failures"],
        "poisoned_fences": chaos["poisoned_fences"],
        "sync_fallbacks": chaos["sync_fallbacks"],
        "fence_timeouts": chaos["fence_timeouts"],
        "degraded_shards": chaos["degraded_shards"],
        "fault_free_tok_s": base["throughput_tok_s"],
        "chaos_tok_s": chaos["throughput_tok_s"],
        "chaos_throughput_ratio": (
            chaos["throughput_tok_s"] / max(base["throughput_tok_s"], 1e-9)
        ),
    }


def multitenant_probe(cfg, params, hp, n_requests, slots, lanes, seed,
                      slo=20.0):
    """Two-tenant skewed-load isolation probe (the WFQ acceptance bar):
    a LIGHT tenant's realtime Poisson stream with an SLO, served

      * solo — the attainment ceiling this machine can give it;
      * alongside a HEAVY tenant's 3x closed-loop flood, WFQ engaged
        (equal weights): deficit round robin must keep the light tenant's
        SLO attainment >= 0.9 of the solo run — the heavy tenant's offered
        load buys it nothing beyond its weight share;
      * same combined stream WITHOUT tenant separation (the pre-tenant
        single-queue scheduler): the unprotected contrast, where the
        flood's earlier deadlines starve the light stream at the EDF gate.

    ``attainment_ratio`` (wfq / solo) is the headline; >= 0.9 is the bar."""

    def light_stream():
        rng = np.random.default_rng(seed)
        return poisson_requests(
            rng, n_requests, rate_rps=4.0, vocab_size=cfg.vocab_size,
            prompt_len_range=(4, 24), max_new_range=(2, 8), slo_s=slo,
            tenant="light",
        )

    def heavy_stream():
        rng = np.random.default_rng(seed + 1)
        return poisson_requests(
            rng, 3 * n_requests, rate_rps=1e6, vocab_size=cfg.vocab_size,
            prompt_len_range=(4, 24), max_new_range=(2, 8), slo_s=slo,
            tenant="heavy", rid_base=10_000,
        )

    def run(reqs, tenants):
        config = ServingConfig.from_kwargs(
            slots_per_layer=slots, max_lanes=lanes, max_prefill_batch=lanes,
            buckets=(8, 16, 32), cache_len=48, eviction="lru",
            tenants=tenants,
        )
        srv = RequestServer(cfg, params, hp, config)
        warm = poisson_requests(
            np.random.default_rng(99), 2 * lanes, rate_rps=1e6,
            vocab_size=cfg.vocab_size, prompt_len_range=(4, 24),
            max_new_range=(2, 8),
        )
        srv.run(warm, realtime=False)
        srv.store.stats.reset()
        srv.telemetry = Telemetry()
        srv.run(reqs, realtime=True)
        arrived = sum(1 for r in reqs if r.tenant == "light")
        ok = sum(
            1 for r in srv.completed
            if r.tenant == "light" and r.latency_s <= (r.slo_s or np.inf)
        )
        light_done = sum(1 for r in srv.completed if r.tenant == "light")
        summary = srv.tenant_summary()
        srv.close()
        return ok / max(arrived, 1), light_done, summary

    light = TenantConfig("light", weight=1.0)
    heavy = TenantConfig("heavy", weight=1.0)
    solo_att, _, _ = run(light_stream(), (light,))
    combined = sorted(
        light_stream() + heavy_stream(), key=lambda r: r.arrival_s
    )
    wfq_att, wfq_done, summary = run(combined, (light, heavy))
    combined = sorted(
        light_stream() + heavy_stream(), key=lambda r: r.arrival_s
    )
    flat_att, flat_done, _ = run(combined, ())
    return {
        "light_requests": n_requests,
        "heavy_requests": 3 * n_requests,
        "slo_s": slo,
        "light_solo_attainment": solo_att,
        "light_wfq_attainment": wfq_att,
        "light_unprotected_attainment": flat_att,
        "attainment_ratio": wfq_att / max(solo_att, 1e-9),
        "light_completed_wfq": wfq_done,
        "light_completed_unprotected": flat_done,
        "heavy_completed_wfq": summary["heavy"]["completed"],
        "light_p95_latency_s": summary["light"]["p95_latency_s"],
        "heavy_p95_latency_s": summary["heavy"]["p95_latency_s"],
        "light_pinned_share": summary["light"]["pinned_share"],
    }


def serve_prefill_fcfs(baseline_cls, cfg, params, reqs, slots) -> Dict[str, float]:
    """FCFS request-at-a-time prefill through a router-inline baseline."""
    from repro.serving.telemetry import Histogram

    srv = baseline_cls(cfg, params, slots_per_layer=slots)
    srv._forward_batch(reqs[0].prompt[None])  # warm compile
    srv.store.stats.reset()
    lat = Histogram()
    tokens = 0
    t0 = time.perf_counter()
    for r in sorted(reqs, key=lambda r: r.arrival_s):
        wait = r.arrival_s - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        logits = srv._forward_batch(r.prompt[None])
        _ = int(np.argmax(np.asarray(logits)[0, -1]))  # first token (TTFT)
        lat.observe(time.perf_counter() - t0 - r.arrival_s)
        tokens += r.prompt_len
    wall = time.perf_counter() - t0
    st = srv.store.stats
    refs = st.hits + st.loads
    return {
        "prefill_only": 1.0,
        "completed": float(len(reqs)),
        "throughput_tok_s": tokens / wall if wall else 0.0,
        "p50_latency_s": lat.percentile(50),
        "p95_latency_s": lat.percentile(95),
        "p99_latency_s": lat.percentile(99),
        "p50_ttft_s": lat.percentile(50),   # TTFT == prefill completion here
        "p95_ttft_s": lat.percentile(95),
        "cache_hit_rate": st.hits / refs if refs else 0.0,
        "h2d_mb": st.bytes_h2d / 1e6,
    }


def bench(E=8, n_requests=12, rate=6.0, slots=2, lanes=4, slo=20.0, seed=0):
    cfg, params, hp = get_system(E, draft=True)  # server_spec + spec_probe
    result = {
        "config": {
            "arch": cfg.name, "experts": E, "slots": slots, "lanes": lanes,
            "requests": n_requests, "rate_rps": rate, "slo_s": slo,
        },
        "engines": {},
    }
    result["engines"]["server_async"] = serve_requests(
        cfg, params, hp, _requests(cfg, n_requests, rate, seed, slo),
        slots, lanes, prefetch_depth=2,
    )
    result["engines"]["server_sync"] = serve_requests(
        cfg, params, hp, _requests(cfg, n_requests, rate, seed, slo),
        slots, lanes,
    )
    # speculative decode over the async pipeline at the SAME slot budget:
    # k-token draft/verify blocks + one superset prefetch ticket per block
    # (k=2: see spec_probe on matching k to draft accuracy)
    result["engines"]["server_spec"] = serve_requests(
        cfg, params, hp, _requests(cfg, n_requests, rate, seed, slo),
        slots, lanes, prefetch_depth=2, spec_mode="draft", spec_k=2,
    )
    # int8 device-resident slots: spend the SAME slot-byte budget the fp
    # server gets, which buys ~4x the resident experts (f32 miniatures) —
    # the capacity -> hit-rate -> latency leg of the quantized-slots story
    from benchmarks.common import quant_capacity_info

    result["quantized_slots"] = quant_capacity_info(cfg, params, slots)
    q_slots = result["quantized_slots"]["int8_slots_at_equal_bytes"]
    result["engines"]["server_quant"] = serve_requests(
        cfg, params, hp, _requests(cfg, n_requests, rate, seed, slo),
        q_slots, lanes, prefetch_depth=2, quantized_slots=True,
    )
    # hierarchical residency tiers at the SAME slot-byte budget as
    # server_quant: the store keeps `tier_split` of the budget as int8 hot
    # slots and converts the rest into int4 warm slots (~2x experts per
    # byte, scale planes included), promoting by decayed α-mass — the
    # capacity -> hit-rate leg of the warm tier (bench_memory holds the
    # byte accounting; the acceptance bar is hit rate >= server_quant's)
    from benchmarks.common import tier_capacity_info
    from repro.configs.base import TierConfig

    result["tiered_slots"] = tier_capacity_info(cfg, params, q_slots)
    result["engines"]["server_tiered"] = serve_requests(
        cfg, params, hp, _requests(cfg, n_requests, rate, seed, slo),
        q_slots, lanes, prefetch_depth=2, quantized_slots=True,
        tier=TierConfig(int4_slots=True, tier_split=0.5),
    )
    # expert-parallel sharded serving on 4 (simulated) devices: the slot
    # pools partition over a 1-D "model" mesh, the expert FFN runs inside
    # shard_map, and the async pipeline fans uploads into per-shard
    # transfer queues. The byte-identity guarantee (tests/test_ep_serving)
    # holds at full residency; under this row's tight shard-even budget
    # the per-shard partitions can drop different experts than the global
    # pool, so treat the row as a residency variant measuring the
    # sharding's latency/stall cost, not a bit-replica of server_async.
    # Emitted only when the host exposes >= 4 devices (CI forces them with
    # XLA_FLAGS=--xla_force_host_platform_device_count=4).
    import jax as _jax

    if _jax.device_count() >= 4:
        # the slot budget rounds UP to a shard-even split (>= 1 slot per
        # shard); ep_slots is recorded so the row stays comparable
        ep_slots = -(-slots // 4) * 4
        result["engines"]["server_ep"] = serve_requests(
            cfg, params, hp, _requests(cfg, n_requests, rate, seed, slo),
            ep_slots, lanes, prefetch_depth=2, ep_shards=4,
        )
        result["engines"]["server_ep"]["ep_shards"] = 4.0
        result["engines"]["server_ep"]["ep_slots"] = float(ep_slots)
        # same sharded server with hot-expert replication + periodic
        # load-aware home rebalancing: α-hot experts keep copies on
        # several shards (dispatch round-robins tokens over the
        # least-loaded copies), and home placement re-derives from the
        # decayed α-mass every rebalance interval. The summary() row adds
        # shard_upload_max_over_mean — the per-shard transfer-queue load
        # skew this machinery exists to flatten (1.0 == perfectly even).
        result["engines"]["server_ep_repl"] = serve_requests(
            cfg, params, hp, _requests(cfg, n_requests, rate, seed, slo),
            ep_slots, lanes, prefetch_depth=2, ep_shards=4,
            replicate_hot=1, rebalance_interval=0.05,
        )
        result["engines"]["server_ep_repl"]["ep_shards"] = 4.0
        result["engines"]["server_ep_repl"]["ep_slots"] = float(ep_slots)
    else:
        result["ep_skipped"] = (
            f"server_ep needs >= 4 devices, have {_jax.device_count()}"
        )
    # same eviction policy as the server so the delta isolates continuous
    # batching + scheduling, not cache replacement
    # long-context serving: chunked prefill + paged K/V residency. The row
    # must show short-request decode progress DURING the long prefill
    # (short_tokens_during_long_prefill) — the criterion the paged path
    # exists to satisfy.
    result["engines"]["server_longctx"] = longctx_probe(
        cfg, params, hp, slots, lanes, seed
    )
    result["engines"]["sequential"] = serve_requests(
        cfg, params, hp, _requests(cfg, n_requests, rate, seed, slo),
        slots, lanes=1,
    )
    result["engines"]["ondemand_prefill"] = serve_prefill_fcfs(
        OnDemandServer, cfg, params, _requests(cfg, n_requests, rate, seed, slo),
        slots,
    )
    result["engines"]["prefetchall_prefill"] = serve_prefill_fcfs(
        PrefetchAllServer, cfg, params,
        _requests(cfg, n_requests, rate, seed, slo), slots,
    )
    # the headline async-prefetch delta: upload time that stalled the
    # forward path, sync (inline uploads) vs async (ready-fence waits only),
    # measured as a paired closed-loop probe (noise-robust)
    result["async_prefetch"] = stall_probe(
        cfg, params, hp, n_requests, slots, lanes, seed
    )
    # the headline speculative delta: closed-loop spec-vs-async tokens/s and
    # per-block-vs-per-token fence stall at equal slots, with acceptance
    result["speculative"] = spec_probe(
        cfg, params, hp, n_requests, slots, lanes, seed
    )
    # the headline replication delta: deterministic skewed-trace per-shard
    # upload balance, fixed-home vs replicated + rebalanced (store +
    # pipeline level, so it runs regardless of device count)
    result["shard_load_balance"] = shard_balance_probe(cfg, params)
    # the headline fault-tolerance delta: same stream fault-free vs under
    # seeded p=0.2 upload faults — byte-identical outputs, priced in
    # throughput (retry/poison/degrade machinery, see core/faults.py)
    result["server_chaos"] = chaos_probe(
        cfg, params, hp, n_requests, slots, lanes, seed
    )
    # the headline multi-tenant delta: a light tenant's SLO attainment
    # solo vs under a heavy tenant's 3x flood, WFQ vs the unprotected
    # single-queue path (attainment_ratio >= 0.9 is the acceptance bar)
    result["server_multitenant"] = multitenant_probe(
        cfg, params, hp, n_requests, slots, lanes, seed, slo=slo
    )
    return result


def run() -> List[Row]:
    """benchmarks.run entry point."""
    result = bench()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "serving.json"), "w") as f:
        json.dump(result, f, indent=2)
    rows = []
    for name, m in result["engines"].items():
        rows.append(Row(
            f"serving/{name}",
            m["p50_latency_s"] * 1e6,
            tput_tok_s=round(m["throughput_tok_s"], 1),
            p95_s=round(m["p95_latency_s"], 4),
            ttft_p50_s=round(m["p50_ttft_s"], 4),
            hit_rate=round(m["cache_hit_rate"], 3),
            stall_s=round(m.get("upload_stall_s", 0.0), 4),
        ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experts", type=int, default=8)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=6.0)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--slo", type=float, default=20.0)
    args = ap.parse_args()
    result = bench(args.experts, args.requests, args.rate, args.slots,
                   args.lanes, args.slo)
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "serving.json"), "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))


if __name__ == "__main__":
    main()
