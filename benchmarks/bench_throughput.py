"""Paper Fig. 9 — throughput: SiDA vs Standard / OnDemand / PrefetchAll
across sentence-length profiles and expert counts."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, get_system, profile_batches, warmed
from repro.core.baselines import OnDemandServer, PrefetchAllServer, StandardServer
from repro.core.engine import SiDAEngine


def run() -> List[Row]:
    rows = []
    for E in (8, 16):
        cfg, params, hp = get_system(E)
        slots = max(2, E // 4)
        for profile in ("sst2", "mrpc", "multirc"):
            batches = profile_batches(cfg, profile, 4, 8)
            engines = {
                "standard": StandardServer(cfg, params),
                "ondemand": OnDemandServer(cfg, params, slots_per_layer=slots),
                "prefetchall": PrefetchAllServer(cfg, params, slots_per_layer=slots),
                "sida": SiDAEngine(cfg, params, hp, slots_per_layer=slots),
            }
            tputs = {}
            for name, eng in engines.items():
                warmed(eng, batches)
                m = (
                    eng.serve(batches, threaded=True)
                    if isinstance(eng, SiDAEngine)
                    else eng.serve(batches)
                )
                tputs[name] = m.throughput
                rows.append(Row(
                    f"fig9/E{E}/{profile}/{name}",
                    m.wall_s * 1e6 / len(batches),
                    tput_tok_s=round(m.throughput, 1),
                    vs_standard=round(m.throughput / max(tputs["standard"], 1e-9), 3),
                    slots=slots,
                ))
            # the paper's headline metric: SiDA vs the average of baselines
            # (here: the memory-constrained serving alternatives)
            off_avg = (tputs["ondemand"] + tputs["prefetchall"]) / 2
            rows.append(Row(
                f"fig9/E{E}/{profile}/sida_vs_offload_avg", 0.0,
                speedup=round(tputs["sida"] / max(off_avg, 1e-9), 3),
            ))
    return rows
