"""Shared benchmark infrastructure.

`get_system(E)` returns a *trained* miniature Switch-family system (model +
hash function + data stream) with E experts per MoE layer — the scaled-down
analogue of switch-base-{8,64,128,256} that the paper's figures sweep.
Training is cached under experiments/cache so the full benchmark suite can
re-run cheaply.
"""
from __future__ import annotations

import dataclasses
import os
import time
from functools import lru_cache
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.configs.base import get_config
from repro.core.hash_fn import init_hash_fn
from repro.core.tkd import train_hash_fn
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, init_params, n_moe_layers
from repro.optim.adamw import adamw_init

CTX = ShardingCtx()
CACHE = os.path.join(os.path.dirname(__file__), "..", "experiments", "cache")
SEQ = 48
VOCAB = 512


def bench_cfg(E: int):
    """Miniature Switch with E experts (analogue of switch-base-E·16).

    d_expert is kept large relative to the backbone so the expert FFNs
    dominate compute/memory exactly as in the real Switch models (Table 2:
    89–99% of parameters are experts) — the regime where the paper's
    effects exist.
    """
    cfg = get_config("switch-base-8").reduced()
    return dataclasses.replace(
        cfg,
        n_layers=4,
        d_ff=128,
        moe=dataclasses.replace(
            cfg.moe, num_experts=E, top_k=1, capacity_factor=4.0,
            d_expert=512,
        ),
    )


def data_for(cfg, profile=None, seed=0) -> SyntheticLM:
    return SyntheticLM(
        SyntheticConfig(
            vocab_size=cfg.vocab_size, seq_len=SEQ, n_domains=max(4, min(16, cfg.moe.num_experts)),
            profile=profile,
        ),
        seed=seed,
    )


def _with_draft_head(cfg, params, hp, ck: str, draft_steps: int):
    """Attach + distill the tied-embedding draft head (speculative decode)
    onto a trained predictor. Cached separately from the hash checkpoint so
    pre-draft caches stay valid and the router heads stay bit-identical —
    only `draft_proj` trains (see tkd.train_draft_head)."""
    from repro.core.hash_fn import init_draft_head
    from repro.core.tkd import train_draft_head

    hp = init_draft_head(jax.random.PRNGKey(7), hp, cfg.d_model)
    dck = os.path.join(ck, "draft")
    if os.path.exists(os.path.join(dck, "manifest.json")):
        dp, _ = load_checkpoint(dck, like={"draft_proj": hp["draft_proj"]})
        return {**hp, **dp}

    data = data_for(cfg, seed=1)

    def batches():
        while True:
            toks, _, _ = data.sample(8)
            out = forward(params, cfg, CTX, jnp.asarray(toks))
            emb = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
            yield emb, out["logits"]

    hp, _ = train_draft_head(
        hp, params["embed"], batches(), steps=draft_steps,
        num_experts=cfg.moe.num_experts, lr=3e-3,
    )
    save_checkpoint(dck, {"draft_proj": hp["draft_proj"]})
    return hp


@lru_cache(maxsize=None)
def get_system(E: int, train_steps: int = 80, hash_steps: int = 150,
               draft: bool = False, draft_steps: int = 300):
    """draft=True additionally attaches + distills the speculative-decode
    draft head (cached; only the spec suites pay for it — every other
    consumer gets the plain predictor)."""
    cfg = bench_cfg(E)
    ck = os.path.join(CACHE, f"sys_E{E}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg), E, d_h=32
    )
    if os.path.exists(os.path.join(ck, "model", "manifest.json")):
        params, _ = load_checkpoint(os.path.join(ck, "model"), like=params)
        hp, _ = load_checkpoint(os.path.join(ck, "hash"), like=hp)
        if draft:
            hp = _with_draft_head(cfg, params, hp, ck, draft_steps)
        return cfg, params, hp

    data = data_for(cfg)
    step = jax.jit(make_train_step(cfg, CTX, lr=2e-3))
    opt = adamw_init(params)
    for toks, labels in data.batches(8, train_steps):
        params, opt, _ = step(params, opt, jnp.asarray(toks), jnp.asarray(labels))

    def batches():
        while True:
            toks, _, _ = data.sample(8)
            out = forward(
                params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True
            )
            emb = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
            yield emb, out["router_logits"]

    hp, _ = train_hash_fn(
        hp, batches(), steps=hash_steps, lr=3e-3, T=min(30, E), verbose=False
    )
    save_checkpoint(os.path.join(ck, "model"), params)
    save_checkpoint(os.path.join(ck, "hash"), hp)
    if draft:
        hp = _with_draft_head(cfg, params, hp, ck, draft_steps)
    return cfg, params, hp


def profile_batches(cfg, profile: str, n: int, batch: int, seed=0):
    data = data_for(cfg, profile=profile, seed=seed)
    return [data.sample(batch)[0] for _ in range(n)]


def quant_capacity_info(cfg, params, slots: int) -> Dict[str, float]:
    """fp vs int8-resident slot cost and the int8 slot count the SAME byte
    budget buys — the single source of the capacity-at-equal-bytes math
    shared by bench_memory and bench_serving (so their JSON/CSV rows can
    never disagree on what "equal bytes" means)."""
    from repro.core.offload import ExpertStore

    st_fp = ExpertStore(cfg, params, slots_per_layer=slots)
    st_q = ExpertStore(cfg, params, slots_per_layer=slots, quantized_slots=True)
    fp_b, q_b = st_fp.expert_slot_bytes(), st_q.expert_slot_bytes()
    return {
        "fp_slot_bytes_per_expert": fp_b,
        "int8_slot_bytes_per_expert": q_b,
        "capacity_ratio_at_equal_bytes": round(fp_b / q_b, 3),
        "fp_slots": slots,
        "int8_slots_at_equal_bytes": min(
            int(slots * fp_b // q_b), cfg.moe.num_experts
        ),
    }


def tier_capacity_info(cfg, params, slots: int, group: int = 64) -> Dict[str, float]:
    """Per-tier slot cost and the tiered capacity the SAME byte budget buys
    (hot int8 / warm int4 with per-group scales) — the tiered analogue of
    `quant_capacity_info`, shared by bench_memory and bench_serving."""
    from repro.configs.base import TierConfig
    from repro.core.offload import ExpertStore

    st = ExpertStore(
        cfg, params, slots_per_layer=slots, quantized_slots=True,
        tier=TierConfig(int4_slots=True, tier_split=0.5, group_size=group),
    )
    tb = st.tier_slot_bytes()
    b8, b4 = tb["hot"], tb["warm"]
    E = cfg.moe.num_experts
    return {
        "int8_slot_bytes_per_expert": b8,
        "int4_slot_bytes_per_expert": b4,
        "int4_capacity_ratio_at_equal_bytes": round(b8 / b4, 3),
        "int4_slots_at_equal_bytes": min(int(slots * b8 // b4), E),
        "hot_slots": st.S8,
        "warm_slots": st.S4,
        "tiered_slots_at_equal_bytes": min(st.S8 + st.S4, E),
        "quant_group": group,
    }


def warmed(engine, batches):
    """Compile/warm an engine outside the timed region, reset its stats."""
    from repro.core.engine import SiDAEngine

    if isinstance(engine, SiDAEngine):
        engine.serve(batches[:1], threaded=False)
        engine.store.stats.reset()
    else:
        engine.serve(batches[:1])
    return engine


class Row:
    """One CSV row: name,us_per_call,derived."""

    def __init__(self, name: str, us: float, **derived):
        self.name = name
        self.us = us
        self.derived = derived

    def csv(self) -> str:
        d = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us:.1f},{d}"


def timed(fn, *args, repeats=1):
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") or isinstance(
        out, jax.Array
    ) else None
    return out, (time.perf_counter() - t0) / repeats * 1e6
