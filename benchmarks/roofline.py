"""Roofline analysis (deliverable g) — reads the dry-run artifacts.

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs_per_dev / peak_FLOP/s
    memory term     = HLO_bytes_per_dev / HBM_bw
    collective term = collective_bytes_per_dev / link_bw
dominant bottleneck = argmax of the three; plus MODEL_FLOPS = 6·N·D (train)
or 2·N_active·D (inference) and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs × n_devices).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (DESIGN.md).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs.base import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs for the whole step (all devices)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    counts = cfg.param_counts()
    n_active = counts["active"] - counts["embed"]  # matmul-participating
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def analyse_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n_dev = rec["n_devices"]
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed"] / HBM_BW
    t_coll = rec["collective_total_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["flops"] * n_dev
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "step_lower_bound_s": max(terms.values()),
        "mem_per_dev_gb": (
            rec["argument_size_in_bytes"] + rec["temp_size_in_bytes"]
            + rec["output_size_in_bytes"] - rec["alias_size_in_bytes"]
        ) / 1e9,
    }


def load_all(mesh: str = "pod") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(f) as fh:
            rec = json.load(fh)
        r = analyse_record(rec)
        if r:
            out.append(r)
    return out


def markdown_table(rows: List[Dict]) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful ratio | mem/dev GB |\n|---|---|---|---|---|---|---|---|\n"
    )
    body = "".join(
        f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
        f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
        f"**{r['dominant']}** | {r['useful_ratio']:.3f} | "
        f"{r['mem_per_dev_gb']:.2f} |\n"
        for r in rows
    )
    return hdr + body


def run():
    from benchmarks.common import Row

    rows = []
    for mesh in ("pod", "multipod"):
        for r in load_all(mesh):
            rows.append(Row(
                f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                r["step_lower_bound_s"] * 1e6,
                dominant=r["dominant"],
                compute_s=f"{r['t_compute_s']:.3e}",
                memory_s=f"{r['t_memory_s']:.3e}",
                collective_s=f"{r['t_collective_s']:.3e}",
                useful_ratio=round(r["useful_ratio"], 4),
            ))
    return rows


if __name__ == "__main__":
    for mesh in ("pod", "multipod"):
        rows = load_all(mesh)
        if rows:
            print(f"\n## mesh = {mesh}\n")
            print(markdown_table(rows))
