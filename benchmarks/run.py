"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,table5]

Prints ``name,us_per_call,derived`` CSV and writes the same rows to
experiments/bench/results.json. Paper-artifact index in DESIGN.md §6.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

SUITES = [
    ("memory", "benchmarks.bench_memory"),          # Table 2, Figs 2/4/8
    ("latency", "benchmarks.bench_latency"),        # Figs 3, 10
    ("throughput", "benchmarks.bench_throughput"),  # Fig 9
    ("budget", "benchmarks.bench_budget"),          # Fig 11
    ("hash_hits", "benchmarks.bench_hash_hits"),    # Table 5
    ("fidelity", "benchmarks.bench_fidelity"),      # Tables 3/4
    ("dependency", "benchmarks.bench_dependency"),  # Eq. 2, Figs 6/7
    ("dispatch", "benchmarks.bench_dispatch"),      # beyond-paper ablation
    ("decode", "benchmarks.bench_decode"),          # beyond-paper serving
    ("serving", "benchmarks.bench_serving"),        # request-level serving
    ("roofline", "benchmarks.roofline"),            # deliverable (g)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib

    all_rows = []
    print("name,us_per_call,derived")
    for name, module in SUITES:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        mod = importlib.import_module(module)
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{name}/ERROR,0.0,error={type(e).__name__}:{e}")
            continue
        for r in rows:
            print(r.csv())
            all_rows.append({"name": r.name, "us": r.us, **r.derived})
        dt = time.perf_counter() - t0
        print(f"# suite {name} done in {dt:.1f}s", file=sys.stderr)

    out_dir = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "results.json"), "w") as f:
        json.dump(all_rows, f, indent=2, default=str)


if __name__ == "__main__":
    main()
