"""Hash-function ablation: truncation T, sparse attention, λ (paper §3.4/3.5).

Trains the SiDA predictor under different objectives on the same frozen MoE
and reports top-1/top-3 hit rates — reproducing the design rationale for
truncated KD + CE and the SparseMax attention.

    PYTHONPATH=src python examples/hash_function_study.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from benchmarks.common import CTX, data_for, get_system
from repro.core.hash_fn import init_hash_fn
from repro.core.tkd import evaluate_hash_fn, train_hash_fn
from repro.models.transformer import forward, n_moe_layers


def main():
    E = 16
    cfg, params, _ = get_system(E)
    data = data_for(cfg, seed=42)
    L = n_moe_layers(cfg)

    def batches():
        while True:
            toks, _, _ = data.sample(8)
            out = forward(params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True)
            emb = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
            yield emb, out["router_logits"]

    toks, _, _ = data.sample(32)
    out = forward(params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True)
    emb_eval = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
    teacher_eval = out["router_logits"]

    print(f"{'objective':>28} {'top1':>7} {'top3':>7}")
    for label, T, lam in [
        ("TKD(T=2) + CE (paper-ish)", 2, 0.005),
        ("TKD(T=8) + CE", 8, 0.005),
        ("full KD (T=E) + CE", E, 0.005),
        ("CE only (lam>>, no KD)", 1, 100.0),
    ]:
        hp = init_hash_fn(jax.random.PRNGKey(0), cfg.d_model, L, E, d_h=32)
        hp, _ = train_hash_fn(hp, batches(), steps=120, lr=3e-3, T=T, lam=lam,
                              verbose=False)
        m = evaluate_hash_fn(hp, emb_eval, teacher_eval)
        print(f"{label:>28} {m['top1_hit']:7.3f} {m['top3_hit']:7.3f}")
    print(f"{'(chance)':>28} {1/E:7.3f} {3/E:7.3f}")


if __name__ == "__main__":
    main()
