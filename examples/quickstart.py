"""Quickstart: the whole SiDA-MoE pipeline in two minutes on CPU.

  1. train a miniature Switch-Transformer MoE on a synthetic corpus
  2. train the LSTM hash function with truncated knowledge distillation
  3. serve with the two-thread SiDA engine under a 50% expert-memory budget
  4. compare against Standard / OnDemand / PrefetchAll

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.baselines import OnDemandServer, PrefetchAllServer, StandardServer
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.tkd import evaluate_hash_fn, train_hash_fn
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, init_params, n_moe_layers, param_count
from repro.optim.adamw import adamw_init

CTX = ShardingCtx()


def main():
    # -- 1. model + data ----------------------------------------------------
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=4,
        moe=dataclasses.replace(cfg.moe, d_expert=512, capacity_factor=4.0),
    )
    E = cfg.moe.num_experts
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {cfg.name} (reduced)  params={param_count(params):,}  E={E}")
    data = SyntheticLM(
        SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=48, n_domains=8), seed=0
    )

    step = jax.jit(make_train_step(cfg, CTX, lr=2e-3))
    opt = adamw_init(params)
    print("training MoE ...")
    for i, (toks, labels) in enumerate(data.batches(8, 80)):
        params, opt, m = step(params, opt, jnp.asarray(toks), jnp.asarray(labels))
        if i % 20 == 0:
            print(f"  step {i:3d}  lm_loss {float(m['lm_loss']):.3f}")

    # -- 2. offline hash-function training (TKD) ----------------------------
    hp = init_hash_fn(jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg), E, d_h=32)

    def hash_batches():
        while True:
            toks, _, _ = data.sample(8)
            out = forward(params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True)
            emb = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
            yield emb, out["router_logits"]

    print("training hash function (truncated KD) ...")
    hp, _ = train_hash_fn(hp, hash_batches(), steps=150, lr=3e-3, T=E, log_every=50)
    toks, _, _ = data.sample(16)
    out = forward(params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True)
    emb = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
    hits = evaluate_hash_fn(hp, emb, out["router_logits"])
    print(f"hash hit rate: top1={hits['top1_hit']:.3f} top3={hits['top3_hit']:.3f} "
          f"(chance={1/E:.3f})")

    # -- 3 & 4. serve -------------------------------------------------------
    batches = [data.sample(8)[0] for _ in range(6)]
    slots = E // 4
    servers = {
        "Standard   (all experts resident)": StandardServer(cfg, params),
        "OnDemand   (naive offloading)": OnDemandServer(cfg, params, slots_per_layer=slots),
        "PrefetchAll(data-unaware stream)": PrefetchAllServer(cfg, params, slots_per_layer=slots),
        "SiDA       (data-aware, 2-thread)": SiDAEngine(cfg, params, hp, slots_per_layer=slots),
    }
    print(f"\nserving 6 batches, expert budget = {slots}/{E} experts per layer:")
    for name, srv in servers.items():
        # warmup (compile) then measure
        if isinstance(srv, SiDAEngine):
            srv.serve(batches[:1], threaded=False)
            m = srv.serve(batches, threaded=True)
        else:
            srv.serve(batches[:1])
            m = srv.serve(batches)
        extra = ""
        if isinstance(srv, SiDAEngine):
            ms = srv.memory_saving()
            extra = f"  expert-mem saved {100*ms['reduction']:.0f}%"
        print(f"  {name}: {m.throughput:8.0f} tok/s  "
              f"lat {1e3*m.mean_latency:6.1f} ms{extra}")


if __name__ == "__main__":
    main()
