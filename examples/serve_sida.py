"""Serving scenario: memory-budget sweep (paper Fig. 11 style).

Trains a miniature 16-expert Switch MoE + hash function once (cached), then
sweeps the device expert budget and reports throughput / latency / residency
for SiDA vs the data-unaware alternatives.

    PYTHONPATH=src python examples/serve_sida.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import get_system, profile_batches, warmed
from repro.core.baselines import OnDemandServer, PrefetchAllServer
from repro.core.engine import SiDAEngine


def main():
    E = 16
    cfg, params, hp = get_system(E)
    batches = profile_batches(cfg, "mrpc", 6, 8)
    print(f"arch={cfg.name} E={E}; sweeping device expert budget\n")
    print(f"{'budget':>8} {'engine':>12} {'tok/s':>9} {'lat ms':>8} "
          f"{'loads':>6} {'hits':>6} {'evict':>6}")
    for slots in (2, 4, 8, 16):
        for name, ctor in (
            ("sida", lambda: SiDAEngine(cfg, params, hp, slots_per_layer=slots)),
            ("ondemand", lambda: OnDemandServer(cfg, params, slots_per_layer=slots)),
            ("prefetchall", lambda: PrefetchAllServer(cfg, params, slots_per_layer=slots)),
        ):
            eng = warmed(ctor(), batches)
            m = (
                eng.serve(batches, threaded=True)
                if isinstance(eng, SiDAEngine)
                else eng.serve(batches)
            )
            st = eng.store.stats
            print(f"{slots:>5}/{E:<2} {name:>12} {m.throughput:9.0f} "
                  f"{1e3*m.mean_latency:8.1f} {st.loads:6d} {st.hits:6d} "
                  f"{st.evictions:6d}")
        print()


if __name__ == "__main__":
    main()
