"""End-to-end training driver: a ~100M-parameter model for a few hundred
steps on the synthetic corpus (deliverable b).

Defaults to the full-size smollm-135m config (135M params). On this CPU
container a few hundred steps take a while — pass --steps/--batch/--seq to
scale, or --arch switch-base-8 --reduced for a fast demonstration.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --batch 4 --seq 128
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="experiments/ckpt/train_lm")
    args = ap.parse_args()
    params, history = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, reduced=args.reduced, ckpt=args.ckpt,
    )
    losses = [h["loss"] for h in history]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({100*(1-losses[-1]/losses[0]):.1f}% reduction)")


if __name__ == "__main__":
    main()
