"""Pytree checkpointing: npz shards + JSON manifest. Host-gathered, atomic."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np


def _flatten(params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz can't store bf16: upcast
            arr = arr.astype(np.float32)  # (lossless; manifest keeps dtype)
        flat[key] = arr
    return flat


def save_checkpoint(path: str, params, step: int = 0, extra: dict | None = None):
    os.makedirs(path, exist_ok=True)
    orig_dtypes = {}
    for tree_path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in tree_path)
        orig_dtypes[key] = str(np.asarray(leaf).dtype)
    flat = _flatten(params)
    manifest = {
        "step": step,
        "keys": {
            k: {"shape": list(v.shape), "dtype": orig_dtypes[k]}
            for k, v in flat.items()
        },
        "extra": extra or {},
    }
    # atomic: write temp then rename (np.savez appends .npz if missing)
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp, os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, like=None):
    """Restore. If `like` pytree given, restore into its structure/dtypes."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {k: data[k] for k in data.files}
    if like is None:
        return flat, manifest
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    paths, treedef = jax.tree_util.tree_flatten(like)
    out = []
    for path, leaf in leaves_with_path[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        assert list(arr.shape) == list(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_with_path[1], out), manifest
