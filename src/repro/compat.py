"""Version-compatibility shims for jax APIs that moved between releases.

The repo targets current jax but must degrade gracefully on the versions
CI and laptops actually have (e.g. 0.4.3x, where `shard_map` still lives
under `jax.experimental` and `jax.sharding.AxisType` does not exist yet).
"""
from __future__ import annotations

import jax


def shard_map(*args, **kwargs):
    """`jax.shard_map` where available, `jax.experimental.shard_map` before
    it was promoted (jax < 0.6)."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    return fn(*args, **kwargs)
