"""Model/config system.

Every architecture in the assigned pool (plus the paper's own Switch family)
is expressed as a single `ModelConfig`. The transformer builder
(`repro.models.transformer`) consumes nothing but this dataclass, so adding an
architecture is adding a config file.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts settings for a layer stack."""

    num_experts: int = 0              # routed experts (0 => dense FFN)
    top_k: int = 1
    d_expert: int = 0                 # hidden dim of each routed expert
    num_shared_experts: int = 0       # DeepSeek-style always-on experts
    d_shared: int = 0                 # hidden dim of each shared expert
    capacity_factor: float = 1.25     # train-time capacity for dispatch
    router_aux_coef: float = 0.01     # load-balance loss weight
    router_z_coef: float = 1e-3       # router z-loss weight
    moe_every: int = 1                # MoE layer stride (1 => every layer)

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class AttnConfig:
    """Attention settings."""

    qkv_bias: bool = False
    qk_norm: bool = False             # chameleon-style per-head q/k RMSNorm
    logit_softcap: float = 0.0        # gemma2 attention softcap (0 = off)
    window: int = 0                   # sliding-window size (0 = full)
    # per-layer pattern cycled over depth, entries: "local" | "global"
    layer_pattern: Tuple[str, ...] = ("global",)
    rope_theta: float = 10000.0


@dataclass(frozen=True)
class PrefetchConfig:
    """Async expert-prefetch pipeline settings (serving-time).

    `depth` is the lookahead: how many prediction batches may have uploads
    outstanding at once (bounds both transfer-queue backpressure and the
    eviction-protection working set). `staging_buffers` sizes the host
    staging ring the transfer thread double-buffers H2D copies through."""

    enabled: bool = False
    depth: int = 2                    # max outstanding prefetch tickets
    staging_buffers: int = 2          # host staging slabs (2 = double-buffered)
    # fault tolerance (see core/faults.py and ARCHITECTURE.md "Failure
    # model"): a failed upload batch is retried with bounded exponential
    # backoff; exhausted retries poison its fences, and `degrade_after`
    # consecutive abandonments flip the shard to the synchronous path
    max_retries: int = 3              # upload attempts = 1 + max_retries
    backoff_s: float = 0.002          # base backoff (doubles per attempt)
    degrade_after: int = 3            # consecutive failures -> degraded mode


@dataclass(frozen=True)
class SpecConfig:
    """Speculative multi-token decode settings (serving-time).

    The LSTM hash predictor already runs ahead of the model; `mode="draft"`
    additionally reads a tied-embedding next-token head off the same
    predictor state, unrolls it `k` steps to propose a draft block, and
    verifies the whole block in one jitted k-position decode. The union of
    the k positions' predicted expert sets ships as a single multi-token
    prefetch ticket (a strict superset of each per-step ticket), so
    speculation deepens expert-prefetch lookahead for free."""

    mode: str = "off"                 # "off" | "draft"
    k: int = 4                        # draft tokens proposed per verify step

    @property
    def enabled(self) -> bool:
        return self.mode != "off" and self.k > 1


@dataclass(frozen=True)
class TierConfig:
    """Hierarchical residency tiers for the device expert cache.

    With `int4_slots` the slot pool splits into a HOT tier (int8 slots, the
    existing fused-dequant format) and a WARM tier (int4 group-quantized
    slots — ~2× more resident experts per byte at coarser precision); cold
    experts stay on host. The decayed α-mass EMA drives promotion/demotion
    between the tiers (see ExpertStore.plan_layer).

    `tier_split` is the share of the slot-BYTE budget spent on hot int8
    slots; the remainder buys warm int4 slots (so `slots_per_layer` keeps
    meaning "budget in int8-slot units" — the equal-bytes currency every
    capacity bench uses). `warm_slots` overrides the derived warm count
    directly. `group_size` is the int4 contraction-axis scale group (one f32
    scale per `group_size` input channels per output channel); 64 keeps the
    scale-plane overhead low enough for ≥1.8× capacity vs int8 on the
    miniature configs. `promote_margin` is the promotion hysteresis: a warm
    expert promotes only when its decayed α mass exceeds `promote_margin ×`
    the coldest demotable hot expert's (or a hot slot is free)."""

    int4_slots: bool = False
    tier_split: float = 0.5
    group_size: int = 64
    promote_margin: float = 1.25
    warm_slots: Optional[int] = None

    @property
    def enabled(self) -> bool:
        return self.int4_slots


@dataclass(frozen=True)
class QuantConfig:
    """Expert-weight quantization settings (serving-time).

    `quantized_slots` makes int8 the *native residency format*: device slot
    pools hold int8 expert weights plus per-expert scale planes, uploads move
    quantized slabs with no dequant hop, and the expert FFN dequantizes
    in-kernel (fused) — so a fixed slot-byte budget holds 2–4× more experts
    than fp slots. `scale_granularity` picks how scales are computed:
    "channel" (per-output-channel absmax, tighter) or "tensor" (one scale per
    expert tensor, coarser but smaller metadata); storage is always a
    per-channel plane so kernels stay uniform.

    `tier` adds the hot/warm/cold residency hierarchy on top (int4 warm
    slots; requires `quantized_slots` — see TierConfig)."""

    quantized_slots: bool = False
    scale_granularity: str = "channel"  # "channel" | "tensor"
    tier: TierConfig = field(default_factory=TierConfig)


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent block settings (mamba + xLSTM)."""

    state_dim: int = 16               # mamba N (per-channel state)
    conv_dim: int = 4                 # mamba depthwise conv width
    expand: int = 2                   # mamba inner expansion
    # xLSTM: pattern over depth, entries: "m" (mLSTM) | "s" (sLSTM)
    xlstm_pattern: Tuple[str, ...] = ()
    xlstm_heads: int = 4


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "hybrid", "ssm", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # one of FAMILIES
    citation: str = ""                # source paper / model card

    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 0                 # 0 => d_model // n_heads
    d_ff: int = 256                   # dense FFN hidden (ignored if pure-MoE)
    vocab_size: int = 1024

    act: str = "silu"                 # "silu" | "gelu"
    glu: bool = True                  # gated FFN (SwiGLU/GeGLU)
    norm_eps: float = 1e-6
    post_norm: bool = False           # gemma2 extra post-sublayer norms
    tie_embeddings: bool = True
    final_logit_softcap: float = 0.0  # gemma2
    embed_scale: bool = False         # gemma2 multiplies embeddings by sqrt(d)

    moe: MoEConfig = field(default_factory=MoEConfig)
    attn: AttnConfig = field(default_factory=AttnConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    prefetch: PrefetchConfig = field(default_factory=PrefetchConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)
    spec: SpecConfig = field(default_factory=SpecConfig)

    # block layout: "attn" (transformer), "hymba" (parallel attn+ssm),
    # "xlstm" (recurrent-only stack)
    block_kind: str = "attn"

    # encoder-decoder (audio)
    enc_dec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub: "text" | "audio" | "vision"
    modality: str = "text"

    dtype: str = "bfloat16"

    # ---- derived -----------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (§Perf hillclimb #2).

        Unpadded odd vocabs (seamless 256206, hymba 32001) cannot shard
        over the model axis, leaving the f32 [B,S,V] logits replicated —
        67 GB/device at train_4k. Padding is the standard production fix
        (MaxText pads too); padded logit columns are masked to -inf in
        `unembed` so they are unreachable by loss/argmax/sampling.
        """
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts?  (see DESIGN.md)"""
        if self.block_kind in ("xlstm", "hymba"):
            return True
        # dense archs qualify only with a native sliding-window variant
        return self.attn.window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec: decoder)

    def pattern_at(self, layer: int) -> str:
        p = self.attn.layer_pattern
        return p[layer % len(p)]

    def layer_window(self, layer: int) -> int:
        """Effective attention window for a layer (0 = full)."""
        if self.block_kind == "hymba":
            return self.attn.window
        if self.pattern_at(layer) == "local":
            return self.attn.window
        return 0

    # ---- param accounting (used by memory benches / Table 2) ----------
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.hd
        nq, nkv = self.n_heads, self.n_kv_heads
        attn = d * hd * nq + 2 * d * hd * nkv + hd * nq * d
        if self.attn.qkv_bias:
            attn += hd * (nq + 2 * nkv)
        ffn_mult = 3 if self.glu else 2
        dense_ffn = ffn_mult * d * self.d_ff if self.d_ff else 0
        expert = ffn_mult * d * self.moe.d_expert if self.moe.enabled else 0
        shared = ffn_mult * d * self.moe.d_shared * self.moe.num_shared_experts
        router = d * self.moe.num_experts if self.moe.enabled else 0
        if self.block_kind == "xlstm":
            per_layer = 8 * d * d  # coarse: proj + gates
            moe_total = 0
        elif self.moe.enabled:
            per_layer = attn + router + shared + expert * self.moe.num_experts
            moe_total = self.n_layers * expert * self.moe.num_experts
        else:
            per_layer = attn + dense_ffn
            moe_total = 0
        if self.block_kind == "hymba":
            per_layer += 4 * d * d  # ssm branch
        n_blocks = self.n_layers + (self.n_enc_layers if self.enc_dec else 0)
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = n_blocks * per_layer + embed
        return {
            "total": total,
            "moe": moe_total,
            "active": total - moe_total
            + (self.n_layers * expert * (self.moe.top_k) if self.moe.enabled else 0),
            "embed": embed,
        }

    def bytes_per_param(self) -> int:
        return {"bfloat16": 2, "float32": 4, "float16": 2}[self.dtype]

    # ---- reduced variant for CPU smoke tests --------------------------
    def reduced(self) -> "ModelConfig":
        """Same family/features, laptop-sized: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 128)
        nh = max(1, min(self.n_heads, 4))
        nkv = max(1, min(self.n_kv_heads, nh))
        while nh % nkv:
            nkv -= 1
        moe = self.moe
        if moe.enabled:
            moe = replace(
                moe,
                num_experts=min(moe.num_experts, 4),
                top_k=min(moe.top_k, 2),
                d_expert=min(moe.d_expert, 64) or 64,
                num_shared_experts=min(moe.num_shared_experts, 1),
                d_shared=min(moe.d_shared, 64) if moe.d_shared else 0,
            )
        attn = replace(
            self.attn,
            window=min(self.attn.window, 64) if self.attn.window else 0,
        )
        ssm = replace(
            self.ssm,
            state_dim=min(self.ssm.state_dim, 8),
            xlstm_heads=max(1, min(self.ssm.xlstm_heads, 2)),
            xlstm_pattern=self.ssm.xlstm_pattern[:2] or self.ssm.xlstm_pattern,
        )
        return replace(
            self,
            n_layers=2,
            n_enc_layers=2 if self.enc_dec else 0,
            d_model=d,
            n_heads=nh,
            n_kv_heads=nkv,
            head_dim=min(self.hd, 32),
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            moe=moe,
            attn=attn,
            ssm=ssm,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.family in FAMILIES, cfg.family
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Sequence[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import side-effect registers configs
    from repro.configs import (  # noqa: F401
        gemma2_9b,
        qwen3_moe_235b_a22b,
        stablelm_12b,
        hymba_1_5b,
        qwen2_1_5b,
        chameleon_34b,
        seamless_m4t_medium,
        xlstm_125m,
        deepseek_moe_16b,
        smollm_135m,
        switch_base,
    )


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is part of the coverage matrix; reason if not."""
    if shape.kind == "decode" and shape.seq_len > 100_000 and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md)"
    return True, ""
