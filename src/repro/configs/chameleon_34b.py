"""chameleon-34b [vlm] — early-fusion; images are VQ tokens in the vocab.

[arXiv:2405.09818] 48L, d_model 8192, 64 heads (GQA kv=8), d_ff 22016,
vocab 65536 (text + VQ image codes), qk-norm for stability. Early fusion
means the "vision frontend" is a VQ tokenizer producing ordinary token ids;
per the spec carve-out, `input_specs()` provides pre-tokenised mixed
text+image id sequences (the backbone is what we implement).
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="chameleon-34b",
        family="vlm",
        citation="arXiv:2405.09818",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        tie_embeddings=False,
        modality="vision",
        attn=AttnConfig(qk_norm=True, rope_theta=10000.0),
    )
)
