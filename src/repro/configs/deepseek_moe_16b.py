"""deepseek-moe-16b [moe] — fine-grained experts, 2 shared + 64 routed top-6.

[arXiv:2401.06066] 28L, d_model 2048, 16 heads (MHA kv=16), expert d_ff 1408,
vocab 102400, 64 routed experts top-6 plus 2 shared (always-active) experts.
Shared experts are never offloaded by SiDA (always resident); the hash
function predicts routed experts only.
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        citation="arXiv:2401.06066",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=0,  # pure-MoE FFN (shared experts provide the dense path)
        vocab_size=102400,
        tie_embeddings=False,
        attn=AttnConfig(rope_theta=10000.0),
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            d_expert=1408,
            num_shared_experts=2,
            d_shared=1408,
            router_aux_coef=0.001,
        ),
    )
)
