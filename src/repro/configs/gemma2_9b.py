"""gemma2-9b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118] Gemma 2 technical report. 42L, d_model 3584, 16 heads
(GQA kv=8), head_dim 256, d_ff 14336 (GeGLU), vocab 256000, sliding window
4096 on local layers, attn softcap 50, final logit softcap 30.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma2-9b",
        family="dense",
        citation="arXiv:2408.00118",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        act="gelu",
        glu=True,
        post_norm=True,
        tie_embeddings=True,
        embed_scale=True,
        final_logit_softcap=30.0,
        attn=AttnConfig(
            logit_softcap=50.0,
            window=4096,
            layer_pattern=("local", "global"),
            rope_theta=10000.0,
        ),
    )
)
