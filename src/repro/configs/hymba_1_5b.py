"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

[arXiv:2411.13676] 32L, d_model 1600, 25 attn heads (GQA kv=5),
d_ff 5504, vocab 32001, ssm_state 16. Hymba fuses an attention branch and
a Mamba branch *in parallel* inside each block (outputs mean-fused after
per-branch normalisation); most layers use sliding-window attention, which
is what makes 500k-token decode tractable.
"""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        citation="arXiv:2411.13676",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        block_kind="hymba",
        attn=AttnConfig(window=2048, layer_pattern=("local",)),
        ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
    )
)
