"""qwen2-1.5b [dense] — GQA with QKV bias.

[arXiv:2407.10671] 28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960,
vocab 151936, qkv bias, tied embeddings.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        citation="arXiv:2407.10671",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        tie_embeddings=True,
        attn=AttnConfig(qkv_bias=True, rope_theta=1000000.0),
    )
)
