"""qwen3-moe-235b-a22b [moe] — 128 routed experts, top-8, fine-grained FFN.

[hf:Qwen/Qwen3-30B-A3B family scaled per assignment] 94L, d_model 4096,
64 heads (GQA kv=4), head_dim 128, expert d_ff 1536, vocab 151936,
MoE 128 experts top-8 on every layer, qk-norm, no qkv bias.
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        citation="hf:Qwen/Qwen3-30B-A3B",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=0,  # pure-MoE FFN: every layer routed
        vocab_size=151936,
        tie_embeddings=False,
        attn=AttnConfig(qk_norm=True, rope_theta=1000000.0),
        moe=MoEConfig(
            num_experts=128,
            top_k=8,
            d_expert=1536,
            router_aux_coef=0.001,
        ),
    )
)
