"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

[arXiv:2308.11596] 12 encoder + 12 decoder layers, d_model 1024, 16 heads
(MHA, kv=16), d_ff 4096, vocab 256206. The audio frontend (mel-spectrogram +
conv feature extractor) is STUBBED per the spec carve-out: `input_specs()`
provides precomputed frame embeddings [batch, frames, d_model]; we implement
the transformer encoder + autoregressive text decoder with cross-attention.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        citation="arXiv:2308.11596",
        n_layers=12,
        n_enc_layers=12,
        enc_dec=True,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        act="gelu",
        glu=False,
        tie_embeddings=True,
        modality="audio",
        attn=AttnConfig(rope_theta=10000.0),
    )
)
