"""smollm-135m [dense] — llama-arch small model.

[hf:HuggingFaceTB/SmolLM-135M] 30L, d_model 576, 9 heads (GQA kv=3),
d_ff 1536, vocab 49152, tied embeddings.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="smollm-135m",
        family="dense",
        citation="hf:HuggingFaceTB/SmolLM-135M",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab_size=49152,
        tie_embeddings=True,
        attn=AttnConfig(rope_theta=10000.0),
    )
)
