"""stablelm-12b [dense] — llama-style GQA decoder.

[hf:stabilityai/stablelm-2-1_6b family, 12b per assignment] 40L,
d_model 5120, 32 heads (GQA kv=8), d_ff 13824, vocab 100352.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="stablelm-12b",
        family="dense",
        citation="hf:stabilityai/stablelm-2-1_6b",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=13824,
        vocab_size=100352,
        tie_embeddings=False,
        attn=AttnConfig(rope_theta=10000.0, qkv_bias=False),
    )
)
