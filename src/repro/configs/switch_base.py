"""Switch Transformer base family — the paper's own models.

[arXiv:2101.03961 / Fedus et al. 2022] T5-base backbone: 12 layers,
d_model 768, 12 heads, d_ff 3072, vocab 32128, MoE every other layer,
top-1 routing, E ∈ {8, 64, 128, 256}. These are the models SiDA-MoE
evaluates (Table 2, Figs 2-4, 8-11). We model the decoder-only analogue
(the paper's measurements are agnostic to enc-dec vs dec-only — what
matters is the MoE layer structure and expert count).
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, register


def _switch(num_experts: int) -> ModelConfig:
    return register(
        ModelConfig(
            name=f"switch-base-{num_experts}",
            family="moe",
            citation="arXiv:2101.03961",
            n_layers=12,
            d_model=768,
            n_heads=12,
            n_kv_heads=12,
            d_ff=3072,
            vocab_size=32128,
            act="gelu",
            glu=False,
            tie_embeddings=True,
            attn=AttnConfig(rope_theta=10000.0),
            moe=MoEConfig(
                num_experts=num_experts,
                top_k=1,
                d_expert=3072,
                moe_every=2,  # MoE on every other layer, as in Switch
                capacity_factor=1.25,
            ),
        )
    )


SWITCH_BASE_8 = _switch(8)
SWITCH_BASE_64 = _switch(64)
SWITCH_BASE_128 = _switch(128)
SWITCH_BASE_256 = _switch(256)
