"""xlstm-125m [ssm] — sLSTM + mLSTM recurrent blocks, attention-free.

[arXiv:2405.04517] 12 blocks, d_model 768, 4 heads, vocab 50304, d_ff 0
(blocks carry their own up/down projections). Pattern alternates mLSTM
(matrix-memory, parallelisable) and sLSTM (scalar-memory, strictly
recurrent) as in the paper's 1:1 configs. O(1)-state decode => long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        citation="arXiv:2405.04517",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_kind="xlstm",
        tie_embeddings=True,
        ssm=SSMConfig(xlstm_pattern=("m", "s"), xlstm_heads=4),
    )
)
