"""Serving baselines (paper §4 Setup): Standard, OnDemand, PrefetchAll.

* Standard      — the stock implementation: every expert resident on device,
                  routers run inline, dense dispatch over all E experts.
* OnDemand      — naive offloading (the paper's Challenge-1 strawman): experts
                  live on host; routing is only known after each router runs,
                  so every MoE layer synchronously loads its activated experts,
                  stalling the forward pipeline.
* PrefetchAll   — data-UNAWARE streaming under a memory budget (proxy for
                  DeepSpeed/Tutel-style model-parallel serving): each MoE layer
                  loads ALL its experts through the slot pool in ⌈E/S⌉ waves,
                  computing each wave's tokens after its load completes.

All three share the model substrate; OnDemand/PrefetchAll reuse the
ExpertStore slot cache so memory budgets are comparable with SiDA.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import ServeMetrics
from repro.core.offload import ExpertStore
from repro.models.attention import ShardingCtx
from repro.models.layers import rmsnorm
from repro.models.moe import router_topk
from repro.models.transformer import (
    _apply_sublayer_full,
    embed_tokens,
    forward,
    n_moe_layers,
    period,
    sub_kind,
    unembed,
)


class StandardServer:
    """Everything resident; router inline; all-expert dense dispatch."""

    def __init__(self, cfg: ModelConfig, params: dict, ctx: ShardingCtx = ShardingCtx()):
        self.cfg, self.params, self.ctx = cfg, params, ctx

        @jax.jit
        def _fwd(p, tokens):
            return forward(p, cfg, ctx, tokens)["logits"]

        self._fwd = _fwd

    def serve(self, batches: Sequence[np.ndarray]) -> ServeMetrics:
        m = ServeMetrics()
        t_start = time.perf_counter()
        for toks in batches:
            t0 = time.perf_counter()
            logits = self._fwd(self.params, jnp.asarray(toks))
            jax.block_until_ready(logits)
            m.latency_s.append(time.perf_counter() - t0)
            m.tokens += int(np.prod(toks.shape))
        m.wall_s = time.perf_counter() - t_start
        return m

    def device_memory_bytes(self) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(self.params))


class _LayerwiseServer:
    """Shared python-loop forward for offloading baselines.

    The layer loop runs in Python so host<->device synchronisation points
    (router output -> expert load) are faithfully serialised, exactly like
    the naive implementation the paper describes.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        slots_per_layer: int,
        ctx: ShardingCtx = ShardingCtx(),
    ):
        assert cfg.moe.enabled
        self.cfg, self.ctx = cfg, ctx
        self.per = period(cfg)
        self.n_groups = cfg.n_layers // self.per
        self.store = ExpertStore(cfg, params, slots_per_layer)
        # routers stay on device for these baselines (they must run inline)
        self.routers = {
            f"sub{s}": jnp.asarray(params["blocks"][f"sub{s}"]["moe"]["router"])
            for s in range(self.per)
            if sub_kind(cfg, s).get("moe")
        }
        self.embed = params["embed"]
        self.final_norm = params["final_norm"]
        self.head = params.get("head")
        cfg_ = cfg

        @partial(jax.jit, static_argnames=("sub",))
        def _sublayer_dense(gp, x, sub: int):
            y, _ = _apply_sublayer_full(
                gp, x, cfg_, ctx, sub, True, None, None, "scan"
            )
            return y

        @partial(jax.jit, static_argnames=("sub",))
        def _attn_part(gp, x, sub: int):
            # attention + residual + pre-MoE norm + router logits
            sk_params = {k: v for k, v in gp.items() if k != "moe"}
            h = rmsnorm(gp["ln1"], x, cfg_.norm_eps)
            from repro.models.attention import attend_full

            a = attend_full(gp["attn"], h, cfg_, sub, ctx)
            if cfg_.post_norm:
                a = rmsnorm(gp["ln1_post"], a, cfg_.norm_eps)
            x = x + a
            h2 = rmsnorm(gp["ln2"], x, cfg_.norm_eps)
            return x, h2

        @jax.jit
        def _router_logits(router, h2):
            return h2.astype(jnp.float32) @ router

        @jax.jit
        def _moe_part(moe_p, x, h2, slot_ids, w):
            from repro.models.moe import moe_layer

            y, _ = moe_layer(
                moe_p, h2, cfg_, ctx, routing_override=(slot_ids, w)
            )
            if cfg_.post_norm:
                pass  # post-norm handled in dense path only (switch has none)
            return x + y

        @jax.jit
        def _final(x, embed, head):
            x = rmsnorm(self.final_norm, x, cfg_.norm_eps)
            if cfg_.tie_embeddings:
                return x @ embed.T
            return x @ head

        self._sublayer_dense = _sublayer_dense
        self._attn_part = _attn_part
        self._router_logits = _router_logits
        self._moe_part = _moe_part
        self._final = _final

    def _group_params(self, g: int) -> dict:
        return jax.tree.map(lambda x: x[g], self.store.serve_params["blocks"])

    def _needed_experts(self, l: int, ids: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def _forward_batch(self, tokens: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        x = embed_tokens({"embed": self.embed}, cfg, jnp.asarray(tokens))
        l = 0
        for g in range(self.n_groups):
            gp = self._group_params(g)
            for s in range(self.per):
                sp = gp[f"sub{s}"]
                if not sub_kind(cfg, s).get("moe"):
                    x = self._sublayer_dense(sp, x, s)
                    continue
                x, h2 = self._attn_part(sp, x, s)
                # routers are stacked over groups: index this group's router
                logits = self._router_logits(self.routers[f"sub{s}"][g], h2)
                ids, w = router_topk(
                    logits.reshape(-1, cfg.moe.num_experts), cfg.moe.top_k
                )
                ids_np = np.asarray(ids)  # HOST SYNC — the pipeline stall
                x = self._moe_with_loads(l, g, s, x, h2, ids_np, ids, w)
                l += 1
        return self._final(x, self.embed, self.head)

    def _moe_with_loads(self, l, g, s, x, h2, ids_np, ids, w):
        raise NotImplementedError

    def _fresh_moe_params(self, g: int, s: int) -> dict:
        """Slot buffers are functionally replaced on load — always re-fetch."""
        return jax.tree.map(
            lambda a: a[g], self.store.serve_params["blocks"][f"sub{s}"]["moe"]
        )

    def serve(self, batches: Sequence[np.ndarray]) -> ServeMetrics:
        m = ServeMetrics()
        t_start = time.perf_counter()
        for toks in batches:
            t0 = time.perf_counter()
            logits = self._forward_batch(toks)
            jax.block_until_ready(logits)
            m.latency_s.append(time.perf_counter() - t0)
            m.tokens += int(np.prod(toks.shape))
        m.wall_s = time.perf_counter() - t_start
        return m

    def device_memory_bytes(self) -> int:
        non_expert = sum(
            x.nbytes for x in jax.tree.leaves(self.store.serve_params)
        )
        return non_expert  # slot buffers included; host experts excluded


class OnDemandServer(_LayerwiseServer):
    """Load experts only after the router reveals them (synchronous stall)."""

    def _moe_with_loads(self, l, g, s, x, h2, ids_np, ids, w):
        uniq, counts = np.unique(ids_np, return_counts=True)
        needed = uniq[np.argsort(-counts)]
        trans_row = self.store.prepare_layer(l, needed)  # synchronous H2D
        B, S, _ = np.shape(h2)
        slot_flat = jnp.asarray(trans_row)[ids]                    # [T, k]
        w = w * (slot_flat >= 0)
        slot_ids = jnp.maximum(slot_flat, 0).reshape(B, S, -1)
        return self._moe_part(
            self._fresh_moe_params(g, s), x, h2, slot_ids, w.reshape(B, S, -1)
        )


class PrefetchAllServer(_LayerwiseServer):
    """Data-unaware: stream every expert of every layer through the slots."""

    def _moe_with_loads(self, l, g, s, x, h2, ids_np, ids, w):
        E, S_slots = self.store.E, self.store.S
        B, S, _ = np.shape(h2)
        y_parts = None
        for wave_start in range(0, E, S_slots):
            wave = np.arange(wave_start, min(E, wave_start + S_slots))
            trans_row = self.store.prepare_layer(l, wave)
            slot_flat = jnp.asarray(trans_row)[ids]
            in_wave = (ids >= wave_start) & (ids < wave_start + S_slots)
            w_wave = w * in_wave * (slot_flat >= 0)
            slot_ids = jnp.maximum(slot_flat, 0).reshape(B, S, -1)
            part = self._moe_part(
                self._fresh_moe_params(g, s), jnp.zeros_like(x), h2,
                slot_ids, w_wave.reshape(B, S, -1),
            )
            y_parts = part if y_parts is None else y_parts + part
        return x + y_parts
