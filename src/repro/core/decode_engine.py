"""Autoregressive decode serving with incremental hash prediction
(beyond paper — the paper serves full-sequence inference; modern LLM
serving is token-by-token decode, and SiDA's LSTM predictor is naturally
recurrent, so the prediction can advance one token per step).

Per decode step:
  1. `hash_fn_step` advances the predictor's LSTM state on the *previous*
     token's embedding and emits expert ids + α for every MoE layer —
     before the model runs, preserving the look-ahead property;
  2. the ExpertStore loads any missing experts (consecutive tokens reuse
     experts heavily, so steady-state steps are all cache hits);
  3. `decode_step` runs with the routing override (routers offloaded).

The SparseMax attention over LSTM outputs is kept exactly, over a ring
buffer of the last `HISTORY` outputs (identical to the full-sequence
predictor whenever the context fits the ring; the paper's own ĉ∈[1,4]
cross-embedding dependency says distant history is irrelevant).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.hash_fn import sparsemax
from repro.core.hash_table import HashTable
from repro.core.offload import ExpertStore, PrefetchPipeline
from repro.models.attention import ShardingCtx
from repro.models.transformer import decode_step, init_cache, n_moe_layers

Array = jax.Array

HISTORY = 128  # SparseMax attention ring length


# ---------------------------------------------------------------------------
# incremental hash function
# ---------------------------------------------------------------------------


def hash_state_init(params: dict, batch: int) -> dict:
    d_h = params["attn_q"].shape[0]
    z = lambda: jnp.zeros((batch, d_h), jnp.float32)
    return {
        "h1": z(), "c1": z(), "h2": z(), "c2": z(),
        "ring": jnp.zeros((batch, HISTORY, d_h), jnp.float32),
        # per-lane step counter: continuous batching joins/leaves lanes at
        # different sequence positions, so t cannot be shared across batch
        "t": jnp.zeros((batch,), jnp.int32),
    }


def _lstm_cell(p, x, h, c):
    g = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, gg, o = jnp.split(g, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(gg)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def hash_fn_step(
    params: dict, emb_tok: Array, state: dict, num_experts: int
) -> Tuple[Array, dict]:
    """One-token advance. emb_tok: [B, d_model] -> logits [B, L, E]."""
    E = num_experts
    L = params["heads"].shape[-1] // E
    x = jnp.tanh(emb_tok.astype(jnp.float32) @ params["compress"])
    h1, c1 = _lstm_cell(params["lstm1"], x, state["h1"], state["c1"])
    h2, c2 = _lstm_cell(params["lstm2"], h1, state["h2"], state["c2"])
    t = state["t"]                                        # [B] per-lane step
    bidx = jnp.arange(h2.shape[0])
    ring = state["ring"].at[bidx, t % HISTORY].set(h2)
    # sparse attention of the current query over the ring (same math as the
    # full-sequence predictor for t < HISTORY)
    q = h2 @ params["attn_q"]
    scores = jnp.einsum("bd,bkd->bk", q, ring) / math.sqrt(h2.shape[-1])
    valid = jnp.arange(HISTORY)[None, :] <= t[:, None]
    scores = jnp.where(valid, scores, -1e30)
    w = sparsemax(scores, axis=-1)
    a = jnp.einsum("bk,bkd->bd", w, ring)
    logits = (a + h2) @ params["heads"]
    new_state = {"h1": h1, "c1": c1, "h2": h2, "c2": c2, "ring": ring, "t": t + 1}
    return logits.reshape(-1, L, E), new_state


# ---------------------------------------------------------------------------
# decode engine
# ---------------------------------------------------------------------------


@dataclass
class DecodeMetrics:
    steps: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    stall_s: float = 0.0           # time blocked on async prefetch fences
    loads_per_step: List[int] = field(default_factory=list)

    @property
    def tok_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0


class SiDADecodeEngine:
    """Token-by-token generation under an expert memory budget."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        hash_params: dict,
        slots_per_layer: int,
        serve_top_k: Optional[int] = None,
        ctx: ShardingCtx = ShardingCtx(),
        host_quant: str = "none",
        eviction: str = "fifo",
        store: Optional[ExpertStore] = None,
        prefetch_depth: Optional[int] = None,
        staging_buffers: Optional[int] = None,
        prefetcher: Optional[PrefetchPipeline] = None,
        quantized_slots: Optional[bool] = None,
        scale_granularity: Optional[str] = None,
    ):
        self.cfg = cfg
        self.ctx = ctx
        self.k = serve_top_k or cfg.moe.top_k
        self.hash_params = hash_params
        self.store = store if store is not None else ExpertStore(
            cfg, params, slots_per_layer, host_quant=host_quant, eviction=eviction,
            quantized_slots=quantized_slots, scale_granularity=scale_granularity,
        )
        self._owns_prefetcher = False
        if prefetcher is not None:
            self.prefetcher: Optional[PrefetchPipeline] = prefetcher
        else:
            self.prefetcher = PrefetchPipeline.maybe_create(
                self.store, cfg, prefetch_depth, staging_buffers
            )
            self._owns_prefetcher = self.prefetcher is not None
        self.embed_table = params["embed"]
        self.L = n_moe_layers(cfg)
        E = cfg.moe.num_experts

        @jax.jit
        def _predict_step(hp, embed_table, tokens, hstate):
            emb = jnp.take(embed_table, tokens, axis=0)
            logits, hstate = hash_fn_step(hp, emb, hstate, E)
            vals, ids = jax.lax.top_k(logits, self.k)         # [B, L, k]
            alpha = jax.nn.softmax(vals, axis=-1)
            return (
                jnp.moveaxis(ids, 1, 0).astype(jnp.int32),    # [L, B, k]
                jnp.moveaxis(alpha, 1, 0).astype(jnp.float32),
                hstate,
            )

        cfg_ = cfg
        ctx_ = ctx

        @jax.jit
        def _step(serve_params, cache, tokens, slot_ids, w):
            logits, cache = decode_step(
                serve_params, cache, tokens, cfg_, ctx_,
                routing_override=(slot_ids, w),
            )
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        self._predict_step = _predict_step
        self._step = _step

    def generate(
        self, prompt_last_tokens: np.ndarray, steps: int, cache_len: int = 256
    ) -> Tuple[np.ndarray, DecodeMetrics]:
        """Greedy-decode `steps` tokens for a batch, starting from the given
        current tokens (fresh cache; prompts would be prefillled in prod)."""
        B = prompt_last_tokens.shape[0]
        cache = init_cache(self.cfg, B, cache_len)
        hstate = hash_state_init(self.hash_params, B)
        tokens = jnp.asarray(prompt_last_tokens, jnp.int32)
        out = np.zeros((B, steps), np.int32)
        m = DecodeMetrics()
        t0 = time.perf_counter()
        for i in range(steps):
            ids, alpha, hstate = self._predict_step(
                self.hash_params, self.embed_table, tokens, hstate
            )
            table = HashTable(i, np.asarray(ids)[:, :, None, :],
                              np.asarray(alpha)[:, :, None, :])
            loads_before = self.store.stats.loads
            if self.prefetcher is not None:
                # per-lane decode predictions feed the transfer thread; the
                # step only clears ready fences for the experts it needs
                stall0 = self.prefetcher.stats.stall_s
                ticket = self.prefetcher.submit(table)
                ticket.wait()
                m.stall_s += self.prefetcher.stats.stall_s - stall0
                trans = ticket.trans
            else:
                ticket = None
                trans = self.store.prepare(table)
            m.loads_per_step.append(self.store.stats.loads - loads_before)
            slot_ids, w = self.store.translate(table, trans)
            tokens, cache = self._step(
                self.store.serve_params, cache, tokens,
                jnp.asarray(slot_ids[:, :, 0, :]), jnp.asarray(w[:, :, 0, :]),
            )
            out[:, i] = np.asarray(tokens)  # forces the step; slots consumed
            if ticket is not None:
                ticket.release()
            m.steps += 1
            m.tokens += B
        jax.block_until_ready(tokens)
        m.wall_s = time.perf_counter() - t0
        return out, m

    def close(self) -> None:
        """Join the async prefetch transfer thread (no-op when sync or when
        the pipeline is owned by the caller)."""
        if self.prefetcher is not None and self._owns_prefetcher:
            self.prefetcher.close()
