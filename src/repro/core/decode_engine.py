"""Autoregressive decode serving with incremental hash prediction
(beyond paper — the paper serves full-sequence inference; modern LLM
serving is token-by-token decode, and SiDA's LSTM predictor is naturally
recurrent, so the prediction can advance one token per step).

Per decode step:
  1. `hash_fn_step` advances the predictor's LSTM state on the *previous*
     token's embedding and emits expert ids + α for every MoE layer —
     before the model runs, preserving the look-ahead property;
  2. the ExpertStore loads any missing experts (consecutive tokens reuse
     experts heavily, so steady-state steps are all cache hits);
  3. `decode_step` runs with the routing override (routers offloaded).

The SparseMax attention over LSTM outputs is kept exactly, over a ring
buffer of the last `HISTORY` outputs (identical to the full-sequence
predictor whenever the context fits the ring; the paper's own ĉ∈[1,4]
cross-embedding dependency says distant history is irrelevant).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TierConfig
from repro.core.hash_fn import draft_logits_from_state, sparsemax
from repro.core.hash_table import HashTable
from repro.core.offload import ExpertStore, PrefetchPipeline, ShardedStoreConfig
from repro.models.attention import ShardingCtx
from repro.models.transformer import decode_step, init_cache, n_moe_layers, verify_step

Array = jax.Array

HISTORY = 128  # SparseMax attention ring length


# ---------------------------------------------------------------------------
# incremental hash function
# ---------------------------------------------------------------------------


def hash_state_init(params: dict, batch: int) -> dict:
    d_h = params["attn_q"].shape[0]
    z = lambda: jnp.zeros((batch, d_h), jnp.float32)
    return {
        "h1": z(), "c1": z(), "h2": z(), "c2": z(),
        "ring": jnp.zeros((batch, HISTORY, d_h), jnp.float32),
        # per-lane step counter: continuous batching joins/leaves lanes at
        # different sequence positions, so t cannot be shared across batch
        "t": jnp.zeros((batch,), jnp.int32),
    }


def _lstm_cell(p, x, h, c):
    g = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, gg, o = jnp.split(g, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(gg)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def hash_fn_step(
    params: dict, emb_tok: Array, state: dict, num_experts: int,
    embed_table: Optional[Array] = None,
):
    """One-token advance. emb_tok: [B, d_model] -> logits [B, L, E].

    With `embed_table` (and a draft head in `params`) additionally returns
    tied-embedding next-token draft logits [B, V] between the expert logits
    and the new state — the speculative decode loop reads both off the same
    predictor pass."""
    E = num_experts
    L = params["heads"].shape[-1] // E
    x = jnp.tanh(emb_tok.astype(jnp.float32) @ params["compress"])
    h1, c1 = _lstm_cell(params["lstm1"], x, state["h1"], state["c1"])
    h2, c2 = _lstm_cell(params["lstm2"], h1, state["h2"], state["c2"])
    t = state["t"]                                        # [B] per-lane step
    bidx = jnp.arange(h2.shape[0])
    ring = state["ring"].at[bidx, t % HISTORY].set(h2)
    # sparse attention of the current query over the ring (same math as the
    # full-sequence predictor for t < HISTORY)
    q = h2 @ params["attn_q"]
    scores = jnp.einsum("bd,bkd->bk", q, ring) / math.sqrt(h2.shape[-1])
    valid = jnp.arange(HISTORY)[None, :] <= t[:, None]
    scores = jnp.where(valid, scores, -1e30)
    w = sparsemax(scores, axis=-1)
    a = jnp.einsum("bk,bkd->bd", w, ring)
    z = a + h2
    logits = z @ params["heads"]
    new_state = {"h1": h1, "c1": c1, "h2": h2, "c2": c2, "ring": ring, "t": t + 1}
    if embed_table is not None and "draft_proj" in params:
        draft = draft_logits_from_state(params, z, embed_table)
        return logits.reshape(-1, L, E), draft, new_state
    return logits.reshape(-1, L, E), new_state


# ---------------------------------------------------------------------------
# speculative draft unroll (shared by the engine and the request server)
# ---------------------------------------------------------------------------


def draft_unroll_fn(num_experts: int, top_k: int, K: int):
    """Build the K-step draft unroll: from the last accepted token, advance
    the predictor K times, reading BOTH heads off each state — router heads
    for per-position expert ids/α, the tied-embedding draft head for the
    next (greedy) draft token — and stack the per-position states the
    accept/reject bookkeeping rolls back to.

    One definition serves both consumers (jit each returned callable): the
    engine calls it with `active=None`; the request server passes its lane
    mask so inactive lanes' α is zeroed (their rows route nowhere and the
    masked verify rolls them back entirely). The unroll recurrence and the
    [L, B, K, k] layout are load-bearing for the engine-vs-server greedy
    byte-equivalence, which is why they live in exactly one place.

    Returns (inputs [B, K], ids [L, B, K, k], α [L, B, K, k],
    states stacked [K, B, ...] leaves).
    """

    def unroll(hp, embed_table, tokens, hstate, active=None):
        def step(carry, _):
            tok, st = carry
            emb = jnp.take(embed_table, tok, axis=0)
            logits, dlog, st = hash_fn_step(hp, emb, st, num_experts, embed_table)
            vals, ids = jax.lax.top_k(logits, top_k)         # [B, L, k]
            alpha = jax.nn.softmax(vals, axis=-1)
            if active is not None:
                alpha = alpha * active[:, None, None]
            nxt = jnp.argmax(dlog, -1).astype(jnp.int32)
            return (nxt, st), (
                tok,
                jnp.moveaxis(ids, 1, 0).astype(jnp.int32),   # [L, B, k]
                jnp.moveaxis(alpha, 1, 0).astype(jnp.float32),
                st,
            )

        (_, _), (toks, ids, alpha, states) = jax.lax.scan(
            step, (tokens, hstate), None, length=K
        )
        return (
            jnp.moveaxis(toks, 0, 1),          # [B, K]
            jnp.moveaxis(ids, 0, 2),           # [L, B, K, k]
            jnp.moveaxis(alpha, 0, 2),
            states,                            # stacked [K, B, ...] leaves
        )

    return unroll


def select_accepted_state(states, n_acc: Array, old=None):
    """Per-lane predictor rollback: from the unroll's stacked states
    ([K, B, ...] leaves) pick each lane's state after its last accepted
    input (stack index n_acc - 1). With `old`, lanes that accepted nothing
    (n_acc == 0 — the masked server's inactive lanes) keep their old state.
    Shared by the engine and the server so the rollback indexing cannot
    drift between them."""
    idx = jnp.maximum(n_acc - 1, 0)
    bidx = jnp.arange(n_acc.shape[0])
    if old is None:
        return jax.tree.map(lambda s: s[idx, bidx], states)

    def sel(stk, og):
        chosen = stk[idx, bidx]
        keep = (n_acc > 0).reshape(-1, *([1] * (og.ndim - 1)))
        return jnp.where(keep, chosen, og)

    return jax.tree.map(sel, states, old)


# ---------------------------------------------------------------------------
# decode engine
# ---------------------------------------------------------------------------


@dataclass
class DecodeMetrics:
    """Decode accounting that stays honest under speculation.

    `steps` counts verify blocks (jit dispatches), `tokens` counts tokens
    actually *emitted* (accepted) — never B · steps, which over-reports the
    moment a verify step can reject draft positions. `loads_per_step` is
    attributed per verify block (the k-position superset ticket loads once
    for the whole block). `proposed` counts positions verified, so
    `acceptance_rate == tokens / proposed` is 1.0 for the sync path by
    construction."""

    steps: int = 0                 # verify blocks (== tokens/B when sync)
    tokens: int = 0                # accepted tokens actually emitted
    proposed: int = 0              # positions verified (B·k per spec block)
    wall_s: float = 0.0
    stall_s: float = 0.0           # time blocked on async prefetch fences
    loads_per_step: List[int] = field(default_factory=list)
    accepted_per_step: List[float] = field(default_factory=list)  # mean n_acc/lane

    @property
    def tok_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0

    @property
    def acceptance_rate(self) -> float:
        return self.tokens / self.proposed if self.proposed else 0.0

    @property
    def mean_accepted(self) -> float:
        xs = self.accepted_per_step
        return float(np.mean(xs)) if xs else 0.0


class TableBuffer:
    """Reusable host backing store for per-step decode HashTables.

    The decode hot loop used to allocate two fresh [L, B, S, k] numpy arrays
    plus a HashTable per token; this keeps one persistent pair and copies
    the device predictions into it in place — the only per-step host work
    left is the unavoidable D2H of the prediction itself."""

    def __init__(self, L: int, B: int, S: int, k: int):
        self.ids = np.zeros((L, B, S, k), np.int32)
        self.weights = np.zeros((L, B, S, k), np.float32)
        self.table = HashTable(0, self.ids, self.weights)

    def fill(self, batch_index: int, ids_dev, alpha_dev) -> HashTable:
        """ids/alpha device arrays, [L, B, k] (S folded) or [L, B, S, k]."""
        self.table.batch_index = batch_index
        if ids_dev.ndim == 3:
            np.copyto(self.ids[:, :, 0, :], np.asarray(ids_dev))
            np.copyto(self.weights[:, :, 0, :], np.asarray(alpha_dev))
        else:
            np.copyto(self.ids, np.asarray(ids_dev))
            np.copyto(self.weights, np.asarray(alpha_dev))
        return self.table


class SiDADecodeEngine:
    """Token-by-token generation under an expert memory budget."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        hash_params: dict,
        slots_per_layer: int,
        serve_top_k: Optional[int] = None,
        ctx: ShardingCtx = ShardingCtx(),
        host_quant: str = "none",
        eviction: str = "fifo",
        store: Optional[ExpertStore] = None,
        prefetch_depth: Optional[int] = None,
        staging_buffers: Optional[int] = None,
        prefetcher: Optional[PrefetchPipeline] = None,
        quantized_slots: Optional[bool] = None,
        scale_granularity: Optional[str] = None,
        tier: Optional[TierConfig] = None,
        spec_mode: Optional[str] = None,   # "off" | "draft"; None => cfg.spec
        spec_k: Optional[int] = None,      # draft window; None => cfg.spec.k
        sharded: Optional[ShardedStoreConfig] = None,
    ):
        self.cfg = cfg
        self.ctx = ctx
        self.k = serve_top_k or cfg.moe.top_k
        self.hash_params = hash_params
        mode = spec_mode if spec_mode is not None else cfg.spec.mode
        assert mode in ("off", "draft"), mode
        self.spec_k = spec_k if spec_k is not None else cfg.spec.k
        self.spec = mode == "draft" and self.spec_k > 1
        if self.spec:
            assert "draft_proj" in hash_params, (
                "spec_mode='draft' needs a hash function with a draft head "
                "(init_hash_fn(draft=True) or init_draft_head)"
            )
        self.store = store if store is not None else ExpertStore(
            cfg, params, slots_per_layer, host_quant=host_quant, eviction=eviction,
            quantized_slots=quantized_slots, scale_granularity=scale_granularity,
            tier=tier, sharded=sharded, mesh=ctx.mesh,
        )
        self._owns_prefetcher = False
        if prefetcher is not None:
            self.prefetcher: Optional[PrefetchPipeline] = prefetcher
        else:
            self.prefetcher = PrefetchPipeline.maybe_create(
                self.store, cfg, prefetch_depth, staging_buffers
            )
            self._owns_prefetcher = self.prefetcher is not None
        self.embed_table = params["embed"]
        self.L = n_moe_layers(cfg)
        E = cfg.moe.num_experts

        @jax.jit
        def _predict_step(hp, embed_table, tokens, hstate):
            emb = jnp.take(embed_table, tokens, axis=0)
            logits, hstate = hash_fn_step(hp, emb, hstate, E)
            vals, ids = jax.lax.top_k(logits, self.k)         # [B, L, k]
            alpha = jax.nn.softmax(vals, axis=-1)
            return (
                jnp.moveaxis(ids, 1, 0).astype(jnp.int32),    # [L, B, k]
                jnp.moveaxis(alpha, 1, 0).astype(jnp.float32),
                hstate,
            )

        cfg_ = cfg
        ctx_ = ctx

        @jax.jit
        def _step(serve_params, cache, tokens, slot_ids, w):
            logits, cache = decode_step(
                serve_params, cache, tokens, cfg_, ctx_,
                routing_override=(slot_ids, w),
            )
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        @jax.jit
        def _verify(serve_params, cache, tokens_blk, slot_ids, w):
            out, n_acc, _, cache = verify_step(
                serve_params, cache, tokens_blk, cfg_, ctx_,
                routing_override=(slot_ids, w),
            )
            # next block starts from the last *accepted* model token
            nxt = jnp.take_along_axis(out, (n_acc - 1)[:, None], axis=1)[:, 0]
            return out, n_acc, nxt, cache

        self._predict_step = _predict_step
        self._step = _step
        self._draft_unroll = jax.jit(draft_unroll_fn(E, self.k, self.spec_k))
        self._verify = _verify
        self._roll_hstate = jax.jit(select_accepted_state)

    def _route_table(self, table: HashTable, m: DecodeMetrics):
        """Resolve residency for one decode table: async ticket (fence-only)
        or synchronous prepare. Returns (trans, ticket); loads/stall are
        attributed to the current verify block in `m`."""
        loads_before = self.store.stats.loads
        if self.prefetcher is not None:
            # per-lane decode predictions feed the transfer thread; the
            # step only clears ready fences for the experts it needs
            stall0 = self.prefetcher.stats.stall_s
            ticket = self.prefetcher.submit(table)
            ticket.wait()
            m.stall_s += self.prefetcher.stats.stall_s - stall0
            trans = ticket.trans
        else:
            ticket = None
            trans = self.store.prepare(table)
        m.loads_per_step.append(self.store.stats.loads - loads_before)
        return trans, ticket

    def _make_cache(self, B: int, cache_len: int, paged):
        """Ring cache, or a paged cache + its KVPagePool when a
        `residency.PagedKVConfig` is supplied. The pool shares the
        engine's prefetch pipeline so page-ins ride the same transfer
        queues/priorities as expert uploads."""
        if paged is None:
            return init_cache(self.cfg, B, cache_len), None
        from repro.core.residency import KVPagePool

        pool = KVPagePool(
            self.cfg, paged, B, eviction="alpha", pipeline=self.prefetcher
        )
        return pool.init_cache(), pool

    @staticmethod
    def _page_tick(pool, cache, upto: np.ndarray, extra_span: int = 0):
        """Pre-step paging: make each lane's positions resident up to
        `upto[b]`, clear fences, refresh the device table. In-span pages
        are pinned as they are ensured (lane N's alloc must never evict a
        page lane M's upcoming step reads); the caller unpins after the
        step."""
        for b in range(upto.shape[0]):
            cache = pool.ensure(
                cache, b, int(upto[b]), pin=True, extra_span=extra_span
            )
        cache = pool.sync(cache)
        cache["page_table"] = pool.device_table()
        return cache

    def generate(
        self,
        prompt_last_tokens: np.ndarray,
        steps: int,
        cache_len: int = 256,
        paged=None,   # residency.PagedKVConfig => page-table K/V residency
    ) -> Tuple[np.ndarray, DecodeMetrics]:
        """Greedy-decode `steps` tokens for a batch, starting from the given
        current tokens (fresh cache; prompts would be prefillled in prod).

        With speculation enabled (spec_mode="draft", spec_k > 1) each loop
        iteration verifies a k-token draft block in one jitted step; outputs
        are token-for-token identical to the sync path whenever every
        predicted expert is resident (see docs/ARCHITECTURE.md). With
        `paged`, the K/V cache lives in the shared page pool; greedy output
        is byte-identical to the ring path while every page stays resident
        (the paged-vs-ring differential in tests/test_paged_kv.py)."""
        if self.spec:
            return self._generate_spec(
                prompt_last_tokens, steps, cache_len, paged
            )
        B = prompt_last_tokens.shape[0]
        cache, pool = self._make_cache(B, cache_len, paged)
        hstate = hash_state_init(self.hash_params, B)
        tokens = jnp.asarray(prompt_last_tokens, jnp.int32)
        out = np.zeros((B, steps), np.int32)
        m = DecodeMetrics()
        tbuf = TableBuffer(self.L, B, 1, self.k)
        t0 = time.perf_counter()
        for i in range(steps):
            if pool is not None:
                cache = self._page_tick(
                    pool, cache, np.full((B,), i + 1, np.int64)
                )
            ids, alpha, hstate = self._predict_step(
                self.hash_params, self.embed_table, tokens, hstate
            )
            table = tbuf.fill(i, ids, alpha)
            trans, ticket = self._route_table(table, m)
            # translation runs on device straight off the still-resident
            # prediction (no per-step numpy slot gather / override upload)
            slot_ids, w = self.store.translate_device(
                ids[:, :, None, :], alpha[:, :, None, :], trans
            )
            tokens, cache = self._step(
                self.store.serve_params, cache, tokens,
                slot_ids[:, :, 0, :], w[:, :, 0, :],
            )
            out[:, i] = np.asarray(tokens)  # forces the step; slots consumed
            if pool is not None:
                pool.unpin_all()            # pinned by _page_tick
            if ticket is not None:
                ticket.release()
            m.steps += 1
            m.tokens += B                   # every position emitted == accepted
            m.proposed += B
            m.accepted_per_step.append(1.0)
        jax.block_until_ready(tokens)
        m.wall_s = time.perf_counter() - t0
        return out, m

    def _generate_spec(
        self,
        prompt_last_tokens: np.ndarray,
        steps: int,
        cache_len: int,
        paged=None,
    ) -> Tuple[np.ndarray, DecodeMetrics]:
        """Speculative decode: draft K tokens off the predictor's tied
        next-token head, prefetch the union of all K positions' predicted
        expert sets as ONE multi-token ticket (a strict superset of each
        per-step ticket -> deeper prefetch lookahead for free), verify the
        block in one jitted k-position `verify_step`, and keep per-lane
        accepted prefixes. Lanes advance at different rates; the loop ends
        when every lane has emitted `steps` tokens."""
        B = prompt_last_tokens.shape[0]
        K = self.spec_k
        cache, pool = self._make_cache(B, cache_len, paged)
        assert K <= (pool.paged.seq_len if pool is not None else cache_len), (
            K, cache_len
        )
        hstate = hash_state_init(self.hash_params, B)
        tokens = jnp.asarray(prompt_last_tokens, jnp.int32)
        out = np.zeros((B, steps), np.int32)
        filled = np.zeros((B,), np.int64)
        pos_np = np.zeros((B,), np.int64)   # per-lane cache position (paged)
        m = DecodeMetrics()
        tbuf = TableBuffer(self.L, B, K, self.k)
        t0 = time.perf_counter()
        while filled.min() < steps:
            if pool is not None:
                # verify writes the whole K-block before acceptance is known;
                # _page_tick pins the ensured pages so eviction can't race
                # the rollback. Clamp to the addressable range: a lane near
                # the edge drafts past it, but overflow writes route to the
                # trash page and the loop stops before accepting them
                cache = self._page_tick(
                    pool, cache, np.minimum(pos_np + K, pool.paged.seq_len),
                    extra_span=K - 1,
                )
            inputs, ids, alpha, states = self._draft_unroll(
                self.hash_params, self.embed_table, tokens, hstate
            )
            table = tbuf.fill(m.steps, ids, alpha)
            trans, ticket = self._route_table(table, m)
            slot_ids, w = self.store.translate_device(ids, alpha, trans)
            out_blk, n_acc, tokens, cache = self._verify(
                self.store.serve_params, cache, inputs,
                jnp.moveaxis(slot_ids, 2, 0), jnp.moveaxis(w, 2, 0),
            )
            hstate = self._roll_hstate(states, n_acc)
            out_np = np.asarray(out_blk)    # forces the step; slots consumed
            n_np = np.asarray(n_acc)
            if pool is not None:
                pool.unpin_all()
                pos_np += n_np
            if ticket is not None:
                ticket.release()
            delivered = 0
            for b in range(B):
                take = int(min(n_np[b], steps - filled[b]))
                out[b, filled[b] : filled[b] + take] = out_np[b, :take]
                filled[b] += take
                m.tokens += take
                delivered += take
            # delivered, not raw n_acc: a lane that hits its `steps` budget
            # mid-block drops the tail of its accepted prefix, and the
            # server-side accepted_per_step histogram truncates identically
            m.accepted_per_step.append(delivered / B)
            m.proposed += B * K
            m.steps += 1
        jax.block_until_ready(tokens)
        m.wall_s = time.perf_counter() - t0
        return out, m

    def close(self) -> None:
        """Join the async prefetch transfer thread (no-op when sync or when
        the pipeline is owned by the caller)."""
        if self.prefetcher is not None and self._owns_prefetcher:
            self.prefetcher.close()
