"""SiDA serving engine: hash-building thread ∥ inference thread (paper Fig. 5).

Workflow (Algorithm 1):
  Hash-building thread: for each incoming batch X_j, run the hash function,
  build hash table H_j (expert ids + α per token per MoE layer), enqueue.
  Inference thread: pop H_i, dynamically load predicted-activated experts /
  offload the rest (FIFO under the slot budget), forward X_i with the hash
  table as the routing override (routers never run).

Because the predictor is far cheaper than the model forward, the inference
thread never idles after the first batch — expert selection and offloading
costs are removed from the critical path, which is where the paper's
latency/throughput wins come from.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TierConfig
from repro.core.hash_fn import (
    HASH_SEG_LEN,
    hash_fn_apply,
    hash_fn_apply_segmented,
    predict_topk,
)
from repro.core.hash_table import HashTable, HashTableQueue
from repro.core.offload import (
    ExpertStore,
    PrefetchPipeline,
    PrefetchTicket,
    ShardedStoreConfig,
)
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, n_moe_layers


@dataclass
class ServeMetrics:
    latency_s: List[float] = field(default_factory=list)
    hash_time_s: float = 0.0
    tokens: int = 0
    wall_s: float = 0.0

    @property
    def throughput(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0

    @property
    def mean_latency(self) -> float:
        return float(np.mean(self.latency_s)) if self.latency_s else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "throughput_tok_s": self.throughput,
            "mean_latency_s": self.mean_latency,
            "hash_time_s": self.hash_time_s,
            "wall_s": self.wall_s,
        }


class SiDAEngine:
    """Serve full-sequence batches with data-aware expert offloading."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        hash_params: dict,
        slots_per_layer: int,
        serve_top_k: Optional[int] = None,
        ctx: ShardingCtx = ShardingCtx(),
        host_quant: str = "none",
        spill_dir: Optional[str] = None,
        eviction: str = "fifo",
        store: Optional[ExpertStore] = None,
        prefetch_depth: Optional[int] = None,
        staging_buffers: Optional[int] = None,
        prefetcher: Optional[PrefetchPipeline] = None,
        quantized_slots: Optional[bool] = None,
        scale_granularity: Optional[str] = None,
        tier: Optional[TierConfig] = None,
        sharded: Optional["ShardedStoreConfig"] = None,
    ):
        self.cfg = cfg
        self.ctx = ctx
        self.k = serve_top_k or cfg.moe.top_k
        self.hash_params = hash_params
        # a caller-supplied store lets prefill and decode engines share one
        # device slot cache (the request server runs both against it).
        # `sharded` partitions the slot pools expert-parallel over ctx's
        # mesh (see ShardedStoreConfig) — the forward then takes the
        # shard_map EP dispatch automatically.
        self.store = store if store is not None else ExpertStore(
            cfg, params, slots_per_layer,
            host_quant=host_quant, spill_dir=spill_dir, eviction=eviction,
            quantized_slots=quantized_slots, scale_granularity=scale_granularity,
            tier=tier, sharded=sharded, mesh=ctx.mesh,
        )
        # async prefetch: explicit args > cfg.prefetch knobs > off. A
        # caller-supplied pipeline (the request server's) is shared as-is.
        self._owns_prefetcher = False
        if prefetcher is not None:
            self.prefetcher: Optional[PrefetchPipeline] = prefetcher
        else:
            self.prefetcher = PrefetchPipeline.maybe_create(
                self.store, cfg, prefetch_depth, staging_buffers
            )
            self._owns_prefetcher = self.prefetcher is not None
        self.embed_table = params["embed"]
        self.L = n_moe_layers(cfg)

        E = cfg.moe.num_experts
        self.E = E

        @jax.jit
        def _predict(hp, embed_table, tokens):
            emb = jnp.take(embed_table, tokens, axis=0)
            logits = hash_fn_apply(hp, emb, num_experts=E)
            return predict_topk(logits, self.k)

        self._predict = _predict

        @jax.jit
        def _forward(serve_params, tokens, slot_ids, weights):
            out = forward(
                serve_params, cfg, ctx, tokens,
                routing_override=(slot_ids, weights),
            )
            return out["logits"]

        self._forward = _forward

        @jax.jit
        def _forward_kv(serve_params, tokens, slot_ids, weights):
            out = forward(
                serve_params, cfg, ctx, tokens,
                routing_override=(slot_ids, weights), collect_kv=True,
            )
            return out["logits"], out["kv"]

        self._forward_kv = _forward_kv

    # ------------------------------------------------------------------
    def build_table(self, batch_index: int, tokens: np.ndarray) -> HashTable:
        if tokens.shape[1] > HASH_SEG_LEN:
            # long-prompt admission (chunked prefill): the one-shot
            # predictor is O(S^2) in compute AND scores memory — take the
            # segmented build (exact LSTM threading, per-segment SparseMax)
            emb = jnp.take(self.embed_table, jnp.asarray(tokens), axis=0)
            logits = hash_fn_apply_segmented(self.hash_params, emb, self.E)
            ids, w = predict_topk(logits, self.k)
        else:
            ids, w = self._predict(self.hash_params, self.embed_table, tokens)
        return HashTable(batch_index, np.asarray(ids), np.asarray(w))

    def _route(self, table: HashTable, ticket: Optional[PrefetchTicket] = None):
        """Resolve the routing override for `table`: through the async
        pipeline (fence on ready events, never upload inline) when one is
        attached, synchronous prepare otherwise. Returns
        (slot_ids, weights, ticket) — the caller must `release()` a
        non-None ticket once the forward has consumed the slots."""
        if ticket is None and self.prefetcher is not None:
            ticket = self.prefetcher.submit(table)
        if ticket is not None:
            ticket.wait()
            slot_ids, w = self.store.translate(table, ticket.trans)
        else:
            trans = self.store.prepare(table)
            slot_ids, w = self.store.translate(table, trans)
        return slot_ids, w, ticket

    def infer(
        self, tokens: np.ndarray, table: HashTable,
        ticket: Optional[PrefetchTicket] = None,
    ) -> np.ndarray:
        slot_ids, w, ticket = self._route(table, ticket)
        logits = self._forward(
            self.store.serve_params, jnp.asarray(tokens),
            jnp.asarray(slot_ids), jnp.asarray(w),
        )
        if ticket is not None:
            # slots stay eviction-protected until the forward has read them
            jax.block_until_ready(logits)
            ticket.release()
        return logits

    def prefill(self, tokens: np.ndarray, table: HashTable,
                ticket: Optional[PrefetchTicket] = None):
        """Like `infer`, but also returns every layer's rope-applied K/V
        ({sub: (k, v)} each [G, B, S, K, D]) so the request server can seed
        decode-lane caches directly from the prefill forward. The server
        passes a pre-submitted `ticket` whose uploads it already overlapped
        against decode compute; otherwise one is submitted here."""
        slot_ids, w, ticket = self._route(table, ticket)
        out = self._forward_kv(
            self.store.serve_params, jnp.asarray(tokens),
            jnp.asarray(slot_ids), jnp.asarray(w),
        )
        if ticket is not None:
            jax.block_until_ready(out)
            ticket.release()
        return out

    # ------------------------------------------------------------------
    def _cache_affinity(self, table: HashTable) -> float:
        """Fraction of the table's active experts already resident or with
        an upload in flight (generalized onto ExpertStore so the request
        scheduler shares it)."""
        if self.prefetcher is not None:
            return self.prefetcher.cache_affinity(table)
        return self.store.cache_affinity(table)

    def serve(
        self, batches: Sequence[np.ndarray], threaded: bool = True,
        lookahead: int = 1,
    ) -> ServeMetrics:
        """Run the two-thread pipeline over `batches` of token ids [B, S].

        lookahead > 1 enables cache-aware scheduling (beyond paper): the
        inference thread buffers up to `lookahead` hash tables and serves
        the one whose predicted expert set overlaps the resident cache the
        most — fewer H2D loads under tight budgets, at bounded reordering.

        With an async prefetcher attached, the hash thread doubles as the
        prefetch producer: it submits each table's expert uploads the moment
        the table is built, so batch j+1's transfers overlap batch j's
        forward and the inference thread only clears ready fences.
        """
        metrics = ServeMetrics()
        q = HashTableQueue(maxsize=max(4, lookahead))
        results: List[Optional[np.ndarray]] = [None] * len(batches)
        # ticket handoff hash->inference thread; the queue put/get pair
        # orders the dict write before the read
        tickets: Dict[int, PrefetchTicket] = {}

        def hash_thread():
            for j, toks in enumerate(batches):
                t0 = time.perf_counter()
                table = self.build_table(j, toks)
                if self.prefetcher is not None:
                    tickets[j] = self.prefetcher.submit(table)
                q.put(table)
                metrics.hash_time_s += time.perf_counter() - t0
            q.close()

        def _run_one(table: HashTable):
            i = table.batch_index
            t0 = time.perf_counter()
            logits = self.infer(batches[i], table, ticket=tickets.pop(i, None))
            jax.block_until_ready(logits)
            metrics.latency_s.append(time.perf_counter() - t0)
            results[i] = np.asarray(logits)
            metrics.tokens += int(np.prod(batches[i].shape))

        def inference_thread():
            pool: List[HashTable] = []
            closed = False
            while True:
                while not closed and len(pool) < lookahead:
                    table = q.get()
                    if table is None:
                        closed = True
                        break
                    pool.append(table)
                    if lookahead == 1:
                        break
                if not pool:
                    if closed:
                        break
                    continue
                best = max(pool, key=self._cache_affinity) if len(pool) > 1 else pool[0]
                pool.remove(best)
                _run_one(best)

        t_start = time.perf_counter()
        if threaded:
            ht = threading.Thread(target=hash_thread)
            it = threading.Thread(target=inference_thread)
            ht.start(); it.start()
            ht.join(); it.join()
        else:  # sequential ablation: hash + prepare + forward serialised
            for j, toks in enumerate(batches):
                t0 = time.perf_counter()
                table = self.build_table(j, toks)
                logits = self.infer(toks, table)
                jax.block_until_ready(logits)
                metrics.latency_s.append(time.perf_counter() - t0)
                results[j] = np.asarray(logits)
                metrics.tokens += int(np.prod(toks.shape))
        metrics.wall_s = time.perf_counter() - t_start
        self.results = results
        return metrics

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Join the async prefetch transfer thread (no-op when sync or when
        the pipeline is owned by the caller, e.g. the request server)."""
        if self.prefetcher is not None and self._owns_prefetcher:
            self.prefetcher.close()

    # ------------------------------------------------------------------
    def device_memory_bytes(self) -> int:
        """Device-resident bytes: non-expert params + slot buffers."""
        non_expert = sum(
            x.nbytes for x in jax.tree.leaves(self.store.serve_params)
        ) - self.store.device_bytes()
        return non_expert + self.store.device_bytes()

    def memory_saving(self) -> Dict[str, float]:
        """The paper's Fig. 8 metric: expert bytes saved vs full residency."""
        full = self.store.full_expert_bytes()
        resident = self.store.device_bytes()
        return {
            "full_expert_gb": full / 1e9,
            "resident_expert_gb": resident / 1e9,
            "reduction": 1.0 - resident / full if full else 0.0,
        }
