"""Deterministic, seeded fault injection for the serving stack.

The async pipeline (hash-ahead prediction -> prefetch upload -> fenced
decode) has exactly four places a production deployment sees fail: the H2D
copy itself, the link stalling, the host master read, and the transfer
thread dying. A `FaultPlan` schedules any of those at precise points —
"the 3rd upload", "every upload with probability 0.2 under seed 7" — so a
test, the chaos CI step, and `bench_serving --fault-plan` all drive the
byte-identical scenario and the supervision machinery
(retry/backoff -> fence poisoning -> degraded sync fallback, see
core/offload.py) can be exercised deterministically.

Plan grammar (`;`-separated specs):

    site:kind[=delay_s][@nth[xtimes]][,p=prob]

    upload:fail@3          the 3rd upload batch raises InjectedFault once
    upload:fail@3x2        upload batches 3 and 4 raise
    upload:fail,p=0.2      each upload batch raises with probability 0.2
    upload:stall=0.05,p=.1 10% of upload batches sleep 50 ms first
    host_read:fail@1       the 1st host-master gather raises
    thread:crash@2         the 2nd transfer-loop iteration raises (kills
                           the shard thread; the supervisor restarts it)
    hash:fail@1            the 1st hash-ahead admission raises (the hash
                           thread rejects that request and continues)

Sites are just strings; the injection points name them (grep for
`inject(`). Counters are per-site and the probabilistic draw uses one RNG
per site seeded from (seed, site), so adding a spec for one site never
perturbs another site's schedule. With a single transfer thread per shard
the per-site operation order — and therefore an `@nth` schedule — is fully
deterministic; under multiple shards the @nth match lands on whichever
shard reaches the counter first (use `p=` for multi-shard plans).
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = ["FaultSpec", "FaultPlan", "InjectedFault"]

# the sites the serving stack currently instruments (a plan may name others;
# they simply never match — this list is for the launcher's validation)
KNOWN_SITES = ("upload", "host_read", "thread", "hash")
KNOWN_KINDS = ("fail", "stall", "crash")


class InjectedFault(RuntimeError):
    """Raised at an injection point. Deliberately a plain RuntimeError
    subclass: the supervision code must treat it exactly like a real
    transfer/read error (no special-casing), or the chaos suite would be
    testing a path production errors never take."""

    def __init__(self, site: str, n: int):
        super().__init__(f"injected fault at {site} (operation #{n})")
        self.site = site
        self.n = n


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire `kind` at `site` on operations
    [nth, nth+times) and/or with probability `p` on every operation."""

    site: str
    kind: str = "fail"            # "fail" | "stall" | "crash"
    delay_s: float = 0.0          # stall duration (kind == "stall")
    nth: int = 0                  # 1-based op index; 0 = probabilistic only
    times: int = 1                # consecutive ops faulted from nth
    p: float = 0.0                # per-op probability (seeded RNG)

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        text = text.strip()
        head, *mods = text.split(",")
        if ":" not in head:
            raise ValueError(f"fault spec {text!r}: expected site:kind")
        site, kind = head.split(":", 1)
        nth, times = 0, 1
        if "@" in kind:
            kind, sched = kind.split("@", 1)
            if "x" in sched:
                n_s, t_s = sched.split("x", 1)
                nth, times = int(n_s), int(t_s)
            else:
                nth = int(sched)
            if nth < 1 or times < 1:
                raise ValueError(f"fault spec {text!r}: @nth/xtimes must be >= 1")
        delay = 0.0
        if "=" in kind:
            kind, d_s = kind.split("=", 1)
            delay = float(d_s)
        p = 0.0
        for m in mods:
            k, _, v = m.strip().partition("=")
            if k != "p" or not v:
                raise ValueError(f"fault spec {text!r}: unknown modifier {m!r}")
            p = float(v)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"fault spec {text!r}: p must be in [0, 1]")
        if kind not in KNOWN_KINDS:
            raise ValueError(
                f"fault spec {text!r}: kind {kind!r} not in {KNOWN_KINDS}"
            )
        if kind == "stall" and delay <= 0.0:
            raise ValueError(f"fault spec {text!r}: stall needs =delay_s > 0")
        if nth == 0 and p == 0.0:
            raise ValueError(
                f"fault spec {text!r}: needs @nth scheduling and/or p=prob"
            )
        return cls(site=site.strip(), kind=kind, delay_s=delay,
                   nth=nth, times=times, p=p)


@dataclass
class FaultPlan:
    """Thread-safe registry of scheduled faults, keyed by site.

    `inject(site)` counts one operation at `site`, then fires the first
    matching spec: a stall sleeps `delay_s` and returns; fail/crash raise
    `InjectedFault`. Everything is deterministic under a fixed seed and
    per-site operation order."""

    specs: List[FaultSpec] = field(default_factory=list)
    seed: int = 0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        self._ops: Dict[str, int] = {}
        self._fired: Dict[str, int] = {}
        self._rng: Dict[str, random.Random] = {}

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        specs = [FaultSpec.parse(s) for s in text.split(";") if s.strip()]
        return cls(specs=specs, seed=seed)

    def _site_rng(self, site: str) -> random.Random:
        rng = self._rng.get(site)
        if rng is None:
            # a str seed hashes via sha512 (deterministic regardless of
            # PYTHONHASHSEED); a tuple would go through hash() and vary
            rng = self._rng[site] = random.Random(f"{self.seed}:{site}")
        return rng

    def fire(self, site: str) -> Optional[FaultSpec]:
        """Count one operation at `site`; return the spec that fires on it
        (first match wins), or None. Pure scheduling — no sleep, no raise."""
        with self._lock:
            n = self._ops[site] = self._ops.get(site, 0) + 1
            for spec in self.specs:
                if spec.site != site:
                    continue
                hit = spec.nth > 0 and spec.nth <= n < spec.nth + spec.times
                if not hit and spec.p > 0.0:
                    hit = self._site_rng(site).random() < spec.p
                if hit:
                    self._fired[site] = self._fired.get(site, 0) + 1
                    return spec
        return None

    def inject(self, site: str) -> None:
        """The injection-point call: fire the schedule for one operation at
        `site`, sleeping for stalls and raising `InjectedFault` for
        fail/crash. A site with no matching spec costs one dict lookup."""
        spec = self.fire(site)
        if spec is None:
            return
        if spec.kind == "stall":
            time.sleep(spec.delay_s)
            return
        raise InjectedFault(site, self._ops[site])

    # -- introspection (tests and the chaos bench read these) -----------
    def ops(self, site: str) -> int:
        """Operations counted at `site` so far."""
        return self._ops.get(site, 0)

    def fired(self, site: str) -> int:
        """Faults fired at `site` so far (stalls included)."""
        return self._fired.get(site, 0)

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for site in sorted(set(self._ops) | set(self._fired)):
            out[f"fault_ops_{site}"] = float(self._ops.get(site, 0))
            out[f"fault_fired_{site}"] = float(self._fired.get(site, 0))
        return out
