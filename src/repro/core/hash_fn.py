"""The SiDA hash function: a 2-layer LSTM with SparseMax attention.

Architecture (paper §3.4.2):
  compress FC (d_model -> d_h)
  2-layer LSTM (captures sequential information, lightweight)
  self-attention over LSTM outputs with **SparseMax** weights (sparse
  cross-embedding dependency: ĉ ∈ [1,4] critical tokens — §3.4.1)
  residual connection from the current token's features
  per-MoE-layer linear heads -> expert logits [L_moe, E]

The predictor runs in the hash-building thread, independent of model
inference, and its argmax/top-k + softmax-α outputs populate the HashTable.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init

Array = jax.Array


# ---------------------------------------------------------------------------
# SparseMax (Martins & Astudillo, 2016) — pure-jnp reference; the Pallas
# kernel in repro/kernels/sparsemax.py mirrors this.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _sparsemax_last(z: Array) -> Array:
    K = z.shape[-1]
    z_sorted = jnp.sort(z, axis=-1)[..., ::-1]
    z_cum = jnp.cumsum(z_sorted, axis=-1)
    ks = jnp.arange(1, K + 1, dtype=z.dtype)
    support = z_sorted * ks > (z_cum - 1.0)
    k_z = jnp.sum(support, axis=-1, keepdims=True).astype(z.dtype)
    # support set is a prefix of the sorted sequence (gather-free sum)
    sum_support = jnp.sum(z_sorted * support, axis=-1, keepdims=True)
    tau = (sum_support - 1.0) / k_z
    return jnp.maximum(z - tau, 0.0)


def _sparsemax_fwd(z):
    out = _sparsemax_last(z)
    return out, out


def _sparsemax_bwd(out, g):
    # Jacobian of the simplex projection: J = diag(s) - s s^T / |S|
    # with s the support indicator (Martins & Astudillo, Prop. 2).
    s = (out > 0).astype(g.dtype)
    k = jnp.maximum(jnp.sum(s, axis=-1, keepdims=True), 1.0)
    v = jnp.sum(g * s, axis=-1, keepdims=True) / k
    return ((g - v) * s,)


_sparsemax_last.defvjp(_sparsemax_fwd, _sparsemax_bwd)


def sparsemax(z: Array, axis: int = -1) -> Array:
    """Euclidean projection of z onto the probability simplex (exact VJP)."""
    z = jnp.moveaxis(z, axis, -1)
    out = _sparsemax_last(z)
    return jnp.moveaxis(out, -1, axis)


# ---------------------------------------------------------------------------
# LSTM
# ---------------------------------------------------------------------------


def _init_lstm_layer(key, d_in: int, d_h: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "wx": dense_init(k1, d_in, 4 * d_h, jnp.float32),
        "wh": dense_init(k2, d_h, 4 * d_h, jnp.float32),
        "b": jnp.zeros((4 * d_h,), jnp.float32).at[d_h : 2 * d_h].set(1.0),  # forget bias
    }


def _lstm_layer(
    p: dict, x: Array, carry: Optional[Tuple[Array, Array]] = None
) -> Tuple[Array, Tuple[Array, Array]]:
    """x: [B, S, d_in] -> ([B, S, d_h], final (h, c)).

    `carry` resumes from a previous call's final (h, c) — the segmented
    long-prompt path threads it across segments, so the recurrent half of
    the predictor sees the whole sequence regardless of segmentation."""
    B, S, _ = x.shape
    d_h = p["wh"].shape[0]
    xg = x @ p["wx"] + p["b"]

    def step(carry, xg_t):
        h, c = carry
        g = xg_t + h @ p["wh"]
        i, f, gg, o = jnp.split(g, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(gg)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    if carry is None:
        h0 = jnp.zeros((B, d_h), x.dtype)
        carry = (h0, h0)
    carry, hs = jax.lax.scan(step, carry, xg.swapaxes(0, 1))
    return hs.swapaxes(0, 1), carry


# ---------------------------------------------------------------------------
# hash function
# ---------------------------------------------------------------------------


def init_hash_fn(
    key, d_model: int, n_moe_layers: int, num_experts: int, d_h: int = 256,
    draft: bool = False,
) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "compress": dense_init(ks[0], d_model, d_h, jnp.float32),
        "lstm1": _init_lstm_layer(ks[1], d_h, d_h),
        "lstm2": _init_lstm_layer(ks[2], d_h, d_h),
        "attn_q": dense_init(ks[3], d_h, d_h, jnp.float32),
        "heads": dense_init(ks[4], d_h, n_moe_layers * num_experts, jnp.float32),
    }
    if draft:
        # tied-embedding next-token draft head (speculative decode): the same
        # predictor state z that feeds the per-layer router heads projects
        # back to d_model and reads token logits off the model's embedding
        # table — no separate vocab matrix, so the head stays tiny (d_h·d)
        p["draft_proj"] = dense_init(ks[5], d_h, d_model, jnp.float32)
    return p


def init_draft_head(key, params: dict, d_model: int) -> dict:
    """Attach a tied-embedding draft head to an existing (trained) hash fn —
    lets cached predictor checkpoints gain speculative decode without
    retraining the router heads."""
    d_h = params["attn_q"].shape[0]
    return {**params, "draft_proj": dense_init(key, d_h, d_model, jnp.float32)}


def draft_logits_from_state(params: dict, z: Array, embed_table: Array) -> Array:
    """z [..., d_h] predictor state -> next-token logits [..., V] through the
    tied embedding (z @ draft_proj gives a d_model query; the embedding table
    is the output matrix, exactly like a tied-softmax LM head)."""
    q = z @ params["draft_proj"]                          # [..., d_model]
    return q @ embed_table.astype(jnp.float32).T


def hash_fn_apply(params: dict, emb: Array, num_experts: int,
                  use_pallas: bool = False, causal: bool = False,
                  embed_table: Optional[Array] = None):
    """emb: [B, S, d_model] token embeddings -> logits [B, S, L_moe, E].

    causal=True masks the SparseMax attention to the past — train with it
    when the predictor will run incrementally at decode time
    (core/decode_engine.py); the default bidirectional form is the paper's
    full-batch look-ahead setting.

    With `embed_table` (and a draft head in `params`) additionally returns
    tied-embedding next-token draft logits [B, S, V] — the full-sequence
    (training) view of what `hash_fn_step` emits incrementally at decode.
    """
    E = num_experts
    L = params["heads"].shape[-1] // E
    x = jnp.tanh(emb.astype(jnp.float32) @ params["compress"])   # [B,S,dh]
    h, _ = _lstm_layer(params["lstm1"], x)
    h, _ = _lstm_layer(params["lstm2"], h)
    # sparse attention: q=k=v=h (paper: all set to LSTM output sequence)
    q = h @ params["attn_q"]
    scores = jnp.einsum("bqd,bkd->bqk", q, h) / math.sqrt(h.shape[-1])
    if causal:
        S = scores.shape[-1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask[None], scores, -1e30)
    if use_pallas:
        from repro.kernels.ops import sparsemax as sm_op

        w = sm_op(scores)
    else:
        w = sparsemax(scores, axis=-1)
    a = jnp.einsum("bqk,bkd->bqd", w, h)
    # residual: the current token is always the most crucial (paper §3.4.2)
    z = a + h
    logits = z @ params["heads"]
    logits = logits.reshape(*emb.shape[:2], L, E)
    if embed_table is not None and "draft_proj" in params:
        return logits, draft_logits_from_state(params, z, embed_table)
    return logits


# Default attention span for the segmented long-prompt path. Prompts at or
# below this length take the one-shot O(S^2) build, so every pre-existing
# consumer (buckets top out well under 1k) is bit-identical.
HASH_SEG_LEN = 1024


@jax.jit
def _hash_segment(params: dict, emb_seg: Array, c1, c2):
    """One segment of the long-prompt predictor: same math as
    hash_fn_apply, but the LSTM starts from the previous segment's carries
    and the SparseMax attention sees this segment only. Returns
    (z [B,T,dh], new c1, new c2) — callers project z through whatever
    heads they need."""
    x = jnp.tanh(emb_seg.astype(jnp.float32) @ params["compress"])
    h, c1 = _lstm_layer(params["lstm1"], x, c1)
    h, c2 = _lstm_layer(params["lstm2"], h, c2)
    q = h @ params["attn_q"]
    scores = jnp.einsum("bqd,bkd->bqk", q, h) / math.sqrt(h.shape[-1])
    w = sparsemax(scores, axis=-1)
    a = jnp.einsum("bqk,bkd->bqd", w, h)
    return a + h, c1, c2


def hash_fn_apply_segmented(
    params: dict, emb: Array, num_experts: int, seg_len: int = HASH_SEG_LEN
) -> Array:
    """Long-prompt variant of `hash_fn_apply`: O(S·seg_len) instead of
    O(S^2) compute and scores memory (a 32k prompt one-shot would build a
    [S, S] SparseMax score matrix — 4 GB — and dominates admission time
    quadratically).

    The LSTM carries thread across segment boundaries, so the recurrent
    half of the predictor is EXACT over the full sequence; the SparseMax
    attention is restricted to each `seg_len` segment. That mirrors the
    decode-time predictor, whose attention already reads a bounded
    HISTORY-slot ring (core/decode_engine.py) — the paper's sparse
    cross-embedding dependency (c-hat ∈ [1,4] critical tokens, §3.4.1) is
    what makes a bounded attention context faithful. For S <= seg_len the
    result is identical to `hash_fn_apply`.
    """
    E = num_experts
    L = params["heads"].shape[-1] // E
    B, S, _ = emb.shape
    d_h = params["attn_q"].shape[0]
    zeros = jnp.zeros((B, d_h), jnp.float32)
    c1, c2 = (zeros, zeros), (zeros, zeros)
    outs = []
    for s0 in range(0, S, seg_len):
        z, c1, c2 = _hash_segment(params, emb[:, s0:s0 + seg_len], c1, c2)
        outs.append(z @ params["heads"])
    logits = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    return logits.reshape(B, S, L, E)


def hash_fn_param_count(params: dict) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def predict_topk(
    logits: Array, k: int
) -> Tuple[Array, Array]:
    """logits [B,S,L,E] -> (ids [L,B,S,k], α [L,B,S,k]).

    α approximates the router's softmax scaling factor (Eq. 1), renormalised
    over the predicted top-k — exactly how SiDA consumes the hash table.
    """
    vals, ids = jax.lax.top_k(logits, k)                  # [B,S,L,k]
    alpha = jax.nn.softmax(vals, axis=-1)
    ids = jnp.moveaxis(ids, 2, 0)                         # [L,B,S,k]
    alpha = jnp.moveaxis(alpha, 2, 0)
    return ids.astype(jnp.int32), alpha.astype(jnp.float32)


def hash_hit_rate(
    pred_logits: Array, teacher_ids: Array, top: int = 3
) -> Array:
    """Top-`top` hit rate (Table 5): is the teacher's expert among our top-k?

    pred_logits: [B,S,L,E]; teacher_ids: [L,B,S] (the router's argmax).
    """
    _, pred = jax.lax.top_k(pred_logits, top)             # [B,S,L,top]
    pred = jnp.moveaxis(pred, 2, 0)                       # [L,B,S,top]
    hit = (pred == teacher_ids[..., None]).any(-1)
    return hit.mean()
