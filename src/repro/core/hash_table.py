"""Hash tables: the unit of work the hash-building thread produces.

A `HashTable` stores, for one batch, the predicted expert activation for
every token at every MoE layer plus the scaling factors α (Eq. 1 of the
paper). The inference thread consumes tables from a FIFO `queue.Queue`
(the "hash table queue" of Fig. 5).
"""
from __future__ import annotations

import queue
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class HashTable:
    """Expert activation plan for one batch.

    expert_ids: [L_moe, B, S, k] int32 — predicted experts per token/layer
    weights:    [L_moe, B, S, k] float32 — predicted scaling factors α
    """

    batch_index: int
    expert_ids: np.ndarray
    weights: np.ndarray

    @property
    def n_moe_layers(self) -> int:
        return self.expert_ids.shape[0]

    def active_experts(self, layer: int) -> np.ndarray:
        """Unique experts predicted to activate at `layer`, most-used first."""
        ids, counts = np.unique(self.expert_ids[layer], return_counts=True)
        return ids[np.argsort(-counts)].astype(np.int32)

    def activation_mass(self, layer: int, num_experts: int) -> np.ndarray:
        """Total α mass routed to each expert at `layer` — used to pick which
        experts to keep when the slot budget is tighter than the active set."""
        mass = np.zeros((num_experts,), np.float64)
        np.add.at(mass, self.expert_ids[layer].reshape(-1), self.weights[layer].reshape(-1))
        return mass

    def activation_stats(self, num_experts: int) -> Dict[str, float]:
        act = [len(self.active_experts(l)) for l in range(self.n_moe_layers)]
        return {
            "mean_active": float(np.mean(act)),
            "max_active": float(np.max(act)),
            "idle_ratio": 1.0 - float(np.mean(act)) / num_experts,
        }


class HashTableQueue:
    """FIFO queue between the hash-building and inference threads."""

    def __init__(self, maxsize: int = 8):
        self._q: "queue.Queue[Optional[HashTable]]" = queue.Queue(maxsize=maxsize)

    def put(self, table: Optional[HashTable]) -> None:
        self._q.put(table)

    def get(self, timeout: Optional[float] = None) -> Optional[HashTable]:
        return self._q.get(timeout=timeout)

    def close(self) -> None:
        self._q.put(None)
