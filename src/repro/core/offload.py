"""Expert offloading: host-resident expert store + device slot cache.

This is the TPU-native adaptation of SiDA's CPU↔GPU expert offloading
(DESIGN.md §2). The full expert stacks live in host memory (numpy). On
device, each MoE layer owns a fixed pool of `slots` (static shape
[G, S, d, f] so jit never retraces). `prepare(hash_table)` loads exactly the
experts the hash function predicts will activate — FIFO-evicting under the
memory budget — and produces per-layer expert→slot translation tables so the
routing override can address slots directly.

Routers are offloaded entirely: the serving params pytree contains no router
matrix (the hash table replaces it — paper §3.1 "all routers are offloaded
to the main memory and do not participate in the forward pass").
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.hash_table import HashTable
from repro.models.transformer import n_moe_layers, period, sub_kind

Array = jax.Array

EXPERT_TENSORS = ("w_in", "w_gate", "w_out")


@partial(jax.jit, donate_argnums=(0,))
def _slot_write(buf: Array, g: Array, slots: Array, w: Array) -> Array:
    """buf [G,S,...] <- w [n,...] at (g[n], slots[n]); donated => in-place."""
    return buf.at[g, slots].set(w)


@partial(jax.jit, donate_argnums=(0,))
def _slot_write_q(buf: Array, g: Array, slots: Array, q: Array, scale: Array) -> Array:
    """int8 variant: dequantisation happens ON DEVICE, so the host->device
    transfer moves int8 + per-channel scales (2x fewer bytes than bf16,
    4x fewer than f32) — SiDA's critical path is exactly these transfers."""
    w = (q.astype(jnp.float32) * scale).astype(buf.dtype)
    return buf.at[g, slots].set(w)


def quantize_expert(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-output-channel int8 quantisation. w: [..., d_in, d_out]."""
    absmax = np.abs(w).max(axis=-2, keepdims=True).astype(np.float32)
    scale = np.maximum(absmax, 1e-8) / 127.0
    q = np.clip(np.round(w.astype(np.float32) / scale), -127, 127).astype(np.int8)
    return q, scale


class EvictionPolicy:
    """Replacement policy for one (group, sub) slot pool.

    The store calls `admit` when an expert is loaded, `touch` on every
    reference (hit or load, with the α mass it carried), `pick_victim`
    when a slot must be reclaimed, passing the experts that must survive
    (currently-needed + pinned). Returns None when every resident expert
    is protected — the caller then drops the load instead of evicting.
    """

    name = "base"

    def admit(self, e: int, weight: float = 0.0) -> None:
        raise NotImplementedError

    def touch(self, e: int, weight: float = 0.0) -> None:
        pass

    def pick_victim(self, protected) -> Optional[int]:
        raise NotImplementedError


class FIFOPolicy(EvictionPolicy):
    """Evict in insertion order (the paper's serving loop assumption)."""

    name = "fifo"

    def __init__(self):
        self.order: collections.deque = collections.deque()

    def admit(self, e: int, weight: float = 0.0) -> None:
        self.order.append(e)

    def pick_victim(self, protected) -> Optional[int]:
        for _ in range(len(self.order)):
            victim = self.order.popleft()
            if victim in protected:
                self.order.append(victim)  # recycle, try next
                continue
            return victim
        return None


class LRUPolicy(EvictionPolicy):
    """Evict the least-recently referenced expert — request-interleaved
    traffic revisits hot experts out of FIFO order, where pure insertion
    order evicts exactly the experts about to be reused."""

    name = "lru"

    def __init__(self):
        self.order: "collections.OrderedDict[int, None]" = collections.OrderedDict()

    def admit(self, e: int, weight: float = 0.0) -> None:
        self.order[e] = None
        self.order.move_to_end(e)

    def touch(self, e: int, weight: float = 0.0) -> None:
        if e in self.order:
            self.order.move_to_end(e)

    def pick_victim(self, protected) -> Optional[int]:
        for victim in self.order:
            if victim not in protected:
                del self.order[victim]
                return victim
        return None


class AlphaMassPolicy(EvictionPolicy):
    """Evict the expert with the least decayed α mass: the hash table gives
    the routing weight every token sends to each expert, so the cache can
    rank residency by how much computation an expert actually absorbs
    rather than by arrival order."""

    name = "alpha"

    def __init__(self, decay: float = 0.9):
        self.decay = decay
        self.score: Dict[int, float] = {}

    def admit(self, e: int, weight: float = 0.0) -> None:
        self.score[e] = self.score.get(e, 0.0) + max(weight, 1e-6)

    def touch(self, e: int, weight: float = 0.0) -> None:
        if e in self.score:
            self.score[e] = self.decay * self.score[e] + weight

    def pick_victim(self, protected) -> Optional[int]:
        best, best_s = None, None
        for e, sc in self.score.items():
            if e in protected:
                continue
            if best_s is None or sc < best_s:
                best, best_s = e, sc
        if best is not None:
            del self.score[best]
        return best


EVICTION_POLICIES = {
    "fifo": FIFOPolicy,
    "lru": LRUPolicy,
    "alpha": AlphaMassPolicy,
}


@dataclass
class TransferStats:
    bytes_h2d: int = 0
    loads: int = 0
    evictions: int = 0
    hits: int = 0
    prepare_time: float = 0.0

    def reset(self):
        self.bytes_h2d = self.loads = self.evictions = self.hits = 0
        self.prepare_time = 0.0


class ExpertStore:
    """Host store + device slot cache for every MoE layer of a model.

    host_quant="int8" stores experts quantised on host and dequantises on
    device at load (beyond-paper; the paper notes quantisation is orthogonal
    — here it composes directly with the offloading path, halving H2D
    bytes vs bf16). spill_dir enables the paper's §6 hierarchical tier:
    host arrays live in disk-backed memmaps instead of RAM.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        slots_per_layer: int,
        host_quant: str = "none",      # "none" | "int8"
        spill_dir: Optional[str] = None,
        eviction: str = "fifo",        # "fifo" | "lru" | "alpha"
    ):
        assert cfg.moe.enabled, "ExpertStore requires an MoE config"
        assert eviction in EVICTION_POLICIES, eviction
        self.cfg = cfg
        self.per = period(cfg)
        self.n_groups = cfg.n_layers // self.per
        self.moe_subs = [s for s in range(self.per) if sub_kind(cfg, s).get("moe")]
        self.L = n_moe_layers(cfg)
        self.E = cfg.moe.num_experts
        self.S = min(slots_per_layer, self.E)
        self.quant = host_quant
        self.stats = TransferStats()

        def _spill(name: str, arr: np.ndarray) -> np.ndarray:
            if spill_dir is None:
                return arr
            import os

            os.makedirs(spill_dir, exist_ok=True)
            path = os.path.join(spill_dir, f"{name}.npy")
            mm = np.lib.format.open_memmap(path, mode="w+", dtype=arr.dtype,
                                           shape=arr.shape)
            mm[...] = arr
            mm.flush()
            return np.lib.format.open_memmap(path, mode="r")

        # --- split params: experts + routers -> host; rest stays on device
        self.host: Dict[str, Dict[str, np.ndarray]] = {}
        self.host_scale: Dict[str, Dict[str, np.ndarray]] = {}
        serve_params = jax.tree.map(lambda x: x, params)  # shallow-ish copy
        for s in self.moe_subs:
            moe_p = serve_params["blocks"][f"sub{s}"]["moe"]
            self.host[f"sub{s}"] = {}
            self.host_scale[f"sub{s}"] = {}
            for t in EXPERT_TENSORS:
                w = np.asarray(moe_p[t])
                if host_quant == "int8":
                    q, scale = quantize_expert(w)
                    self.host[f"sub{s}"][t] = _spill(f"sub{s}_{t}", q)
                    self.host_scale[f"sub{s}"][t] = scale
                else:
                    self.host[f"sub{s}"][t] = _spill(f"sub{s}_{t}", w)
            for t in EXPERT_TENSORS:
                full = moe_p[t]
                G, E = full.shape[:2]
                moe_p[t] = jnp.zeros((G, self.S, *full.shape[2:]), full.dtype)
            moe_p.pop("router", None)  # routers never participate in forward
        self.serve_params = serve_params

        # --- cache state per (group, sub): expert->slot + eviction policy
        self.eviction = eviction
        self.resident: Dict[Tuple[int, int], Dict[int, int]] = {}
        self.policy: Dict[Tuple[int, int], EvictionPolicy] = {}
        self.free: Dict[Tuple[int, int], List[int]] = {}
        self.pinned: Dict[Tuple[int, int], set] = {}
        for g in range(self.n_groups):
            for s in self.moe_subs:
                self.resident[(g, s)] = {}
                self.policy[(g, s)] = EVICTION_POLICIES[eviction]()
                self.free[(g, s)] = list(range(self.S))
                self.pinned[(g, s)] = set()

    # -- layer indexing: moe layer l = g * len(moe_subs) + j ----------------
    def layer_to_gs(self, l: int) -> Tuple[int, int]:
        j = l % len(self.moe_subs)
        return l // len(self.moe_subs), self.moe_subs[j]

    # ------------------------------------------------------------------
    def device_bytes(self) -> int:
        """Bytes of expert weights resident on device (the paper's metric)."""
        tot = 0
        for s in self.moe_subs:
            for t in EXPERT_TENSORS:
                tot += self.serve_params["blocks"][f"sub{s}"]["moe"][t].nbytes
        return tot

    def full_expert_bytes(self) -> int:
        return sum(
            arr.nbytes for sub in self.host.values() for arr in sub.values()
        )

    # ------------------------------------------------------------------
    def pin_experts(self, l: int, experts) -> None:
        """Mark experts at MoE layer `l` as never-evictable (hot experts a
        deployment wants permanently resident). Pinned experts still load
        through the normal prepare path; they just cannot be victims."""
        g, s = self.layer_to_gs(l)
        self.pinned[(g, s)].update(int(e) for e in experts)

    def unpin_experts(self, l: int, experts) -> None:
        g, s = self.layer_to_gs(l)
        self.pinned[(g, s)].difference_update(int(e) for e in experts)

    def plan_layer(
        self, l: int, needed: np.ndarray, mass: Optional[np.ndarray] = None
    ) -> List[Tuple[int, int, int]]:
        """Cache bookkeeping for one layer; returns pending (g, slot, e) loads.

        `mass` (optional, [E]) is the α mass the current hash table routes to
        each expert — fed to the eviction policy so α-weighted replacement
        can rank residency by absorbed computation.
        """
        g, s = self.layer_to_gs(l)
        res = self.resident[(g, s)]
        policy = self.policy[(g, s)]
        free = self.free[(g, s)]
        needed_set = set(int(e) for e in needed)
        protected = needed_set | self.pinned[(g, s)]
        pending: List[Tuple[int, int, int]] = []
        for e in needed:
            e = int(e)
            w = float(mass[e]) if mass is not None else 0.0
            if e in res:
                self.stats.hits += 1
                policy.touch(e, w)
                continue
            if free:
                slot = free.pop()
            else:
                # evict per policy — never an expert needed right now or pinned
                victim = policy.pick_victim(protected)
                if victim is None:  # everything resident is protected => drop
                    continue
                slot = res.pop(victim)
                self.stats.evictions += 1
            res[e] = slot
            policy.admit(e, w)
            pending.append((g, slot, e))
            self.stats.loads += 1
        return pending

    def commit_loads(self, s: int, items: List[Tuple[int, int, int]]) -> None:
        """Batched host->device writes for sub-slot `s` (one per tensor)."""
        if not items:
            return
        gs = np.array([i[0] for i in items], np.int32)
        sl = np.array([i[1] for i in items], np.int32)
        es = np.array([i[2] for i in items], np.int32)
        moe_p = self.serve_params["blocks"][f"sub{s}"]["moe"]
        for t in EXPERT_TENSORS:
            w_host = self.host[f"sub{s}"][t][gs, es]              # [n, d, f]
            if self.quant == "int8":
                scale = self.host_scale[f"sub{s}"][t][gs, es]
                self.stats.bytes_h2d += w_host.nbytes + scale.nbytes
                moe_p[t] = _slot_write_q(
                    moe_p[t], jnp.asarray(gs), jnp.asarray(sl),
                    jnp.asarray(w_host), jnp.asarray(scale),
                )
            else:
                self.stats.bytes_h2d += w_host.nbytes
                moe_p[t] = _slot_write(
                    moe_p[t], jnp.asarray(gs), jnp.asarray(sl), jnp.asarray(w_host)
                )

    def trans_row(self, l: int) -> np.ndarray:
        g, s = self.layer_to_gs(l)
        row = np.full((self.E,), -1, np.int32)
        for e, slot in self.resident[(g, s)].items():
            row[e] = slot
        return row

    def prepare_layer(self, l: int, needed: np.ndarray) -> np.ndarray:
        """Synchronously load `needed` experts for one layer (OnDemand path)."""
        t0 = time.perf_counter()
        if len(needed) > self.S:
            needed = needed[: self.S]
        _, s = self.layer_to_gs(l)
        self.commit_loads(s, self.plan_layer(l, np.asarray(needed)))
        row = self.trans_row(l)
        self.stats.prepare_time += time.perf_counter() - t0
        return row

    def prepare(self, table: HashTable) -> np.ndarray:
        """Load predicted experts for a whole batch (SiDA look-ahead path).

        Returns the translation table [L, E] expert->slot (-1 = not resident).
        """
        t0 = time.perf_counter()
        trans = np.full((self.L, self.E), -1, np.int32)
        pending: Dict[int, List[Tuple[int, int, int]]] = {s: [] for s in self.moe_subs}
        for l in range(self.L):
            needed = table.active_experts(l)
            mass = None
            if len(needed) > self.S or self.eviction == "alpha":
                mass = table.activation_mass(l, self.E)
            if len(needed) > self.S:
                # tighter budget than the active set: keep the highest-α-mass
                needed = needed[np.argsort(-mass[needed])][: self.S]
            _, s = self.layer_to_gs(l)
            pending[s].extend(self.plan_layer(l, needed, mass=mass))
            trans[l] = self.trans_row(l)
        for s, items in pending.items():
            self.commit_loads(s, items)
        self.stats.prepare_time += time.perf_counter() - t0
        return trans

    # ------------------------------------------------------------------
    def cache_affinity(self, table: HashTable) -> float:
        """Fraction of the table's active experts already resident — the
        scheduling score for cache-aware batch/request ordering (engine
        lookahead and the request scheduler both rank work by it)."""
        hits = tot = 0
        for l in range(self.L):
            g, s = self.layer_to_gs(l)
            res = self.resident[(g, s)]
            for e in table.active_experts(l):
                tot += 1
                hits += int(e) in res
        return hits / max(tot, 1)

    # ------------------------------------------------------------------
    def translate(self, table: HashTable, trans: np.ndarray):
        """(slot_ids [L,B,S,k] int32, weights [L,B,S,k] f32).

        Predicted experts that missed residency (dropped under a tight slot
        budget) get weight 0; the surviving weights are renormalized per
        token so the MoE output keeps its original α mass instead of
        silently shrinking toward zero (each token's override weights sum
        to what the hash function predicted, miss or no miss). Tokens whose
        every predicted expert missed keep weight 0 — there is nothing on
        device to compute them with.
        """
        L, B, S, k = table.expert_ids.shape
        flat = table.expert_ids.reshape(L, -1)
        slots = np.take_along_axis(trans, flat, axis=1).reshape(L, B, S, k)
        w = table.weights * (slots >= 0)
        orig = table.weights.sum(axis=-1, keepdims=True)
        surv = w.sum(axis=-1, keepdims=True)
        scale = np.where(surv > 0, orig / np.maximum(surv, 1e-12), 1.0)
        w = w * scale
        return np.maximum(slots, 0).astype(np.int32), w.astype(np.float32)
