"""Expert offloading: host-resident expert store + device slot cache.

This is the TPU-native adaptation of SiDA's CPU↔GPU expert offloading
(DESIGN.md §2). The full expert stacks live in host memory (numpy). On
device, each MoE layer owns a fixed pool of `slots` (static shape
[G, S, d, f] so jit never retraces). `prepare(hash_table)` loads exactly the
experts the hash function predicts will activate — FIFO-evicting under the
memory budget — and produces per-layer expert→slot translation tables so the
routing override can address slots directly.

Routers are offloaded entirely: the serving params pytree contains no router
matrix (the hash table replaces it — paper §3.1 "all routers are offloaded
to the main memory and do not participate in the forward pass").

`PrefetchPipeline` adds the asynchronous tier on top of the store: a
background transfer thread consumes per-step expert predictions, stages the
(int8-quantised) host weights into double-buffered staging slabs, and
commits them to device slots overlapped against the previous step's
compute. The forward path blocks only on per-expert ready fences instead of
performing uploads inline — see the class docstring for the protocol.
"""
from __future__ import annotations

import collections
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TierConfig
from repro.core.hash_table import HashTable
from repro.models.transformer import n_moe_layers, period, sub_kind

Array = jax.Array

EXPERT_TENSORS = ("w_in", "w_gate", "w_out")


@dataclass(frozen=True)
class ShardedStoreConfig:
    """Expert-parallel partitioning of the serving slot pools.

    With `ep_shards` > 1 every (group, sub) slot pool is split into
    `ep_shards` per-shard partitions: each expert has a fixed *home shard*
    (`placement`) and may only occupy slots in that shard's contiguous slot
    range, with its own per-shard eviction policy, free list, and pinning
    protection. Slot ids stay *global* (`shard * slots_per_shard + local`),
    so the translation tables, tickets, and routing overrides the engines
    already exchange keep working unchanged — the expert-parallel dispatch
    derives each shard's local (id, slot) pairs from the global id's range.

    When a `mesh` is attached to the store, the device slot-pool arrays are
    placed with the slot dim sharded over `model_axis` (see
    `sharding/policy.py::slot_pool_spec`), which is exactly the layout the
    shard_map expert dispatch consumes without any resharding collective.

    `replicate_hot` > 0 lets α-mass-hot experts hold up to that many EXTRA
    copies on other shards (free slots only — replicas are opportunistic
    and never evict a primary). Replicas keep global slot ids, so tickets,
    fences, and the EP dispatch are untouched; translation spreads each
    token's lookup round-robin over the copies, least-loaded shard first.
    `hot_alpha` is the decayed-α share above which an expert counts as hot
    (default 2/E — twice the uniform share); `alpha_decay` is the per-table
    decay of the α EMA that also drives `ExpertStore.rebalance_homes`.
    """

    ep_shards: int = 1
    model_axis: str = "model"
    placement: str = "mod"            # "mod": e -> e % shards | "block": e -> e // (E/shards)
    replicate_hot: int = 0            # extra copies a hot expert may hold
    hot_alpha: Optional[float] = None  # hot threshold as a share of total α
    alpha_decay: float = 0.9          # per-table decay of the α-mass EMA

    @property
    def enabled(self) -> bool:
        return self.ep_shards > 1

    def home_shards(self, num_experts: int) -> np.ndarray:
        """[E] expert -> home shard under the configured placement."""
        e = np.arange(num_experts)
        if self.placement == "block":
            blk = max(num_experts // self.ep_shards, 1)
            return np.minimum(e // blk, self.ep_shards - 1).astype(np.int32)
        assert self.placement == "mod", self.placement
        return (e % self.ep_shards).astype(np.int32)


@jax.jit
def _translate_dev(cand: Array, ids: Array, w: Array) -> Tuple[Array, Array]:
    """Device-side expert->slot translation (see ExpertStore.translate for
    the semantics, including per-token miss renormalization). cand [L, E, R]
    holds R candidate slots per expert (replicated hot experts list every
    copy, least-loaded shard first, cyclically tiled; R=1 when replication
    is off); each routed (token, k) lane picks copy `flat_index % R`, so
    replicated traffic round-robins over the copies while every copy holds
    bit-identical weights — the chosen value never depends on the pick.
    ids/w [L, B, S, k] -> (slot_ids int32, weights f32), all on device."""
    L, R = ids.shape[0], cand.shape[2]
    flat = ids.reshape(L, -1)
    s_all = jnp.take_along_axis(cand, flat[:, :, None], axis=1)   # [L, T, R]
    rr = (jnp.arange(flat.shape[1]) % R)[None, :, None]
    slots = jnp.take_along_axis(s_all, rr, axis=2)[..., 0].reshape(ids.shape)
    wz = w.astype(jnp.float32)
    masked = wz * (slots >= 0)
    orig = wz.sum(axis=-1, keepdims=True)
    surv = masked.sum(axis=-1, keepdims=True)
    scale = jnp.where(surv > 0, orig / jnp.maximum(surv, 1e-12), 1.0)
    return jnp.maximum(slots, 0).astype(jnp.int32), masked * scale


def _pool_set(buf: Array, g: Array, slots: Array, w: Array) -> Array:
    """buf [G,S,...] <- w [n,...] at (g[n], slots[n])."""
    return buf.at[g, slots].set(w)


def _pool_set_q(buf: Array, g: Array, slots: Array, q: Array, scale: Array) -> Array:
    """int8 variant: dequantisation happens ON DEVICE, so the host->device
    transfer moves int8 + per-channel scales (2x fewer bytes than bf16,
    4x fewer than f32) — SiDA's critical path is exactly these transfers."""
    w = (q.astype(jnp.float32) * scale).astype(buf.dtype)
    return buf.at[g, slots].set(w)


# One pure scatter pair, four jit wrappings. Donating variants update the
# pool in place; the non-donating (copy-on-write) variants exist for
# concurrent writers — the async transfer thread commits while a forward
# may still hold (and read) the previous slot-pool array, so the old
# buffer must stay alive.
_slot_write = jax.jit(_pool_set, donate_argnums=(0,))
_slot_write_q = jax.jit(_pool_set_q, donate_argnums=(0,))
_slot_write_cow = jax.jit(_pool_set)
_slot_write_q_cow = jax.jit(_pool_set_q)


def _make_pool_writes(sharding):
    """The same four wrappings over a mesh-sharded pool: out_shardings is
    pinned so the scatter's result keeps the slot dim partitioned over the
    expert-parallel axis (GSPMD must not re-replicate the pool)."""
    kw = dict(out_shardings=sharding)
    return (
        jax.jit(_pool_set, donate_argnums=(0,), **kw),
        jax.jit(_pool_set_q, donate_argnums=(0,), **kw),
        jax.jit(_pool_set, **kw),
        jax.jit(_pool_set_q, **kw),
    )


def quantize_expert(
    w: np.ndarray, granularity: str = "channel"
) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 quantisation. w: [..., d_in, d_out].

    granularity="channel": one scale per output channel (absmax over d_in) —
    the tight default. granularity="tensor": one scale per expert tensor
    (absmax over both trailing axes) — coarser, but the scale plane is
    constant. Either way the returned scale is a [..., 1, d_out] per-channel
    plane so slot storage and the fused-dequant kernel stay uniform.
    """
    if granularity == "tensor":
        absmax = np.abs(w).max(axis=(-2, -1), keepdims=True).astype(np.float32)
        absmax = np.broadcast_to(
            absmax, w.shape[:-2] + (1, w.shape[-1])
        ).copy()
    else:
        assert granularity == "channel", granularity
        absmax = np.abs(w).max(axis=-2, keepdims=True).astype(np.float32)
    scale = np.maximum(absmax, 1e-8) / 127.0
    q = np.clip(np.round(w.astype(np.float32) / scale), -127, 127).astype(np.int8)
    return q, scale


def pack_nibbles(q: np.ndarray) -> np.ndarray:
    """int4 values (int8 storage, [-8, 7]) [..., K, N] -> nibble-packed
    uint8 [..., ceil(K/2), N]. Byte i holds contraction rows 2i (low
    nibble) and 2i+1 (high nibble), two's complement; odd K pads one zero
    row. Must match `kernels/ref.unpack_int4_ref` bit-for-bit."""
    K = q.shape[-2]
    if K % 2:
        pad = [(0, 0)] * (q.ndim - 2) + [(0, 1), (0, 0)]
        q = np.pad(q, pad)
    u = (q.astype(np.int16) & 0xF).astype(np.uint8)
    return (u[..., 1::2, :] << 4) | u[..., 0::2, :]


def unpack_nibbles(p: np.ndarray, k: int) -> np.ndarray:
    """Inverse of `pack_nibbles`: uint8 [..., ceil(k/2), n] -> int8 [..., k, n]."""
    lo = (p & 0xF).astype(np.int8)
    hi = (p >> 4).astype(np.int8)
    v = np.stack([lo, hi], axis=-2)
    v = v.reshape(p.shape[:-2] + (-1, p.shape[-1]))[..., :k, :]
    return np.where(v >= 8, v - 16, v).astype(np.int8)


def _group_of(k: int, group: int) -> int:
    """Effective quantization group along a contraction axis of length `k`:
    `group` when it divides `k`, else the whole axis (one scale group)."""
    g = min(group, k)
    return g if k % g == 0 else k


def quantize_expert_q4(
    w: np.ndarray, group: int = 64
) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric int4 quantisation with per-group scales. w: [..., d_in, d_out].

    The contraction axis is split into groups of `group` rows; each
    (group, output channel) pair gets one f32 scale = absmax / 7, and values
    quantize to [-7, 7] (the symmetric int4 range; -8 is unused so the
    format round-trips through negation). Returns (packed, scale):
    packed [..., ceil(d_in/2), d_out] uint8 (see `pack_nibbles`),
    scale [..., d_in/group, d_out] f32.
    """
    k = w.shape[-2]
    g = _group_of(k, group)
    ng = k // g
    wg = w.astype(np.float32).reshape(w.shape[:-2] + (ng, g, w.shape[-1]))
    absmax = np.abs(wg).max(axis=-2, keepdims=True)
    scale = np.maximum(absmax, 1e-8) / 7.0
    q = np.clip(np.round(wg / scale), -7, 7).astype(np.int8)
    q = q.reshape(w.shape)
    return pack_nibbles(q), scale[..., 0, :].reshape(
        w.shape[:-2] + (ng, w.shape[-1])
    ).astype(np.float32)


def expert_format_bytes(
    shapes: List[Tuple[int, int]], fmt: str, group: int = 64
) -> int:
    """Per-expert device bytes per MoE layer for one residency format,
    scale planes included — the single bytes-per-expert-per-tier rule that
    `ExpertStore.tier_slot_bytes`, `ResidencyManager.split_budget_tiered`,
    and the bench_memory capacity claims all share. `shapes` lists the
    (d_in, d_out) of each expert tensor (w_in, w_gate, w_out)."""
    tot = 0
    for k, n in shapes:
        if fmt == "int8":
            tot += k * n + 4 * n                    # int8 rows + [1, n] f32 scale
        else:
            assert fmt == "int4", fmt
            g = _group_of(k, group)
            tot += ((k + 1) // 2) * n + 4 * (k // g) * n
    return tot


class EvictionPolicy:
    """Replacement policy for one (group, sub) slot pool.

    The store calls `admit` when an expert is loaded, `touch` on every
    reference (hit or load, with the α mass it carried), `pick_victim`
    when a slot must be reclaimed, passing the experts that must survive
    (currently-needed + pinned). Returns None when every resident expert
    is protected — the caller then drops the load instead of evicting.
    """

    name = "base"

    def admit(self, e: int, weight: float = 0.0) -> None:
        raise NotImplementedError

    def touch(self, e: int, weight: float = 0.0) -> None:
        pass

    def forget(self, e: int) -> None:
        """Remove `e` without treating it as an eviction (the expert's
        primary copy migrated to another shard's policy)."""
        pass

    def pick_victim(self, protected) -> Optional[int]:
        raise NotImplementedError


class FIFOPolicy(EvictionPolicy):
    """Evict in insertion order (the paper's serving loop assumption)."""

    name = "fifo"

    def __init__(self):
        self.order: collections.deque = collections.deque()

    def admit(self, e: int, weight: float = 0.0) -> None:
        self.order.append(e)

    def forget(self, e: int) -> None:
        try:
            self.order.remove(e)
        except ValueError:
            pass

    def pick_victim(self, protected) -> Optional[int]:
        for _ in range(len(self.order)):
            victim = self.order.popleft()
            if victim in protected:
                self.order.append(victim)  # recycle, try next
                continue
            return victim
        return None


class LRUPolicy(EvictionPolicy):
    """Evict the least-recently referenced expert — request-interleaved
    traffic revisits hot experts out of FIFO order, where pure insertion
    order evicts exactly the experts about to be reused."""

    name = "lru"

    def __init__(self):
        self.order: "collections.OrderedDict[int, None]" = collections.OrderedDict()

    def admit(self, e: int, weight: float = 0.0) -> None:
        self.order[e] = None
        self.order.move_to_end(e)

    def touch(self, e: int, weight: float = 0.0) -> None:
        if e in self.order:
            self.order.move_to_end(e)

    def forget(self, e: int) -> None:
        self.order.pop(e, None)

    def pick_victim(self, protected) -> Optional[int]:
        for victim in self.order:
            if victim not in protected:
                del self.order[victim]
                return victim
        return None


class AlphaMassPolicy(EvictionPolicy):
    """Evict the expert with the least decayed α mass: the hash table gives
    the routing weight every token sends to each expert, so the cache can
    rank residency by how much computation an expert actually absorbs
    rather than by arrival order."""

    name = "alpha"

    def __init__(self, decay: float = 0.9):
        self.decay = decay
        self.score: Dict[int, float] = {}

    def admit(self, e: int, weight: float = 0.0) -> None:
        self.score[e] = self.score.get(e, 0.0) + max(weight, 1e-6)

    def touch(self, e: int, weight: float = 0.0) -> None:
        if e in self.score:
            self.score[e] = self.decay * self.score[e] + weight

    def forget(self, e: int) -> None:
        self.score.pop(e, None)

    def pick_victim(self, protected) -> Optional[int]:
        best, best_s = None, None
        for e, sc in self.score.items():
            if e in protected:
                continue
            if best_s is None or sc < best_s:
                best, best_s = e, sc
        if best is not None:
            del self.score[best]
        return best


EVICTION_POLICIES = {
    "fifo": FIFOPolicy,
    "lru": LRUPolicy,
    "alpha": AlphaMassPolicy,
}


@dataclass
class TransferStats:
    bytes_h2d: int = 0
    loads: int = 0
    evictions: int = 0
    hits: int = 0
    dropped: int = 0               # planned loads dropped (every victim protected)
    prepare_time: float = 0.0      # synchronous upload time inside the forward path
    replica_loads: int = 0         # extra-copy uploads of hot experts (also in loads)
    rebalance_moves: int = 0       # primaries migrated by rebalance_homes
    promotions: int = 0            # warm (int4) -> hot (int8) tier moves
    demotions: int = 0             # hot (int8) -> warm (int4) tier moves
    pin_quota_refusals: int = 0    # tenant pins refused at the quota cap

    def reset(self):
        self.bytes_h2d = self.loads = self.evictions = self.hits = 0
        self.dropped = 0
        self.prepare_time = 0.0
        self.replica_loads = self.rebalance_moves = 0
        self.promotions = self.demotions = 0
        self.pin_quota_refusals = 0


class ExpertStore:
    """Host store + device slot cache for every MoE layer of a model.

    host_quant="int8" stores experts quantised on host and dequantises on
    device at load (beyond-paper; the paper notes quantisation is orthogonal
    — here it composes directly with the offloading path, halving H2D
    bytes vs bf16). spill_dir enables the paper's §6 hierarchical tier:
    host arrays live in disk-backed memmaps instead of RAM.

    quantized_slots=True makes int8 the *native residency format*: the device
    slot pools themselves are int8 (plus per-expert per-output-channel f32
    scale planes `w_*_scale`), uploads move the quantized slabs with no
    dequant hop, and the expert FFN dequantizes in-kernel (fused) — so the
    same slot-byte budget holds 2–4× more resident experts than fp slots.
    Implies host_quant="int8". Defaults resolve from `cfg.quant`.

    `sharded` partitions the pools expert-parallel (see ShardedStoreConfig):
    slots_per_layer stays the TOTAL per-layer slot count, split evenly into
    per-shard partitions with independent eviction/pinning bookkeeping; with
    a `mesh` the pool arrays are placed slot-dim-sharded over the model axis.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        slots_per_layer: int,
        host_quant: str = "none",      # "none" | "int8"
        spill_dir: Optional[str] = None,
        eviction: str = "fifo",        # "fifo" | "lru" | "alpha"
        quantized_slots: Optional[bool] = None,   # None => cfg.quant
        scale_granularity: Optional[str] = None,  # "channel" | "tensor"
        sharded: Optional[ShardedStoreConfig] = None,
        mesh: Optional[jax.sharding.Mesh] = None,
        tier: Optional[TierConfig] = None,        # None => cfg.quant.tier
    ):
        assert cfg.moe.enabled, "ExpertStore requires an MoE config"
        assert eviction in EVICTION_POLICIES, eviction
        self.cfg = cfg
        self.per = period(cfg)
        self.n_groups = cfg.n_layers // self.per
        self.moe_subs = [s for s in range(self.per) if sub_kind(cfg, s).get("moe")]
        self.L = n_moe_layers(cfg)
        self.E = cfg.moe.num_experts
        self.sharded = sharded or ShardedStoreConfig()
        self.shards = self.sharded.ep_shards
        assert self.shards >= 1
        # the pool never needs more than one slot per expert COPY: E without
        # replication, E * (1 + replicate_hot) with it (hot experts occupy a
        # slot in every hosting shard's partition)
        copies = (
            min(self.shards, 1 + max(0, self.sharded.replicate_hot))
            if self.shards > 1 else 1
        )
        self.S = min(slots_per_layer, self.E * copies)
        if self.shards > 1:
            assert self.E % self.shards == 0, (
                f"experts ({self.E}) must divide over ep_shards ({self.shards})"
            )
            assert self.S >= self.shards, (
                f"need >= 1 slot per shard (slots={self.S}, shards={self.shards})"
            )
            # round the total budget down to a per-shard-even split
            self.S = (self.S // self.shards) * self.shards
        self.S_loc = self.S // self.shards
        # expert -> home shard (initial placement; rebalance_homes may
        # re-assign it online from the decayed α-mass EMA)
        self.home = self.sharded.home_shards(self.E)
        # copies per hot expert: primary + replicate_hot extras, never more
        # than one copy per shard
        self.R = copies
        self.mesh = mesh
        if self.shards > 1 and mesh is not None:
            assert self.sharded.model_axis in mesh.axis_names, mesh
            assert mesh.shape[self.sharded.model_axis] == self.shards, (
                mesh.shape, self.shards,
            )
        self.quantized_slots = (
            cfg.quant.quantized_slots if quantized_slots is None else quantized_slots
        )
        self.scale_granularity = scale_granularity or cfg.quant.scale_granularity
        if self.quantized_slots:
            host_quant = "int8"  # int8 residency requires the int8 host tier
        self.quant = host_quant
        self.stats = TransferStats()

        # --- hierarchical residency tiers (hot int8 / warm int4 / cold host)
        # `slots_per_layer` stays the budget in INT8-slot currency; the warm
        # tier converts its share into int4 slots via the per-tier
        # bytes-per-expert rule (scale planes included), so a tiered store
        # at N slots costs the same device bytes as an untiered one at N.
        self.tier = cfg.quant.tier if tier is None else tier
        self.tiered = bool(self.tier is not None and self.tier.enabled)
        moe_p0 = params["blocks"][f"sub{self.moe_subs[0]}"]["moe"]
        self._expert_shapes = [
            tuple(moe_p0[t].shape[2:]) for t in EXPERT_TENSORS
        ]
        if self.tiered:
            assert self.quantized_slots, (
                "the int4 warm tier layers on int8-native slots "
                "(--int4-slots requires --quantized-slots)"
            )
            assert self.sharded.replicate_hot == 0, (
                "hot-expert replication and residency tiering are mutually "
                "exclusive (a replica's tier would be ambiguous)"
            )
            b8 = expert_format_bytes(self._expert_shapes, "int8")
            b4 = expert_format_bytes(
                self._expert_shapes, "int4", self.tier.group_size
            )
            # combined slot count caps at E: more slots than experts would
            # shrink the per-slot dispatch capacity (C ~ tokens / n_slots)
            # below the dense forward's for no residency gain, silently
            # dropping tokens the untiered store would serve
            if self.tier.warm_slots is not None:
                S8 = min(max(slots_per_layer, 1), self.E)
                S4 = min(self.tier.warm_slots, self.E - S8)
            else:
                S8 = max(1, int(round(slots_per_layer * self.tier.tier_split)))
                S8 = min(S8, self.E)
                S4 = min(
                    max(0, ((slots_per_layer - S8) * b8) // b4),
                    self.E - S8,
                )
            if self.shards > 1:
                S8 = max((S8 // self.shards) * self.shards, self.shards)
                S4 = (S4 // self.shards) * self.shards
            self.S8, self.S4 = int(S8), int(S4)
            self.S = self.S8 + self.S4
            self.S8_loc = self.S8 // self.shards
            self.S4_loc = self.S4 // self.shards
            self.S_loc = self.S8_loc + self.S4_loc
            if self.S4 == 0:
                # degenerate all-hot config: with no warm slots the store
                # must be BEHAVIORALLY identical to the untiered quantized
                # path, so drop the tier flag entirely — otherwise the
                # tier-only branches (α-mass EMA feeds, tier-aware policy
                # admits, rebalance gating) would diverge from the plain
                # store's bookkeeping with zero tier capacity to show for it
                self.tiered = False
        else:
            self.S8, self.S4 = self.S, 0
            self.S8_loc, self.S4_loc = self.S_loc, 0

        # device slot writers: module-level jits for the single-shard case;
        # per-store jits pinned to the pool NamedSharding when the pools are
        # mesh-sharded (out_shardings keeps GSPMD from re-replicating the
        # pool around the scatter, donation keeps the in-place update)
        self._pool_sharding = None
        self._set, self._set_q = _slot_write, _slot_write_q
        self._set_cow, self._set_q_cow = _slot_write_cow, _slot_write_q_cow
        if self.shards > 1 and mesh is not None:
            from repro.sharding.policy import slot_pool_spec

            self._pool_sharding = jax.sharding.NamedSharding(
                mesh, slot_pool_spec(self.sharded.model_axis)
            )
            writes = _make_pool_writes(self._pool_sharding)
            self._set, self._set_q, self._set_cow, self._set_q_cow = writes

        def _spill(name: str, arr: np.ndarray) -> np.ndarray:
            if spill_dir is None:
                return arr
            import os

            os.makedirs(spill_dir, exist_ok=True)
            path = os.path.join(spill_dir, f"{name}.npy")
            mm = np.lib.format.open_memmap(path, mode="w+", dtype=arr.dtype,
                                           shape=arr.shape)
            mm[...] = arr
            mm.flush()
            return np.lib.format.open_memmap(path, mode="r")

        # --- split params: experts + routers -> host; rest stays on device
        self.host: Dict[str, Dict[str, np.ndarray]] = {}
        self.host_scale: Dict[str, Dict[str, np.ndarray]] = {}
        # int4 host masters (tiered stores only): quantized from the SAME
        # f32 originals as the int8 masters, never from the int8 rows —
        # demotion re-uploads host int4 rows and promotion re-uploads host
        # int8 rows, so a tier move is always a requantization from master,
        # never a lossy int8<->int4 transcode.
        self.host4: Dict[str, Dict[str, np.ndarray]] = {}
        self.host4_scale: Dict[str, Dict[str, np.ndarray]] = {}
        serve_params = jax.tree.map(lambda x: x, params)  # shallow-ish copy
        for s in self.moe_subs:
            moe_p = serve_params["blocks"][f"sub{s}"]["moe"]
            self.host[f"sub{s}"] = {}
            self.host_scale[f"sub{s}"] = {}
            self.host4[f"sub{s}"] = {}
            self.host4_scale[f"sub{s}"] = {}
            for t in EXPERT_TENSORS:
                w = np.asarray(moe_p[t])
                if host_quant == "int8":
                    q, scale = quantize_expert(w, self.scale_granularity)
                    self.host[f"sub{s}"][t] = _spill(f"sub{s}_{t}", q)
                    self.host_scale[f"sub{s}"][t] = scale
                else:
                    self.host[f"sub{s}"][t] = _spill(f"sub{s}_{t}", w)
                if self.tiered and self.S4 > 0:
                    q4, s4 = quantize_expert_q4(w, self.tier.group_size)
                    self.host4[f"sub{s}"][t] = _spill(f"sub{s}_{t}_q4", q4)
                    self.host4_scale[f"sub{s}"][t] = s4
            for t in EXPERT_TENSORS:
                full = moe_p[t]
                G, E = full.shape[:2]
                k_in, n_out = full.shape[2:]
                if self.quantized_slots:
                    # int8 slot pool + per-expert scale plane: the residency
                    # format IS the transfer format (no dequant hop anywhere)
                    moe_p[t] = self._place(
                        jnp.zeros((G, self.S8, *full.shape[2:]), jnp.int8)
                    )
                    moe_p[t + "_scale"] = self._place(
                        jnp.zeros((G, self.S8, 1, full.shape[-1]), jnp.float32)
                    )
                else:
                    moe_p[t] = self._place(
                        jnp.zeros((G, self.S8, *full.shape[2:]), full.dtype)
                    )
                if self.tiered and self.S4 > 0:
                    # warm tier: nibble-packed int4 pool + per-group scale
                    # plane, addressed by (global slot - S8). Absent when
                    # S4 == 0 so the all-hot degenerate config's params tree
                    # (and therefore its dispatch) is byte-identical to the
                    # untiered quantized path.
                    g4 = _group_of(k_in, self.tier.group_size)
                    moe_p[t + "_q4"] = self._place(
                        jnp.zeros(
                            (G, self.S4, (k_in + 1) // 2, n_out), jnp.uint8
                        )
                    )
                    moe_p[t + "_q4_scale"] = self._place(
                        jnp.zeros((G, self.S4, k_in // g4, n_out), jnp.float32)
                    )
            moe_p.pop("router", None)  # routers never participate in forward
        self.serve_params = serve_params

        # --- cache state per (group, sub): expert->slot + eviction policy.
        # `resident` stays a single expert -> GLOBAL-slot map per (g, s)
        # (readable regardless of sharding); free lists and eviction
        # policies are per shard, indexed [shard], so replacement decisions
        # never cross a shard boundary (an expert's slots come only from
        # its home shard's partition).
        self.eviction = eviction
        self.resident: Dict[Tuple[int, int], Dict[int, int]] = {}
        self.policy: Dict[Tuple[int, int], List[EvictionPolicy]] = {}
        self.free: Dict[Tuple[int, int], List[List[int]]] = {}
        # warm-tier (int4) twins of policy/free: global warm slot ids live
        # in [S8, S8 + S4), per-shard partitions [S8 + m*S4_loc, ...). The
        # hot structures are untouched by tiering, so an all-hot tier config
        # (S4 = 0) takes exactly the untiered bookkeeping paths.
        self.policy4: Dict[Tuple[int, int], List[EvictionPolicy]] = {}
        self.free4: Dict[Tuple[int, int], List[List[int]]] = {}
        self.pinned: Dict[Tuple[int, int], set] = {}
        # replica copies per (g, s): expert -> {shard: global slot}. EXTRA
        # copies only — the primary stays in `resident`; each shard's
        # eviction policy tracks exactly the primaries its slots host.
        self.replicas: Dict[Tuple[int, int], Dict[int, Dict[int, int]]] = {}
        # decayed per-expert α mass per (g, s): drives the hot threshold
        # for replication and the greedy placement in rebalance_homes
        self.alpha_ema: Dict[Tuple[int, int], np.ndarray] = {}
        for g in range(self.n_groups):
            for s in self.moe_subs:
                self.resident[(g, s)] = {}
                self.policy[(g, s)] = [
                    EVICTION_POLICIES[eviction]() for _ in range(self.shards)
                ]
                self.free[(g, s)] = [
                    list(range(m * self.S8_loc, (m + 1) * self.S8_loc))
                    for m in range(self.shards)
                ]
                self.policy4[(g, s)] = [
                    EVICTION_POLICIES[eviction]() for _ in range(self.shards)
                ]
                self.free4[(g, s)] = [
                    list(range(self.S8 + m * self.S4_loc,
                               self.S8 + (m + 1) * self.S4_loc))
                    for m in range(self.shards)
                ]
                self.pinned[(g, s)] = set()
                self.replicas[(g, s)] = {}
                self.alpha_ema[(g, s)] = np.zeros((self.E,), np.float64)
        # multi-tenant pin attribution: which tenant owns each tenant-scoped
        # pin (per (g, s): expert -> tenant), and each tenant's quota as a
        # fraction of the per-layer slot count. Legacy tenant-less pins stay
        # unattributed and uncapped, so single-tenant behavior is unchanged.
        self.pin_owner: Dict[Tuple[int, int], Dict[int, str]] = {
            k: {} for k in self.pinned
        }
        self.pin_quota: Dict[str, float] = {}
        # decayed α mass dispatched per home shard (the load half of
        # shard_load_score; the other half is measured upload traffic)
        self._shard_alpha = np.zeros((self.shards,), np.float64)
        # bumped on every residency mutation (loads, evictions, replica
        # reclaims, rebalance moves) — cache_affinity consumers key their
        # memoization on it (see Scheduler._order)
        self._epoch = 0
        # planning + device commits are serialized under this lock so the
        # async transfer thread and the forward thread never interleave slot
        # bookkeeping or double-donate a slot buffer
        self._lock = threading.RLock()
        self._prefetcher: Optional["PrefetchPipeline"] = None

    # -- layer indexing: moe layer l = g * len(moe_subs) + j ----------------
    def layer_to_gs(self, l: int) -> Tuple[int, int]:
        j = l % len(self.moe_subs)
        return l // len(self.moe_subs), self.moe_subs[j]

    # -- expert-parallel shard geometry ---------------------------------
    def _place(self, arr: Array) -> Array:
        """Pin a freshly built slot pool to the sharded layout (no-op when
        the store is unsharded or meshless)."""
        if self._pool_sharding is None:
            return arr
        return jax.device_put(arr, self._pool_sharding)

    def shard_of(self, e: int) -> int:
        """Current home shard of expert `e` — where NEW primary loads go.
        Under replication/rebalancing the expert may also hold copies (or
        a promoted primary) on other shards; see `replicas`."""
        return int(self.home[e])

    def shard_slots(self, shard: int) -> range:
        """Global HOT slot ids owned by `shard` (a contiguous partition, so
        the mesh-sharded pool array needs no permutation). Warm (int4) slot
        ids live in the separate [S8 + shard*S4_loc, ...) partition."""
        return range(shard * self.S8_loc, (shard + 1) * self.S8_loc)

    def slot_shard(self, slot: int) -> int:
        """Hosting shard of a global slot id, tier-aware: hot slots are
        partitioned over [0, S8), warm slots over [S8, S8+S4). Degenerates
        to `slot // S_loc` when the store is untiered."""
        if self.S4 and slot >= self.S8:
            return (int(slot) - self.S8) // self.S4_loc
        return int(slot) // self.S8_loc

    def slot_tier(self, slot: int) -> str:
        """'hot' (int8 pool) or 'warm' (int4 pool) for a global slot id."""
        return "warm" if (self.S4 and slot >= self.S8) else "hot"

    def local_trans(self, trans: np.ndarray) -> np.ndarray:
        """Global translation table [L, E] -> per-shard LOCAL slot ids
        (misses stay -1). The expert-parallel dispatch derives the same
        thing on device from the global ids; this is the host-side view
        (tests + debugging). Derived from the slot id, not the home table:
        under replication/rebalancing an expert's primary may be hosted
        off its (current) home shard. Tiered stores concatenate the local
        spaces: a shard's hot slots map to [0, S8_loc) and its warm slots
        to [S8_loc, S8_loc + S4_loc)."""
        if self.S4:
            warm = trans >= self.S8
            local = np.where(
                warm, self.S8_loc + (trans - self.S8) % self.S4_loc,
                trans % self.S8_loc,
            )
            return np.where(trans >= 0, local, -1).astype(np.int32)
        local = np.where(trans >= 0, trans % self.S_loc, -1)
        return local.astype(np.int32)

    @property
    def affinity_epoch(self) -> int:
        """Monotonic residency version: unchanged epoch => every
        `cache_affinity` answer is unchanged too, so callers may reuse a
        memoized score instead of rescanning L×E under the store lock."""
        return self._epoch

    # ------------------------------------------------------------------
    def device_bytes(self) -> int:
        """Bytes of expert weights resident on device (the paper's metric),
        including the scale planes when slots are int8-resident and the
        warm-tier int4 pools + per-group scale planes when tiered."""
        tot = 0
        for s in self.moe_subs:
            moe_p = self.serve_params["blocks"][f"sub{s}"]["moe"]
            for t in EXPERT_TENSORS:
                for key in (t, t + "_scale", t + "_q4", t + "_q4_scale"):
                    arr = moe_p.get(key)
                    if arr is not None:
                        tot += arr.nbytes
        return tot

    def expert_slot_bytes(self) -> int:
        """Device bytes one HOT expert slot costs per MoE layer in the
        current residency format (fp vs int8+scales), scale planes included
        — the denominator of the capacity-at-equal-bytes comparison the
        quantized-slot benches make. Warm-tier (int4) slots cost
        `tier_slot_bytes()["warm"]` instead."""
        tot = 0
        for s in self.moe_subs:
            moe_p = self.serve_params["blocks"][f"sub{s}"]["moe"]
            for t in EXPERT_TENSORS:
                arr = moe_p[t]
                tot += arr.nbytes // (arr.shape[0] * arr.shape[1])
                sc = moe_p.get(t + "_scale")
                if sc is not None:
                    tot += sc.nbytes // (sc.shape[0] * sc.shape[1])
        return tot // len(self.moe_subs)

    def tier_slot_bytes(self) -> Dict[str, int]:
        """Per-expert device bytes per MoE layer for each residency tier
        (scale planes included), from the shared `expert_format_bytes`
        rule — the same numbers `ResidencyManager.split_budget_tiered` and
        the bench_memory tiered-capacity rows use."""
        group = self.tier.group_size if self.tier is not None else 64
        return {
            "hot": expert_format_bytes(self._expert_shapes, "int8"),
            "warm": expert_format_bytes(self._expert_shapes, "int4", group),
        }

    def full_expert_bytes(self) -> int:
        return sum(
            arr.nbytes for sub in self.host.values() for arr in sub.values()
        )

    # ------------------------------------------------------------------
    def set_pin_quota(self, tenant: str, frac: float) -> None:
        """Cap `tenant`'s pinned-slot share: at most `floor(frac x S)` of
        each layer's S device slots may be pinned under this tenant's name
        (the multi-tenant front door registers `TenantConfig.pin_quota`
        here at server construction)."""
        if not (0.0 < frac <= 1.0):
            raise ValueError(f"pin quota for {tenant!r} must be in (0, 1]")
        self.pin_quota[tenant] = float(frac)

    def pin_cap(self, tenant: str) -> int:
        """Per-layer pinned-slot cap for `tenant` (S slots when no quota)."""
        return int(self.pin_quota.get(tenant, 1.0) * self.S)

    def pinned_count(self, l: int, tenant: str) -> int:
        g, s = self.layer_to_gs(l)
        return sum(1 for t in self.pin_owner[(g, s)].values() if t == tenant)

    def pinned_share(self, tenant: str) -> float:
        """Largest fraction of any layer's slot pool held pinned by
        `tenant` — the quantity the quota provably bounds."""
        if self.S <= 0:
            return 0.0
        worst = 0
        for owners in self.pin_owner.values():
            worst = max(worst, sum(1 for t in owners.values() if t == tenant))
        return worst / self.S

    def pin_experts(self, l: int, experts, tenant: Optional[str] = None) -> Set[int]:
        """Mark experts at MoE layer `l` as never-evictable (hot experts a
        deployment wants permanently resident). Pinned experts still load
        through the normal prepare path; they just cannot be victims.

        With `tenant` set, the pin is attributed and counted against the
        tenant's `set_pin_quota` cap: pins beyond `floor(quota x S)` per
        layer are REFUSED (skipped, tallied in `stats.pin_quota_refusals`)
        so no tenant can monopolize the slot pools every other tenant's hit
        rate depends on. Returns the experts actually pinned by this call
        (legacy tenant-less pins are unattributed, uncapped, and behave
        exactly as before)."""
        g, s = self.layer_to_gs(l)
        with self._lock:
            pool = self.pinned[(g, s)]
            if tenant is None:
                new = {int(e) for e in experts}
                pool.update(new)
                return new
            owners = self.pin_owner[(g, s)]
            cap = self.pin_cap(tenant)
            held = sum(1 for t in owners.values() if t == tenant)
            granted: Set[int] = set()
            for e in sorted(int(x) for x in experts):
                if owners.get(e) == tenant:
                    granted.add(e)      # idempotent re-pin, no new charge
                    continue
                if e in pool:
                    # pinned by someone else (or unattributed): not a new
                    # pin, not this tenant's to hold — refuse attribution
                    self.stats.pin_quota_refusals += 1
                    continue
                if held >= cap:
                    self.stats.pin_quota_refusals += 1
                    continue
                pool.add(e)
                owners[e] = tenant
                held += 1
                granted.add(e)
            return granted

    def unpin_experts(self, l: int, experts, tenant: Optional[str] = None) -> None:
        """Release pins. With `tenant` set, only that tenant's own pins are
        released (a tenant cannot unpin another tenant's experts)."""
        g, s = self.layer_to_gs(l)
        with self._lock:
            owners = self.pin_owner[(g, s)]
            for e in (int(x) for x in experts):
                if tenant is not None and owners.get(e) != tenant:
                    continue
                self.pinned[(g, s)].discard(e)
                owners.pop(e, None)

    def plan_layer(
        self,
        l: int,
        needed: np.ndarray,
        mass: Optional[np.ndarray] = None,
        extra_protected: Optional[Set[int]] = None,
    ) -> List[Tuple[int, int, int]]:
        """Cache bookkeeping for one layer; returns pending (g, slot, e) loads.

        `mass` (optional, [E]) is the α mass the current hash table routes to
        each expert — fed to the eviction policy so α-weighted replacement
        can rank residency by absorbed computation. `extra_protected` are
        experts that must also survive eviction: the prefetch pipeline passes
        experts referenced by unconsumed tickets or with uploads in flight.
        """
        g, s = self.layer_to_gs(l)
        res = self.resident[(g, s)]
        policies = self.policy[(g, s)]
        free = self.free[(g, s)]
        needed_set = set(int(e) for e in needed)
        protected = needed_set | self.pinned[(g, s)]
        if extra_protected:
            protected |= extra_protected
        # experts that may not MOVE between tiers: pinned (never demote by
        # contract) and extra-protected (an unreleased ticket's translation
        # may point at the current slot, or an upload to it is mid-flight —
        # a tier move frees that slot for reuse, which would let a pending
        # forward read foreign weights). The CURRENT plan's needed set is
        # safe to move: its translation snapshots after planning.
        move_blocked = set(self.pinned[(g, s)])
        if extra_protected:
            move_blocked |= extra_protected
        if mass is not None and (self.shards > 1 or self.tiered):
            # decayed α EMA (per layer + per home shard): replication's hot
            # threshold, the least-loaded replica pick, rebalance_homes, and
            # the tier promotion/demotion ranking all read these. The decay
            # is per plan call, spread so one full table pass decays by
            # sharded.alpha_decay overall.
            d = self.sharded.alpha_decay ** (1.0 / max(self.L, 1))
            ema = self.alpha_ema[(g, s)]
            ema *= d
            ema += mass
            self._shard_alpha *= d
            self._shard_alpha += np.bincount(
                self.home, weights=mass, minlength=self.shards
            )
        pending: List[Tuple[int, int, int]] = []
        mutated = False
        for e in needed:
            e = int(e)
            w = float(mass[e]) if mass is not None else 0.0
            if e in res:
                self.stats.hits += 1
                if (
                    self.tiered and res[e] >= self.S8
                    and e not in move_blocked
                    and self._promote(g, s, e, w, protected, move_blocked,
                                      pending)
                ):
                    mutated = True
                    continue
                # touch the HOSTING shard's policy — under promotion or
                # rebalancing the primary may live off its home shard
                self._touch(g, s, e, res[e], w)
                continue
            sh = int(self.home[e])          # new loads go to the home shard
            policy = policies[sh]
            if free[sh]:
                slot = free[sh].pop()
            else:
                # reclaim a replica slot first (copies are opportunistic —
                # the expert stays resident via its primary elsewhere),
                # then evict per the hosting shard's policy
                slot = self._reclaim_replica(g, s, sh, protected)
            if slot is None:
                victim = policy.pick_victim(protected)
                if victim is None:
                    if self.tiered:
                        # hot tier exhausted by protected residents: the
                        # overflow loads straight into a warm (int4) slot
                        # instead of dropping — combined capacity is S8+S4
                        wslot = self._take_warm_slot(g, s, sh, protected)
                        if wslot is not None:
                            res[e] = wslot
                            self.policy4[(g, s)][sh].admit(e, w)
                            pending.append((g, wslot, e))
                            self.stats.loads += 1
                            mutated = True
                            continue
                    self.stats.dropped += 1  # everything resident protected
                    continue
                slot = res.pop(victim)
                v_reps = self.replicas[(g, s)].get(victim)
                if v_reps:
                    # the victim has live copies elsewhere: promote one to
                    # primary instead of losing residency (only this
                    # shard's slot is reclaimed, not the expert)
                    m = min(v_reps)
                    res[victim] = v_reps.pop(m)
                    if not v_reps:
                        del self.replicas[(g, s)][victim]
                    policies[m].admit(victim, 0.0)
                elif self.tiered and victim not in move_blocked:
                    # demote instead of evict: the victim survives as a
                    # warm int4 resident (re-uploaded from the host int4
                    # master, never transcoded from its int8 slot)
                    wslot = self._take_warm_slot(g, s, sh, protected)
                    if wslot is not None:
                        res[victim] = wslot
                        self.policy4[(g, s)][sh].admit(
                            victim, float(self.alpha_ema[(g, s)][victim])
                        )
                        pending.append((g, wslot, victim))
                        self.stats.demotions += 1
                        self.stats.loads += 1
                    else:
                        self.stats.evictions += 1
                else:
                    self.stats.evictions += 1
            res[e] = slot
            policy.admit(e, w)
            pending.append((g, slot, e))
            self.stats.loads += 1
            mutated = True
        if self.R > 1 and mass is not None:
            reps = self._plan_replicas(g, s, needed_set, protected)
            pending.extend(reps)
            mutated = mutated or bool(reps)
        if mutated:
            self._epoch += 1
        return pending

    def _touch(self, g: int, s: int, e: int, slot: int, w: float) -> None:
        """Route a reference to the policy of the tier + shard hosting `slot`."""
        sh = self.slot_shard(slot)
        if self.S4 and slot >= self.S8:
            self.policy4[(g, s)][sh].touch(e, w)
        else:
            self.policy[(g, s)][sh].touch(e, w)

    def _take_warm_slot(
        self, g: int, s: int, sh: int, protected: Set[int]
    ) -> Optional[int]:
        """Claim one warm (int4) slot on shard `sh`: a free slot if any,
        else evict the warm tier's policy victim to host. Returns the
        global slot id, or None when the warm tier has no reclaimable slot
        (including the S4 == 0 degenerate config)."""
        free4 = self.free4[(g, s)][sh]
        if free4:
            return free4.pop()
        v4 = self.policy4[(g, s)][sh].pick_victim(protected)
        if v4 is None:
            return None
        slot4 = self.resident[(g, s)].pop(v4)
        self.stats.evictions += 1
        return slot4

    def _peek_hot_victim(
        self, g: int, s: int, sh: int, excluded: Set[int]
    ) -> Optional[int]:
        """Lowest-decayed-α hot resident on shard `sh` not in `excluded` —
        a non-mutating peek (unlike pick_victim) for promotion hysteresis."""
        res = self.resident[(g, s)]
        ema = self.alpha_ema[(g, s)]
        best = None
        for e2, slot in res.items():
            if slot >= self.S8 or self.slot_shard(slot) != sh or e2 in excluded:
                continue
            if best is None or ema[e2] < ema[best]:
                best = e2
        return best

    def _promote(
        self,
        g: int,
        s: int,
        e: int,
        w: float,
        protected: Set[int],
        move_blocked: Set[int],
        pending: List[Tuple[int, int, int]],
    ) -> bool:
        """Try to move warm-resident `e` into the hot tier: into a free hot
        slot when one exists, else by SWAPPING with the coldest demotable
        hot resident — but only when e's decayed α mass beats the victim's
        by `tier.promote_margin` (hysteresis, so two experts of similar mass
        never ping-pong between tiers). Promotion re-uploads the host int8
        rows (quantized from the f32 master — never an int4 upcast); the
        swap demotes the victim into e's old warm slot, so no capacity is
        created or destroyed. Appends the uploads to `pending`; returns
        True iff the move happened (caller then skips the plain touch)."""
        res = self.resident[(g, s)]
        ema = self.alpha_ema[(g, s)]
        wslot = res[e]
        sh = self.slot_shard(wslot)
        free = self.free[(g, s)][sh]
        if free:
            hot_slot = free.pop()
            self.free4[(g, s)][sh].append(wslot)
            self.policy4[(g, s)][sh].forget(e)
            res[e] = hot_slot
            self.policy[(g, s)][sh].admit(e, w)
            pending.append((g, hot_slot, e))
            self.stats.promotions += 1
            self.stats.loads += 1
            return True
        v = self._peek_hot_victim(g, s, sh, protected | move_blocked)
        if v is None or float(ema[e]) <= 0.0:
            return False
        if float(ema[e]) < self.tier.promote_margin * float(ema[v]):
            return False
        hot_slot = res[v]
        res[e] = hot_slot
        res[v] = wslot
        self.policy[(g, s)][sh].forget(v)
        self.policy4[(g, s)][sh].forget(e)
        self.policy[(g, s)][sh].admit(e, w)
        self.policy4[(g, s)][sh].admit(v, float(ema[v]))
        pending.append((g, hot_slot, e))
        pending.append((g, wslot, v))
        self.stats.promotions += 1
        self.stats.demotions += 1
        self.stats.loads += 2
        return True

    def _reclaim_replica(
        self, g: int, s: int, sh: int, protected: Set[int]
    ) -> Optional[int]:
        """Free one replica slot on shard `sh` (lowest decayed α mass
        first). Primaries are untouched, and replicas of protected experts
        (needed now, pinned, or with an upload in flight) are skipped — a
        pending fence may target that exact slot. Returns the freed global
        slot id, or None if no replica on `sh` is reclaimable."""
        reps = self.replicas[(g, s)]
        ema = self.alpha_ema[(g, s)]
        best = None
        for e, by_shard in reps.items():
            if e in protected or sh not in by_shard:
                continue
            if best is None or ema[e] < ema[best]:
                best = e
        if best is None:
            return None
        slot = reps[best].pop(sh)
        if not reps[best]:
            del reps[best]
        self._epoch += 1
        return slot

    def _plan_replicas(
        self, g: int, s: int, needed: Set[int], protected: Set[int]
    ) -> List[Tuple[int, int, int]]:
        """Plan extra copies for α-hot needed experts: up to `R` total
        copies each, FREE slots only (replication never evicts — it soaks
        idle capacity on under-loaded shards), least-loaded shards first.
        Caller holds the lock; returns (g, slot, e) uploads to commit."""
        res = self.resident[(g, s)]
        reps = self.replicas[(g, s)]
        free = self.free[(g, s)]
        ema = self.alpha_ema[(g, s)]
        tot = float(ema.sum())
        if tot <= 0.0:
            return []
        share = (
            self.sharded.hot_alpha if self.sharded.hot_alpha is not None
            else 2.0 / self.E
        )
        thr = share * tot
        score = self.shard_load_score()
        hot = sorted(
            (e for e in needed if e in res and float(ema[e]) >= thr),
            key=lambda e: -float(ema[e]),
        )
        out: List[Tuple[int, int, int]] = []
        for e in hot:
            by_shard = reps.get(e)
            have = {res[e] // self.S_loc} | set(by_shard or ())
            if len(have) >= self.R:
                continue
            for m in sorted(range(self.shards), key=lambda m: (score[m], m)):
                if len(have) >= self.R:
                    break
                if m in have or not free[m]:
                    continue
                slot = free[m].pop()
                if by_shard is None:
                    by_shard = reps.setdefault(e, {})
                by_shard[m] = slot
                have.add(m)
                out.append((g, slot, e))
                self.stats.loads += 1
                self.stats.replica_loads += 1
        return out

    def shard_load_score(self) -> np.ndarray:
        """[shards] relative load: normalized decayed α dispatch mass plus
        half-weighted normalized upload traffic (the per-shard
        `prefetch_uploads_shard{m}` counters when a pipeline is attached).
        Lower = less loaded; the replica pick and `_plan_replicas` order
        shards by it."""
        load = self._shard_alpha.copy()
        tot = load.sum()
        load = load / tot if tot > 0 else np.zeros_like(load)
        pf = self._prefetcher
        if pf is not None:
            ups = np.array(
                [float(pf.stats.uploads_by_shard.get(m, 0))
                 for m in range(self.shards)],
                np.float64,
            )
            utot = ups.sum()
            if utot > 0:
                load = load + 0.5 * ups / utot
        return load

    def commit_loads(self, s: int, items: List[Tuple[int, int, int]]) -> None:
        """Batched host->device writes for sub-slot `s` (one per tensor).

        With a prefetch pipeline attached, writes use the copy-on-write
        variants: an async forward may still read the previous pool array,
        so it cannot be donated out from under it."""
        if not items:
            return
        write = self._set if self._prefetcher is None else self._set_cow
        write_q = self._set_q if self._prefetcher is None else self._set_q_cow
        if self.S4:
            warm = [i for i in items if i[1] >= self.S8]
            items = [i for i in items if i[1] < self.S8]
            self._commit_warm(s, warm, write)
            if not items:
                return
        gs = np.array([i[0] for i in items], np.int32)
        sl = np.array([i[1] for i in items], np.int32)
        es = np.array([i[2] for i in items], np.int32)
        moe_p = self.serve_params["blocks"][f"sub{s}"]["moe"]
        gs_j, sl_j = jnp.asarray(gs), jnp.asarray(sl)
        for t in EXPERT_TENSORS:
            w_host = self.host[f"sub{s}"][t][gs, es]              # [n, d, f]
            if self.quantized_slots:
                # int8-native slots: the quantized rows land as-is and the
                # scale plane rides along — no dequant anywhere on this path
                scale = self.host_scale[f"sub{s}"][t][gs, es]
                self.stats.bytes_h2d += w_host.nbytes + scale.nbytes
                moe_p[t] = write(moe_p[t], gs_j, sl_j, jnp.asarray(w_host))
                moe_p[t + "_scale"] = write(
                    moe_p[t + "_scale"], gs_j, sl_j, jnp.asarray(scale)
                )
            elif self.quant == "int8":
                scale = self.host_scale[f"sub{s}"][t][gs, es]
                self.stats.bytes_h2d += w_host.nbytes + scale.nbytes
                moe_p[t] = write_q(
                    moe_p[t], gs_j, sl_j, jnp.asarray(w_host), jnp.asarray(scale),
                )
            else:
                self.stats.bytes_h2d += w_host.nbytes
                moe_p[t] = write(moe_p[t], gs_j, sl_j, jnp.asarray(w_host))

    def _commit_warm(
        self, s: int, items: List[Tuple[int, int, int]], write
    ) -> None:
        """Batched host->device writes into the warm (int4) pools: the
        nibble-packed slabs and per-group scale planes land as-is from the
        host int4 masters (no transcode hop anywhere — the residency format
        is the transfer format, same as the int8 hot path)."""
        if not items:
            return
        gs = np.array([i[0] for i in items], np.int32)
        sl = np.array([i[1] - self.S8 for i in items], np.int32)  # pool index
        es = np.array([i[2] for i in items], np.int32)
        moe_p = self.serve_params["blocks"][f"sub{s}"]["moe"]
        gs_j, sl_j = jnp.asarray(gs), jnp.asarray(sl)
        for t in EXPERT_TENSORS:
            q4 = self.host4[f"sub{s}"][t][gs, es]
            s4 = self.host4_scale[f"sub{s}"][t][gs, es]
            self.stats.bytes_h2d += q4.nbytes + s4.nbytes
            moe_p[t + "_q4"] = write(moe_p[t + "_q4"], gs_j, sl_j,
                                     jnp.asarray(q4))
            moe_p[t + "_q4_scale"] = write(
                moe_p[t + "_q4_scale"], gs_j, sl_j, jnp.asarray(s4)
            )

    def rollback_upload(self, g: int, s: int, slot: int, e: int) -> bool:
        """Undo plan-time residency publication for one (expert, slot)
        whose upload was abandoned (caller holds `_lock`): the slot returns
        to its tier's free list, so no translation built after this can
        point at a slot whose bytes never landed. Handles primary and
        replica copies and both residency tiers; a mapping that already
        moved on (evict + reload raced the failure) is left alone — the
        newer owner's upload governs that slot now. Returns True iff a
        mapping was actually rolled back."""
        sh = self.slot_shard(slot)
        res = self.resident[(g, s)]
        reps = self.replicas[(g, s)].get(e)
        if reps is not None and reps.get(sh) == slot:
            del reps[sh]
            if not reps:
                del self.replicas[(g, s)][e]
        elif res.get(e) == slot:
            del res[e]
            if self.S4 and slot >= self.S8:
                self.policy4[(g, s)][sh].forget(e)
            else:
                self.policy[(g, s)][sh].forget(e)
            if reps:
                # a live copy elsewhere keeps the expert resident: promote
                # it to primary (mirrors plan_layer's victim handling)
                m = min(reps)
                res[e] = reps.pop(m)
                if not reps:
                    del self.replicas[(g, s)][e]
                self.policy[(g, s)][m].admit(e, 0.0)
        else:
            return False        # re-planned since; nothing of ours to undo
        if self.S4 and slot >= self.S8:
            self.free4[(g, s)][sh].append(slot)
        else:
            self.free[(g, s)][sh].append(slot)
        self._epoch += 1
        return True

    def trans_row(self, l: int) -> np.ndarray:
        g, s = self.layer_to_gs(l)
        row = np.full((self.E,), -1, np.int32)
        for e, slot in self.resident[(g, s)].items():
            row[e] = slot
        return row

    def prepare_layer(self, l: int, needed: np.ndarray) -> np.ndarray:
        """Synchronously load `needed` experts for one layer (OnDemand path)."""
        t0 = time.perf_counter()
        if len(needed) > self.S:
            needed = needed[: self.S]
        _, s = self.layer_to_gs(l)
        with self._lock:
            self.commit_loads(s, self.plan_layer(l, np.asarray(needed)))
            row = self.trans_row(l)
        self.stats.prepare_time += time.perf_counter() - t0
        return row

    def plan(
        self,
        table: HashTable,
        protect_fn: Optional[Callable[[int, int], Set[int]]] = None,
    ):
        """Slot bookkeeping for a whole table (no device traffic).

        Returns (trans [L, E], pending {sub: [(g, slot, e)]}, needed {l: ids}).
        `protect_fn(g, s)` supplies extra never-evict experts (the prefetch
        pipeline protects experts referenced by outstanding tickets and
        uploads still in flight). Caller must hold `_lock`.
        """
        trans = np.full((self.L, self.E), -1, np.int32)
        pending: Dict[int, List[Tuple[int, int, int]]] = {s: [] for s in self.moe_subs}
        needed_by_layer: Dict[int, np.ndarray] = {}
        for l in range(self.L):
            needed = table.active_experts(l)
            mass = None
            # sharded stores always take the mass: the α EMA feeds the hot
            # threshold for replication and the rebalance placement scores;
            # tiered stores take it too — the EMA ranks tier moves
            if (len(needed) > self.S or self.eviction == "alpha"
                    or self.shards > 1 or self.tiered):
                mass = table.activation_mass(l, self.E)
            if len(needed) > self.S:
                # tighter budget than the active set: keep the highest-α-mass
                needed = needed[np.argsort(-mass[needed])][: self.S]
            g, s = self.layer_to_gs(l)
            extra = protect_fn(g, s) if protect_fn is not None else None
            pending[s].extend(
                self.plan_layer(l, needed, mass=mass, extra_protected=extra)
            )
            needed_by_layer[l] = needed
            trans[l] = self.trans_row(l)
        return trans, pending, needed_by_layer

    def prepare(self, table: HashTable) -> np.ndarray:
        """Load predicted experts for a whole batch (SiDA look-ahead path).

        Returns the translation table [L, E] expert->slot (-1 = not resident).
        This is the synchronous path: uploads run inline, so the full time
        lands in `stats.prepare_time` (the upload-stall metric). When a
        PrefetchPipeline is attached, residency of in-flight uploads is
        honored by fencing on them instead of re-issuing the transfer.
        """
        t0 = time.perf_counter()
        pf = self._prefetcher
        # fence poisoning makes this a loop: a waited fence whose upload was
        # abandoned (ev.poisoned — see PrefetchPipeline._fail_rows) means the
        # translation points at a rolled-back slot, so re-plan; the rollback
        # already un-published the residency, so the next round loads the
        # expert synchronously and sees no pending fence for it.
        for _ in range(64):
            with self._lock:
                trans, pending, needed = self.plan(
                    table,
                    protect_fn=pf.protected_experts if pf is not None else None,
                )
                for s, items in pending.items():
                    self.commit_loads(s, items)
                fences = pf.events_for(needed) if pf is not None else []
            poisoned = False
            for _, ev in fences:
                ev.wait()
                poisoned |= bool(getattr(ev, "poisoned", False))
            if not poisoned:
                break
        self.stats.prepare_time += time.perf_counter() - t0
        return trans

    # ------------------------------------------------------------------
    def cache_affinity(
        self,
        table: HashTable,
        inflight: Optional[Dict[Tuple[int, int], Set[int]]] = None,
    ) -> float:
        """Fraction of the table's active experts already resident — the
        scheduling score for cache-aware batch/request ordering (engine
        lookahead and the request scheduler both rank work by it).
        `inflight` extends residency with uploads currently in flight so
        the scheduler credits prefetches it already paid for."""
        hits = tot = 0
        with self._lock:
            for l in range(self.L):
                g, s = self.layer_to_gs(l)
                res = self.resident[(g, s)]
                fly = inflight.get((g, s), ()) if inflight else ()
                for e in table.active_experts(l):
                    tot += 1
                    hits += int(int(e) in res or int(e) in fly)
        return hits / max(tot, 1)

    # ------------------------------------------------------------------
    def translate(self, table: HashTable, trans: np.ndarray):
        """(slot_ids [L,B,S,k] int32, weights [L,B,S,k] f32).

        Predicted experts that missed residency (dropped under a tight slot
        budget) get weight 0; the surviving weights are renormalized per
        token so the MoE output keeps its original α mass instead of
        silently shrinking toward zero (each token's override weights sum
        to what the hash function predicted, miss or no miss). Tokens whose
        every predicted expert missed keep weight 0 — there is nothing on
        device to compute them with.
        """
        L, B, S, k = table.expert_ids.shape
        cand = self.replica_cand(trans)                       # [L, E, R]
        flat = table.expert_ids.reshape(L, -1)
        s_all = np.take_along_axis(cand, flat[:, :, None], axis=1)  # [L,T,R]
        rr = (np.arange(flat.shape[1]) % cand.shape[2])[None, :, None]
        slots = np.take_along_axis(s_all, rr, axis=2)[..., 0].reshape(L, B, S, k)
        w = table.weights * (slots >= 0)
        orig = table.weights.sum(axis=-1, keepdims=True)
        surv = w.sum(axis=-1, keepdims=True)
        scale = np.where(surv > 0, orig / np.maximum(surv, 1e-12), 1.0)
        w = w * scale
        return np.maximum(slots, 0).astype(np.int32), w.astype(np.float32)

    def replica_cand(self, trans: np.ndarray) -> np.ndarray:
        """Expand a translation table [L, E] into the replica candidate
        table [L, E, R] `_translate_dev` consumes: for each expert, every
        live copy of its slot (primary + replicas), sorted least-loaded
        hosting shard first and cyclically tiled to R, so the per-token
        round-robin pick spreads dispatch evenly over the copies with a
        bias toward the idle shards. Unreplicated experts (and the whole
        table when replication is off) tile the primary — the pick then
        degenerates to the plain trans lookup."""
        if self.R <= 1:
            return trans.reshape(self.L, self.E, 1).astype(np.int32)
        cand = np.repeat(trans[:, :, None], self.R, axis=2).astype(np.int32)
        with self._lock:
            score = self.shard_load_score()
            for l in range(self.L):
                g, s = self.layer_to_gs(l)
                for e, by_shard in self.replicas[(g, s)].items():
                    if trans[l, e] < 0 or not by_shard:
                        continue
                    copies = [int(trans[l, e])] + [
                        int(sl) for sl in by_shard.values()
                    ]
                    copies.sort(key=lambda sl: (score[self.slot_shard(sl)], sl))
                    for r in range(self.R):
                        cand[l, e, r] = copies[r % len(copies)]
        return cand

    def translate_device(self, ids: Array, w: Array, trans: np.ndarray):
        """Device-side `translate`: consumes the predictor's still-on-device
        ids/α [L, B, S, k] plus the (host-planned) translation table and
        returns device (slot_ids, weights). The decode hot loop uses this so
        the only per-step D2H sync left is the ids copy planning itself
        needs — the slot gather, replica pick, miss renormalization, and
        the re-upload of [L, B, S, k] overrides all stay on device."""
        return _translate_dev(jnp.asarray(self.replica_cand(trans)), ids, w)

    # ------------------------------------------------------------------
    def rebalance_homes(self) -> int:
        """Online load-aware placement: re-assign expert home shards by
        greedy LPT over the summed decayed α-mass EMA (heaviest expert
        first onto the lightest shard, capacity E/shards each), then
        migrate resident primaries toward their new homes.

        The move protocol never races readers: the OLD primary slot is
        demoted to a replica (it stays resident and readable until a later
        plan reclaims it), the NEW copy either promotes an existing replica
        on the target shard or uploads into a free/reclaimed slot through
        the normal pending-fence machinery — so every translation snapshot
        taken before, during, or after a move points at slots that still
        hold the expert's weights. Returns the number of primaries moved.
        """
        if self.shards <= 1 or self.tiered:
            # tiered stores skip rebalancing: a migrated primary's tier
            # would have to be re-derived per shard, and tiering already
            # does its own α-driven placement (promotion/demotion)
            return 0
        pf = self._prefetcher
        moved = 0
        with self._lock:
            ema = np.zeros((self.E,), np.float64)
            for arr in self.alpha_ema.values():
                ema += arr
            if ema.sum() <= 0.0:
                return 0
            cap = self.E // self.shards
            load = np.zeros((self.shards,), np.float64)
            count = np.zeros((self.shards,), np.int64)
            new_home = np.empty((self.E,), np.int32)
            for e in np.argsort(-ema, kind="stable"):
                open_sh = [m for m in range(self.shards) if count[m] < cap]
                m = min(open_sh, key=lambda m: (load[m], m))
                new_home[e] = m
                load[m] += ema[e]
                count[m] += 1
            if np.array_equal(new_home, self.home):
                return 0
            self.home = new_home
            pending: Dict[int, List[Tuple[int, int, int]]] = {
                s: [] for s in self.moe_subs
            }
            for (g, s), res in self.resident.items():
                reps = self.replicas[(g, s)]
                policies = self.policy[(g, s)]
                free = self.free[(g, s)]
                protected = set(self.pinned[(g, s)])
                if pf is not None:
                    protected |= pf.protected_experts(g, s)
                for e in list(res.keys()):
                    tgt = int(new_home[e])
                    cur = res[e] // self.S_loc
                    if cur == tgt:
                        continue
                    by_shard = reps.setdefault(e, {})
                    if tgt in by_shard:
                        # a live copy already sits on the new home: swap
                        # roles, no bytes move
                        new_slot = by_shard.pop(tgt)
                    else:
                        new_slot = (
                            free[tgt].pop() if free[tgt]
                            else self._reclaim_replica(g, s, tgt, protected)
                        )
                        if new_slot is None:
                            # target shard is full of primaries — leave the
                            # expert where it is; a later pass can move it
                            if not by_shard:
                                del reps[e]
                            continue
                        pending[s].append((g, new_slot, e))
                        self.stats.loads += 1
                    by_shard[cur] = res[e]   # old primary stays readable
                    res[e] = new_slot
                    policies[cur].forget(e)
                    policies[tgt].admit(e, float(ema[e]))
                    moved += 1
            if moved:
                self._epoch += 1
                self.stats.rebalance_moves += moved
            if pf is not None:
                pf.submit_loads(pending, priority=1)
            else:
                for s, items in pending.items():
                    self.commit_loads(s, items)
        return moved


# ---------------------------------------------------------------------------
# asynchronous prefetch pipeline
# ---------------------------------------------------------------------------


def _staged_put(x: np.ndarray) -> Array:
    """H2D transfer of one staged slab. Module-level so tests can inject a
    slow link (the concurrency suite monkeypatches this to model a saturated
    PCIe/ICI channel)."""
    return jax.device_put(x)


@dataclass
class PrefetchStats:
    """Overlap accounting for the async pipeline.

    `stall_s` is the only time the forward path actually lost: waiting on a
    ready fence for an expert whose upload had not landed yet. `transfer_s`
    is the background thread's busy time — the part of it that is not stall
    is transfer hidden behind compute, which is the pipeline's win."""

    submitted: int = 0          # tickets submitted
    uploads: int = 0            # experts uploaded by the transfer threads
    stall_s: float = 0.0        # consumer time blocked on ready fences
    transfer_s: float = 0.0     # background gather+upload busy time
    staging_waits: int = 0      # gathers that waited for a staging slab to drain
    warm_skipped: int = 0       # warming prefetches dropped (transfer backlog)
    stolen: int = 0             # jobs a fence found still queued and ran inline
    # fault-tolerance accounting (see "supervised transfer threads" below)
    upload_retries: int = 0     # failed upload attempts that were retried
    upload_failures: int = 0    # upload batches abandoned (retries exhausted)
    poisoned_fences: int = 0    # per-expert fences poisoned by abandonment
    thread_crashes: int = 0     # transfer-loop exceptions outside a job guard
    thread_restarts: int = 0    # supervised restarts (in-place or watchdog)
    sync_fallbacks: int = 0     # uploads committed via the sync path (degraded
                                # shards, dead-thread drains, inline producers)
    job_errors: int = 0         # callable-job (K/V page-in) exceptions caught
    degraded: int = 0           # shards currently in degraded (sync) mode
    # per-shard upload counts under expert-parallel sharded pools (one
    # transfer queue/thread per shard; `shards` is set by the pipeline so
    # the summary emits a row per shard — zeros included, since an idle
    # shard under skewed expert load is exactly what the counter detects)
    shards: int = 1
    uploads_by_shard: Dict[int, int] = field(default_factory=dict)

    @property
    def overlap_s(self) -> float:
        return max(0.0, self.transfer_s - self.stall_s)

    def reset(self) -> None:
        self.submitted = self.uploads = self.staging_waits = 0
        self.warm_skipped = self.stolen = 0
        self.upload_retries = self.upload_failures = self.poisoned_fences = 0
        self.thread_crashes = self.thread_restarts = 0
        self.sync_fallbacks = self.job_errors = 0
        # `degraded` is a point-in-time shard count, not an event counter —
        # a reset between bench phases must not forget a degraded shard
        self.stall_s = self.transfer_s = 0.0
        self.uploads_by_shard = {}

    def summary(self) -> Dict[str, float]:
        out = {
            "prefetch_submitted": float(self.submitted),
            "prefetch_uploads": float(self.uploads),
            "prefetch_stall_s": self.stall_s,
            "prefetch_transfer_s": self.transfer_s,
            "prefetch_overlap_s": self.overlap_s,
            "prefetch_staging_waits": float(self.staging_waits),
            "prefetch_warm_skipped": float(self.warm_skipped),
            "prefetch_stolen": float(self.stolen),
            "prefetch_upload_retries": float(self.upload_retries),
            "prefetch_upload_failures": float(self.upload_failures),
            "prefetch_poisoned_fences": float(self.poisoned_fences),
            "prefetch_thread_crashes": float(self.thread_crashes),
            "prefetch_thread_restarts": float(self.thread_restarts),
            "prefetch_sync_fallbacks": float(self.sync_fallbacks),
            "prefetch_job_errors": float(self.job_errors),
            "prefetch_degraded_shards": float(self.degraded),
        }
        if self.shards > 1:
            for sh in range(self.shards):
                out[f"prefetch_uploads_shard{sh}"] = float(
                    self.uploads_by_shard.get(sh, 0)
                )
        return out


class _CallableJob:
    """A non-expert transfer job on the pipeline's per-shard queues.

    `fn` runs on the shard's transfer thread (typically staging an H2D
    page copy for the paged K/V pool — see core/residency.py), then `done`
    is set. Callable jobs ride the same three-class priority deques as
    expert upload jobs, so K/V page-ins and expert slabs share one
    bandwidth arbitration: an urgent decode fence still drains ahead of a
    lookahead page-in, and a page-in ahead of warming."""

    __slots__ = ("fn", "done")

    def __init__(self, fn: Callable[[], None]):
        self.fn = fn
        self.done = threading.Event()


class PrefetchTicket:
    """Handle for one submitted prediction: a translation-table snapshot plus
    the ready fences the consumer must clear before forwarding with it.

    Protocol: `submit` plans slots immediately (so `trans` is final at
    submission), uploads land asynchronously; the consumer calls `wait()`
    (or `wait_experts` for a partial fence) before running the forward, and
    `release()` after the forward has consumed the slots — until then every
    expert the ticket references is protected from eviction."""

    def __init__(
        self,
        pipeline: "PrefetchPipeline",
        seq: int,
        trans: np.ndarray,
        needed: Dict[int, np.ndarray],
        fences: List[Tuple[Tuple[int, int, int], threading.Event]],
        protect: bool,
    ):
        self._pipeline = pipeline
        self.seq = seq
        self.trans = trans
        self.needed = needed                  # layer -> expert ids planned
        self._fences = fences                 # ((g, s, e), event) to clear
        self._protect = protect
        # queued per-shard transfer jobs [(shard, {sub: rows})] (stealable)
        self._job: Optional[List[Tuple[int, dict]]] = None
        self.released = False
        # True once any of this ticket's fences was poisoned (its upload
        # abandoned after exhausted retries). The ticket stays consumable —
        # wait()'s replan already healed trans — but the flag lets the
        # serving layer count fault-impacted ticks.
        self.failed = False

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Make the ticket consumable: clear its ready fences AND re-plan
        any needed expert whose prefetch was dropped (slot contention with
        other outstanding tickets), evicted since planning, or rolled back
        by a poisoned (abandoned) upload — the consuming ticket has
        priority, so the final residency matches what the synchronous path
        would have loaded. Refreshes `trans` in place.

        Returns False if `timeout` expired first. CONTRACT: a False return
        means `trans` may still reference experts that are not resident —
        the caller must either call `wait()` again, or fall back to the
        synchronous path (`store.prepare(...)`) and use ITS translation.
        Never spin on wait(timeout) in a tight loop, and never forward with
        a timed-out ticket's trans: the renormalized miss handling would
        silently drop the unresident experts' mass."""
        return self._pipeline._refresh(self, timeout)

    def wait_experts(self, l: int, experts) -> None:
        """Partial fence: block only on uploads of `experts` at MoE layer
        `l` — experts already resident (no pending upload) never block.
        A poisoned fence among them escalates to the full wait(): the
        expert's slot was rolled back, so the partial fence alone no longer
        guarantees a consumable translation."""
        g, s = self._pipeline.store.layer_to_gs(l)
        want = {int(e) for e in experts}
        t0 = time.perf_counter()
        poisoned = False
        for (fg, fs, fe), ev in self._fences:
            if (fg, fs) == (g, s) and fe in want:
                ev.wait()
                poisoned |= bool(getattr(ev, "poisoned", False))
        self._pipeline.stats.stall_s += time.perf_counter() - t0
        if poisoned:
            self._pipeline._refresh(self)

    def release(self) -> None:
        """Drop eviction protection (call after the forward consumed the
        slots this ticket translated)."""
        if not self.released:
            self._pipeline._release(self)
            self.released = True


class PrefetchPipeline:
    """Async double-buffered expert prefetch over one ExpertStore.

    A background transfer thread consumes planned load batches: it gathers
    host weights (int8 + scales under `host_quant="int8"`) into one of
    `staging_buffers` reusable host slabs, ships the slab with
    `jax.device_put`, and scatters it into the device slot pool — all
    overlapped against whatever the forward thread is computing. Slot
    *planning* happens synchronously at `submit` (it is cheap, pure-Python
    bookkeeping), so the returned ticket carries the final translation
    table; only the byte movement is deferred.

    Over an expert-parallel sharded store the pipeline fans each ticket out
    into PER-SHARD transfer queues: one transfer thread + staging-slab ring
    per shard (the software analogue of one H2D/ICI stream per device), so
    a backlogged shard never head-of-line-blocks another shard's uploads,
    and a ticket's ready fences clear shard-by-shard as each device's slab
    lands. Jobs route by the DESTINATION SLOT's shard (slot // S_loc), and
    fences are per-upload: a hot expert may have several copies in flight
    at once (its primary plus replicas on other shards), each with its own
    ready event — a consumer fencing on the expert waits for all of them,
    so no copy a translation may pick is ever observed half-written.

    Correctness invariants:
      * an expert referenced by an unreleased ticket, or with an upload in
        flight, is never an eviction victim (so no slot a pending forward
        will read is ever reused);
      * a ready fence fires only after *all* expert tensors (w_in, w_gate,
        w_out) for that upload have been committed — a consumer can never
        observe a half-written slot;
      * a staging slab is reused only after the device acknowledged the
        previous transfer out of it (the double-buffer fence).
    """

    # CPython's default thread switch interval (5 ms) starves the transfer
    # thread's short numpy/dispatch ops behind the serving loop's Python
    # work, adding ~10 ms of pure scheduling latency per upload; a serving
    # process with a transfer thread wants sub-ms handoff. The interval is
    # process-global, so it is refcounted and restored at close().
    SWITCH_INTERVAL_S = 0.0005
    _switch_refs = 0
    _switch_saved: Optional[float] = None
    _switch_lock = threading.Lock()

    @classmethod
    def _acquire_switch_interval(cls) -> None:
        with cls._switch_lock:
            if cls._switch_refs == 0 and sys.getswitchinterval() > cls.SWITCH_INTERVAL_S:
                cls._switch_saved = sys.getswitchinterval()
                sys.setswitchinterval(cls.SWITCH_INTERVAL_S)
            cls._switch_refs += 1

    @classmethod
    def _release_switch_interval(cls) -> None:
        with cls._switch_lock:
            cls._switch_refs -= 1
            if cls._switch_refs == 0 and cls._switch_saved is not None:
                sys.setswitchinterval(cls._switch_saved)
                cls._switch_saved = None

    @classmethod
    def maybe_create(
        cls,
        store: ExpertStore,
        cfg,
        prefetch_depth: Optional[int] = None,
        staging_buffers: Optional[int] = None,
        faults=None,
    ) -> Optional["PrefetchPipeline"]:
        """Resolve the prefetch knobs (explicit args > cfg.prefetch > off)
        and build a pipeline, or return None for the synchronous path —
        the single source of the precedence rule the engines and the
        request server all share. `faults` (a FaultPlan) and the retry /
        degradation knobs ride cfg.prefetch."""
        depth = prefetch_depth if prefetch_depth is not None else (
            cfg.prefetch.depth if cfg.prefetch.enabled else 0
        )
        nbuf = (staging_buffers if staging_buffers is not None
                else cfg.prefetch.staging_buffers)
        if depth <= 0:
            return None
        pc = cfg.prefetch
        return cls(
            store, depth, nbuf, faults=faults,
            max_retries=getattr(pc, "max_retries", 3),
            backoff_s=getattr(pc, "backoff_s", 0.002),
            degrade_after=getattr(pc, "degrade_after", 3),
        )

    def __init__(
        self,
        store: ExpertStore,
        depth: int = 2,
        staging_buffers: int = 2,
        faults=None,                    # Optional[FaultPlan]
        max_retries: int = 3,           # upload attempts = 1 + max_retries
        backoff_s: float = 0.002,       # base of the exponential backoff
        degrade_after: int = 3,         # consecutive failures -> sync mode
        max_thread_restarts: int = 3,   # in-place restarts before a shard
                                        # thread is declared dead (watchdog
                                        # revive() is the only way back)
    ):
        assert store._prefetcher is None, "store already has a prefetch pipeline"
        self._acquire_switch_interval()
        self.store = store
        self.shards = store.shards
        self.depth = max(1, depth)
        self.n_staging = max(1, staging_buffers)
        self.faults = faults
        self.max_retries = max(0, max_retries)
        self.backoff_s = backoff_s
        self.degrade_after = max(1, degrade_after)
        self.max_thread_restarts = max(0, max_thread_restarts)
        self.stats = PrefetchStats(shards=self.shards)
        self._lock = store._lock
        # three-class transfer queue PER SHARD: urgent consumer jobs (a
        # fence wait is imminent — decode ticks) > pre-submitted consumer
        # jobs (prefill tickets whose fence comes after overlapped compute)
        # > warming jobs — so neither admission bursts nor lookahead prefill
        # ever head-of-line-blocks the decode path. One condition guards all
        # queues; each shard's transfer thread drains only its own.
        self._jobs_cv = threading.Condition()
        self._jobs: List[List[collections.deque]] = [
            [collections.deque() for _ in range(3)] for _ in range(self.shards)
        ]
        # (g, s) -> expert -> {dest slot: ready event} for uploads still in
        # flight (a replicated expert can have one upload per hosting shard
        # in flight simultaneously — each slot gets its own fence)
        self._pending: Dict[
            Tuple[int, int], Dict[int, Dict[int, threading.Event]]
        ] = collections.defaultdict(dict)
        # (g, s) -> expert -> refcount from unreleased tickets
        self._refs: Dict[Tuple[int, int], collections.Counter] = (
            collections.defaultdict(collections.Counter)
        )
        # staging slabs, per shard × buffer: (sub, tensor[, "scale"]) ->
        # host slab, plus the device arrays that must land before the slab
        # is reused. Each shard's thread owns its ring exclusively.
        self._staging: List[List[Dict[tuple, np.ndarray]]] = [
            [{} for _ in range(self.n_staging)] for _ in range(self.shards)
        ]
        self._staging_inflight: List[List[List[Array]]] = [
            [[] for _ in range(self.n_staging)] for _ in range(self.shards)
        ]
        self._buf_i = [0] * self.shards
        self._seq = 0
        self._closed = False
        # supervision state (guarded by _jobs_cv like the queues):
        #   degraded — the shard's uploads go through the synchronous
        #     commit path (its thread may still be alive and draining);
        #   dead     — the shard's thread exhausted its restarts and
        #     exited: producers commit that shard's work inline;
        #   current job / start time — what each thread is holding, so the
        #     supervisor can poison a crashed job's fences and the
        #     watchdog can spot a stalled one.
        self._degraded = [False] * self.shards
        self._dead = [False] * self.shards
        self._fail_streak = [0] * self.shards
        self._crash_count = [0] * self.shards
        self._current_job: List[Optional[object]] = [None] * self.shards
        self._job_started = [0.0] * self.shards
        self._threads = [
            threading.Thread(
                target=self._transfer_main, args=(m,),
                name=f"sida-prefetch-{m}", daemon=True,
            )
            for m in range(self.shards)
        ]
        store._prefetcher = self
        for t in self._threads:
            t.start()

    @property
    def _thread(self) -> threading.Thread:
        """Back-compat alias: the (first) transfer thread."""
        return self._threads[0]

    # -- planning side (consumer threads) -------------------------------
    def protected_experts(self, g: int, s: int) -> Set[int]:
        """Experts at (g, s) that must survive eviction: referenced by an
        unreleased ticket or mid-upload. Caller holds the store lock."""
        prot = set(self._refs[(g, s)].keys())
        prot.update(self._pending[(g, s)].keys())
        return prot

    def events_for(self, needed: Dict[int, np.ndarray]):
        """Ready fences covering `needed` (layer -> expert ids): one entry
        per in-flight upload of a needed expert (a replicated expert
        contributes every copy's fence). Caller holds the lock."""
        fences = []
        for l, ids in needed.items():
            g, s = self.store.layer_to_gs(l)
            pend = self._pending[(g, s)]
            for e in ids:
                for ev in pend.get(int(e), {}).values():
                    fences.append(((g, s, int(e)), ev))
        return fences

    def inflight(self) -> Dict[Tuple[int, int], Set[int]]:
        """Snapshot of experts with uploads in flight (for cache-affinity)."""
        with self._lock:
            return {k: set(v.keys()) for k, v in self._pending.items() if v}

    def cache_affinity(self, table: HashTable) -> float:
        """Affinity that credits in-flight prefetches, not just residency —
        the request scheduler ranks queued work with this."""
        return self.store.cache_affinity(table, inflight=self.inflight())

    @property
    def affinity_epoch(self) -> Tuple[int, int]:
        """Version key for memoizing `cache_affinity`: the store's
        residency epoch plus the upload counter (uploads retire pending
        entries, which the in-flight credit reads)."""
        return (self.store._epoch, self.stats.uploads)

    def submit(
        self, table: HashTable, protect: bool = True,
        priority: Optional[int] = None,
    ) -> Optional[PrefetchTicket]:
        """Plan slots for `table` now; enqueue its uploads for the transfer
        thread. `protect=False` submits a fire-and-forget warming prefetch
        (admission-time): uploads happen and are fenced by later consumers,
        but nothing is pinned, so a warmed expert may be evicted before use
        (a performance miss, never a correctness hazard). Warming submits
        return None without planning anything when the transfer queue is
        backlogged — warming is opportunistic, it must never add pressure.

        `priority` (default: 0 for protected, 2 for warming) picks the
        transfer class: 0 = urgent (fence wait imminent), 1 = pre-submitted
        lookahead (fence comes after overlapped compute), 2 = warming."""
        assert not self._closed, "pipeline is closed"
        prio = priority if priority is not None else (0 if protect else 2)
        if not protect:
            # backpressure only against the shards this table would actually
            # upload to (its experts' home shards) — one backlogged shard
            # must not suppress warming for idle devices. Reading the active
            # experts is side-effect-free; what warming skips is the
            # *planning* (slot assignment/eviction), which would commit the
            # store to uploads that cannot be dropped. Unsharded stores keep
            # the plain one-queue depth check (no scan).
            if self.shards == 1:
                dests = (0,)
            else:
                ids = np.unique(table.expert_ids)  # one pass, order-free
                dests = (
                    set(int(s) for s in np.unique(self.store.home[ids]))
                    if ids.size else set(range(self.shards))
                )
            with self._jobs_cv:
                if any(len(self._jobs[sh][2]) >= self.depth for sh in dests):
                    self.stats.warm_skipped += 1
                    return None
        with self._lock:
            self._seq += 1
            seq = self._seq
            trans, pending, needed = self.store.plan(
                table, protect_fn=self.protected_experts
            )
            # fan the planned loads out per DESTINATION shard (derived from
            # the slot — replica uploads of one expert land on several
            # shards): each shard's rows form one job on that shard's
            # transfer queue (per-device uploads proceed independently;
            # fences clear shard-by-shard)
            jobs: Dict[int, Dict[int, List[tuple]]] = {}
            for s, items in pending.items():
                for g, slot, e in items:
                    ev = threading.Event()
                    self._pending[(g, s)].setdefault(e, {})[slot] = ev
                    sh = self.store.slot_shard(slot)
                    jobs.setdefault(sh, {}).setdefault(s, []).append(
                        (g, slot, e, ev)
                    )
            if protect:
                for l, ids in needed.items():
                    g, s = self.store.layer_to_gs(l)
                    self._refs[(g, s)].update(int(e) for e in ids)
            # the ticket fences on every needed expert still in flight —
            # whether this submit started the upload or an earlier one did
            fences = self.events_for(needed)
            self.stats.submitted += 1
        ticket = PrefetchTicket(self, seq, trans, needed, fences, protect)
        if jobs:
            # outside the store lock: the put may block at `depth` (consumer
            # backpressure); a planned job is never dropped — its slots are
            # already assigned, so the upload must eventually happen
            ticket._job = [(sh, job) for sh, job in jobs.items()]
            inline: List[Tuple[int, dict]] = []
            with self._jobs_cv:
                for sh, job in jobs.items():
                    if protect:
                        # a dead shard's queue never drains: the wait must
                        # break on _dead (set under this cv + notify_all)
                        # or the producer deadlocks against a ghost
                        while (
                            len(self._jobs[sh][prio]) >= self.depth
                            and not self._dead[sh] and not self._closed
                        ):
                            self._jobs_cv.wait()
                    if self._dead[sh]:
                        inline.append((sh, job))
                        continue
                    self._jobs[sh][prio].append(job)
                self._jobs_cv.notify_all()
            for sh, job in inline:
                # no consumer thread: the producer pays for the upload
                # itself via the sync path (degraded mode's whole contract)
                self._commit_sync(sh, job)
        return ticket

    def submit_job(
        self, fn: Callable[[], None], shard: int = 0, priority: int = 1,
    ) -> threading.Event:
        """Enqueue an arbitrary transfer callable on `shard`'s queue at
        `priority` (same 0/1/2 classes as expert uploads) and return its
        done fence. This is how the K/V page pool rides the pipeline: a
        page-in stages its H2D copy on the transfer thread, and the fence
        guarantees a decode tick never reads a half-uploaded page."""
        assert not self._closed, "pipeline is closed"
        job = _CallableJob(fn)
        with self._jobs_cv:
            dead = self._dead[shard]
            if not dead:
                self._jobs[shard][priority].append(job)
                self._jobs_cv.notify_all()
        if dead:
            # no consumer: run inline so the caller's done-fence still fires
            try:
                fn()
            except Exception:
                with self._jobs_cv:
                    self.stats.job_errors += 1
            finally:
                job.done.set()
        return job.done

    def submit_loads(
        self,
        pending: Dict[int, List[Tuple[int, int, int]]],
        priority: int = 1,
    ) -> None:
        """Enqueue pre-planned {sub: [(g, slot, e)]} uploads (the store's
        `rebalance_homes` migrations ride this): each load gets a pending
        fence and lands on its destination slot's shard queue. No
        backpressure — the caller holds the store lock, and a rebalance
        must never park the serve loop against its own transfer thread."""
        assert not self._closed, "pipeline is closed"
        jobs: Dict[int, Dict[int, List[tuple]]] = {}
        for s, items in pending.items():
            for g, slot, e in items:
                ev = threading.Event()
                self._pending[(g, s)].setdefault(e, {})[slot] = ev
                sh = self.store.slot_shard(slot)
                jobs.setdefault(sh, {}).setdefault(s, []).append(
                    (g, slot, e, ev)
                )
        if jobs:
            inline: List[Tuple[int, dict]] = []
            with self._jobs_cv:
                for sh, job in jobs.items():
                    if self._dead[sh]:
                        inline.append((sh, job))
                    else:
                        self._jobs[sh][priority].append(job)
                self._jobs_cv.notify_all()
            for sh, job in inline:
                # caller already holds the (reentrant) store lock; the sync
                # commit nests under it, and the fences fire before return
                self._commit_sync(sh, job)

    def _upload_done(
        self, g: int, s: int, slot: int, e: int, ev: threading.Event
    ) -> None:
        """Retire one committed upload's pending entry (caller holds the
        lock; the identity check guards against a newer upload of the same
        (expert, slot) registered after an evict+reload)."""
        pend = self._pending[(g, s)]
        slots_ev = pend.get(e)
        if slots_ev is not None and slots_ev.get(slot) is ev:
            del slots_ev[slot]
            if not slots_ev:
                del pend[e]

    def _steal(self, ticket: PrefetchTicket) -> None:
        """If any of the ticket's per-shard transfer jobs are still queued
        when its fence is reached, pop them and commit inline on the
        consumer thread — the fence was about to pay for the whole transfer
        anyway, and running it here skips the thread handoff (a starved
        transfer thread can never make the async path slower than
        synchronous uploads). Jobs a transfer thread already owns fall
        through to the fence."""
        entries = ticket._job
        if entries is None:
            return
        ticket._job = None
        stolen: List[Tuple[int, dict]] = []
        with self._jobs_cv:
            for sh, job in entries:
                found = False
                for q in self._jobs[sh]:
                    for k, item in enumerate(q):
                        if item is job:
                            del q[k]
                            found = True
                            break
                    if found:
                        break
                if found:
                    stolen.append((sh, job))
            if stolen:
                # a producer may be parked in submit() backpressure waiting
                # for exactly one of these queue slots — wake it
                self._jobs_cv.notify_all()
        if not stolen:
            return
        with self._lock:
            for sh, job in stolen:
                for s, rows in job.items():
                    self.store.commit_loads(
                        s, [(g, sl, e) for g, sl, e, _ in rows]
                    )
                    for g, sl, e, ev in rows:
                        self._upload_done(g, s, sl, e, ev)
                n = sum(len(r) for r in job.values())
                self.stats.uploads += n
                self.stats.uploads_by_shard[sh] = (
                    self.stats.uploads_by_shard.get(sh, 0) + n
                )
            self.stats.stolen += 1
        for _, job in stolen:
            for rows in job.values():
                for *_, ev in rows:
                    ev.set()

    def _refresh(self, ticket: PrefetchTicket, timeout: Optional[float] = None) -> bool:
        """Consume-time reconciliation for one ticket (see `wait`).

        Loop until every needed expert is resident (or genuinely
        unplannable, e.g. pinned-full — where the sync path drops too):
        re-plan missing experts with priority over later tickets' refs
        (their refresh will re-fetch in turn), never evicting an expert
        whose upload is mid-flight; commit re-planned loads synchronously;
        then clear ready fences and rebuild the translation snapshot from
        live residency (an expert can have moved slots via evict+reload).
        The elapsed time is the pipeline's stall — the only upload time
        the forward path actually pays under async prefetch."""
        store = self.store
        t0 = time.perf_counter()
        self._steal(ticket)

        def _left() -> Optional[float]:
            if timeout is None:
                return None
            return max(0.0, timeout - (time.perf_counter() - t0))

        ok = True
        for _ in range(64):  # in-flight uploads strictly drain between rounds
            drain: List[threading.Event] = []
            with self._lock:
                progressed_all = True
                for l, ids in ticket.needed.items():
                    g, s = store.layer_to_gs(l)
                    res = store.resident[(g, s)]
                    missing = [int(e) for e in ids if int(e) not in res]
                    if not missing:
                        continue
                    pend = self._pending[(g, s)]
                    # protect own needed residents + mid-copy uploads; later
                    # tickets' prefetched experts are fair eviction game
                    extra = set(pend.keys()) | {int(e) for e in ids}
                    loads = store.plan_layer(
                        l, np.asarray(missing, np.int64), extra_protected=extra
                    )
                    if loads:
                        store.commit_loads(s, loads)
                    if any(int(e) not in res for e in missing):
                        progressed_all = False
                        drain.extend(
                            ev for d in pend.values() for ev in d.values()
                        )
                fences = self.events_for(ticket.needed)
            poisoned = False
            for _, ev in fences:
                if not ev.wait(_left()):
                    ok = False
                    break
                poisoned |= bool(getattr(ev, "poisoned", False))
            # a poisoned fence means the expert was rolled back between the
            # residency check and the wait — one more round replans it
            if not ok or (progressed_all and not drain and not poisoned):
                break
            done = all(ev.wait(_left()) for ev in drain)
            if not done:
                ok = False
                break
            if not drain:
                break  # unplannable without pending uploads: sync drops too
        with self._lock:
            for l in ticket.needed:
                ticket.trans[l] = store.trans_row(l)
        if not ticket.failed and any(
            getattr(ev, "poisoned", False) for _, ev in ticket._fences
        ):
            ticket.failed = True   # mark: an upload this ticket fenced on
            # was abandoned (the replan above already healed trans)
        self.stats.stall_s += time.perf_counter() - t0
        return ok

    def _release(self, ticket: PrefetchTicket) -> None:
        if not ticket._protect:
            return
        with self._lock:
            for l, ids in ticket.needed.items():
                g, s = self.store.layer_to_gs(l)
                refs = self._refs[(g, s)]
                refs.subtract(int(e) for e in ids)
                for e in [e for e, c in refs.items() if c <= 0]:
                    del refs[e]

    # -- transfer side (per-shard background threads) -------------------
    def _next_job(self, shard: int) -> Optional[Dict[int, List[tuple]]]:
        with self._jobs_cv:
            while True:
                q = next((q for q in self._jobs[shard] if q), None)
                if q is not None:
                    job = q.popleft()
                    break
                if self._closed:
                    return None
                self._jobs_cv.wait()
            self._jobs_cv.notify_all()
            return job

    def _transfer_main(self, shard: int) -> None:
        """Supervised thread body: restart `_transfer_loop` after a crash
        (an exception escaping the per-job guards — including an injected
        `thread:crash`), poisoning the fences of whatever job the loop died
        holding so its waiters replan instead of hanging. A shard that
        crashes more than `max_thread_restarts` times is declared dead: its
        queue drains synchronously here, producers commit its work inline
        from then on, and only a watchdog `revive()` brings the async path
        back."""
        while True:
            try:
                self._transfer_loop(shard)
                return                      # clean close() exit
            except Exception:
                job = self._current_job[shard]
                self._current_job[shard] = None
                with self._jobs_cv:
                    self.stats.thread_crashes += 1
                    self._crash_count[shard] += 1
                    crashes = self._crash_count[shard]
                    closed = self._closed
                if job is not None:
                    self._fail_job(shard, job)
                if closed:
                    return
                if crashes > self.max_thread_restarts:
                    with self._jobs_cv:
                        self._dead[shard] = True
                        self._set_degraded(shard, True)
                        # wake producers parked in submit() backpressure —
                        # they re-check _dead and commit inline
                        self._jobs_cv.notify_all()
                    self._drain_sync(shard)
                    return
                with self._jobs_cv:
                    self.stats.thread_restarts += 1

    def _transfer_loop(self, shard: int) -> None:
        while True:
            job = self._next_job(shard)
            if job is None:
                return
            self._job_started[shard] = time.perf_counter()
            self._current_job[shard] = job
            if self.faults is not None:
                self.faults.inject("thread")   # outside the per-job guards:
                # the raise kills this loop; _transfer_main supervises
            t0 = time.perf_counter()
            if isinstance(job, _CallableJob):
                try:
                    job.fn()
                except Exception:
                    # a failed page-in (or other callable) must not kill the
                    # shard thread; its waiter sees `done` and re-checks the
                    # state the callable was meant to establish
                    with self._jobs_cv:
                        self.stats.job_errors += 1
                finally:
                    job.done.set()
            else:
                self._run_upload_job(shard, job)
            self._current_job[shard] = None
            dt = time.perf_counter() - t0
            with self._jobs_cv:  # shard threads share the stats object
                self.stats.transfer_s += dt

    def _run_upload_job(self, shard: int, job: Dict[int, List[tuple]]) -> None:
        """Upload one expert job, sub-batch by sub-batch, retrying failed
        attempts with bounded exponential backoff. Exhausted retries poison
        the batch (see `_fail_rows`); a degraded shard skips the staged
        path entirely and commits synchronously."""
        if self._degraded[shard]:
            self._commit_sync(shard, job)
            return
        for s, rows in job.items():
            attempt = 0
            while True:
                try:
                    self._upload(shard, s, rows)
                    with self._jobs_cv:
                        self._fail_streak[shard] = 0
                    break
                except Exception:
                    attempt += 1
                    if attempt > self.max_retries:
                        self._fail_rows(shard, s, rows)
                        break
                    with self._jobs_cv:
                        self.stats.upload_retries += 1
                    # retries re-stage from the host masters, so a partial
                    # commit from the failed attempt is simply overwritten
                    time.sleep(self.backoff_s * (2.0 ** (attempt - 1)))

    def _set_degraded(self, shard: int, value: bool) -> None:
        """Flip one shard's degraded flag, keeping the stats count exact.
        Caller holds `_jobs_cv`."""
        if self._degraded[shard] != value:
            self._degraded[shard] = value
            self.stats.degraded += 1 if value else -1

    def degraded_fraction(self) -> float:
        """Fraction of shards in degraded (sync-fallback) mode — the
        admission controller shrinks its shedding threshold by this, so
        transfer faults surface as early rejections instead of SLO misses."""
        return sum(self._degraded) / self.shards

    def _fail_rows(self, shard: int, s: int, rows: List[tuple]) -> None:
        """Abandon one upload batch after exhausted retries: roll every
        planned slot back to the free list (the residency published at plan
        time is withdrawn), retire the pending entries, then POISON the
        fences — each event fires with `.poisoned = True`, so waiters
        (`_refresh`, `prepare`, `wait_experts`) replan the experts instead
        of blocking forever or consuming a slot whose bytes never landed.
        `degrade_after` consecutive abandonments flip the shard to the
        synchronous path."""
        with self._lock:
            for g, slot, e, ev in rows:
                self.store.rollback_upload(g, s, slot, e)
                self._upload_done(g, s, slot, e, ev)
            self.stats.upload_failures += 1
            self.stats.poisoned_fences += len(rows)
        with self._jobs_cv:
            self._fail_streak[shard] += 1
            if self._fail_streak[shard] >= self.degrade_after:
                self._set_degraded(shard, True)
        for *_, ev in rows:
            ev.poisoned = True       # before set(): waiters never see a
            ev.set()                 # fired-but-unpoisoned abandoned fence

    def _fail_job(self, shard: int, job) -> None:
        """Poison a whole crashed job (rows may be partially uploaded —
        `_fail_rows` rolls back only mappings still pointing at the planned
        slot, and `_upload_done`'s identity check skips retired entries)."""
        if isinstance(job, _CallableJob):
            job.done.set()
            return
        for s, rows in job.items():
            self._fail_rows(shard, s, rows)

    def _commit_sync(self, shard: int, job: Dict[int, List[tuple]]) -> None:
        """The degraded path: commit one job's uploads through the
        synchronous `commit_loads` (host gather -> device write inline, no
        staging ring, no injected upload faults) — byte-identical to what
        the async path would have landed, just not overlapped."""
        evs: List[threading.Event] = []
        with self._lock:
            for s, rows in job.items():
                self.store.commit_loads(
                    s, [(g, sl, e) for g, sl, e, _ in rows]
                )
                for g, sl, e, ev in rows:
                    self._upload_done(g, s, sl, e, ev)
                    evs.append(ev)
            n = sum(len(r) for r in job.values())
            self.stats.uploads += n
            self.stats.uploads_by_shard[shard] = (
                self.stats.uploads_by_shard.get(shard, 0) + n
            )
            self.stats.sync_fallbacks += n
        for ev in evs:
            ev.set()

    def _drain_sync(self, shard: int) -> None:
        """Drain `shard`'s queues on the calling thread via the synchronous
        path — the dead-thread / close-time fallback that keeps the 'a
        planned job is never dropped' invariant without a transfer thread."""
        while True:
            with self._jobs_cv:
                q = next((q for q in self._jobs[shard] if q), None)
                if q is None:
                    return
                job = q.popleft()
                self._jobs_cv.notify_all()
            if isinstance(job, _CallableJob):
                try:
                    job.fn()
                except Exception:
                    with self._jobs_cv:
                        self.stats.job_errors += 1
                finally:
                    job.done.set()
            else:
                self._commit_sync(shard, job)

    # -- watchdog (the server run loop calls this on an interval) -------
    def watchdog(self, max_job_age_s: Optional[float] = None) -> Tuple[int, int]:
        """Liveness + job-age monitor: revive dead shard threads (supervised
        restart) and count jobs a live thread has held longer than
        `max_job_age_s` (a stalled link — Python can't preempt the thread,
        but the count surfaces in telemetry and the caller may degrade the
        shard). Returns (revived, stalled)."""
        revived = stalled = 0
        now = time.perf_counter()
        for m in range(self.shards):
            if self._dead[m] and not self._closed:
                revived += self.revive(m)
            elif (
                max_job_age_s is not None
                and self._current_job[m] is not None
                and now - self._job_started[m] > max_job_age_s
            ):
                stalled += 1
        return revived, stalled

    def revive(self, shard: int) -> int:
        """Supervised restart of a dead shard thread: drain anything queued
        meanwhile, spawn a fresh thread, and lift degraded mode (probation —
        a still-faulty link just re-degrades after `degrade_after` more
        failures). Returns 1 iff a thread was started."""
        with self._jobs_cv:
            if self._closed or not self._dead[shard]:
                return 0
            if self._threads[shard].is_alive():
                return 0
            self._dead[shard] = False
            self._set_degraded(shard, False)
            self._fail_streak[shard] = 0
            self._crash_count[shard] = 0
            t = threading.Thread(
                target=self._transfer_main, args=(shard,),
                name=f"sida-prefetch-{shard}", daemon=True,
            )
            self._threads[shard] = t
            self.stats.thread_restarts += 1
        t.start()
        return 1

    def _stage(
        self,
        buf: Dict[tuple, np.ndarray],
        key: tuple,
        arr: np.ndarray,
        gs: np.ndarray,
        es: np.ndarray,
    ) -> np.ndarray:
        """Gather rows (g, e) of a host tensor [G, E, ...] straight into
        this buffer's persistent slab (grown on demand), so H2D always
        reads from a stable, reusable host region — the staging write."""
        if self.faults is not None:
            self.faults.inject("host_read")   # a failed host-master read
        n = len(gs)
        tail = arr.shape[2:]
        slab = buf.get(key)
        if (
            slab is None or slab.shape[0] < n
            or slab.shape[1:] != tail or slab.dtype != arr.dtype
        ):
            slab = np.empty((n,) + tail, dtype=arr.dtype)
            buf[key] = slab
        view = slab[:n]
        flat = arr.reshape((-1,) + tail)
        np.take(flat, gs.astype(np.int64) * arr.shape[1] + es, axis=0, out=view)
        return view

    def _upload(self, shard: int, s: int, rows: List[tuple]) -> None:
        if self.faults is not None:
            # one schedulable operation per upload batch: `fail` raises
            # (retried with backoff by _run_upload_job), `stall` sleeps
            # (models a saturated or wedged H2D link)
            self.faults.inject("upload")
        store = self.store
        i = self._buf_i[shard]
        self._buf_i[shard] = (i + 1) % self.n_staging
        # double-buffer fence: the slab is free once the device pulled the
        # previous transfer staged in it (per shard — each device's staging
        # ring drains independently)
        for dev in self._staging_inflight[shard][i]:
            ready = dev.is_ready() if hasattr(dev, "is_ready") else False
            if not ready:
                with self._jobs_cv:
                    self.stats.staging_waits += 1
            jax.block_until_ready(dev)
        staging = self._staging[shard][i]
        consumed: List[Array] = []

        # split the batch by destination tier: hot rows stage the int8
        # masters into the int8 pools, warm rows the int4 masters into the
        # q4 pools — both ride the same staging ring, and the batch's ready
        # fences fire only after BOTH commits (below)
        if store.S4:
            hot_rows = [r for r in rows if r[1] < store.S8]
            warm_rows = [r for r in rows if r[1] >= store.S8]
        else:
            hot_rows, warm_rows = rows, []
        gs = np.array([r[0] for r in hot_rows], np.int32)
        sl = np.array([r[1] for r in hot_rows], np.int32)
        es = np.array([r[2] for r in hot_rows], np.int32)
        # stage + H2D outside the lock: host arrays are immutable and the
        # staging slabs are transfer-thread-private, so only the slot-pool
        # read-modify-write below needs to serialize with other commits
        staged = []
        for t in EXPERT_TENSORS if hot_rows else ():
            w_view = self._stage(staging, (s, t), store.host[f"sub{s}"][t], gs, es)
            dev = _staged_put(w_view)
            consumed.append(dev)
            nbytes = w_view.nbytes
            dscale = None
            if store.quant == "int8":
                s_view = self._stage(
                    staging, (s, t, "scale"), store.host_scale[f"sub{s}"][t], gs, es
                )
                dscale = _staged_put(s_view)
                consumed.append(dscale)
                nbytes += s_view.nbytes
            staged.append((t, dev, dscale, nbytes))
        staged_warm = []
        if warm_rows:
            gs4 = np.array([r[0] for r in warm_rows], np.int32)
            sl4 = np.array([r[1] - store.S8 for r in warm_rows], np.int32)
            es4 = np.array([r[2] for r in warm_rows], np.int32)
            for t in EXPERT_TENSORS:
                q_view = self._stage(
                    staging, (s, t, "q4"), store.host4[f"sub{s}"][t], gs4, es4
                )
                dq = _staged_put(q_view)
                consumed.append(dq)
                s_view = self._stage(
                    staging, (s, t, "q4scale"),
                    store.host4_scale[f"sub{s}"][t], gs4, es4,
                )
                ds4 = _staged_put(s_view)
                consumed.append(ds4)
                staged_warm.append((t, dq, ds4, q_view.nbytes + s_view.nbytes))
            dgs4, dsl4 = jnp.asarray(gs4), jnp.asarray(sl4)
        dgs, dsl = jnp.asarray(gs), jnp.asarray(sl)
        with self._lock:
            moe_p = store.serve_params["blocks"][f"sub{s}"]["moe"]
            for t, dev, dscale, nbytes in staged:
                store.stats.bytes_h2d += nbytes
                if store.quantized_slots:
                    # int8-native slots: commit the quantized slab and its
                    # scale plane directly — no on-device dequant hop, so the
                    # staged bytes are the resident bytes
                    moe_p[t] = store._set_cow(moe_p[t], dgs, dsl, dev)
                    moe_p[t + "_scale"] = store._set_cow(
                        moe_p[t + "_scale"], dgs, dsl, dscale
                    )
                elif dscale is not None:
                    moe_p[t] = store._set_q_cow(moe_p[t], dgs, dsl, dev, dscale)
                else:
                    moe_p[t] = store._set_cow(moe_p[t], dgs, dsl, dev)
            for t, dq, ds4, nbytes in staged_warm:
                store.stats.bytes_h2d += nbytes
                moe_p[t + "_q4"] = store._set_cow(
                    moe_p[t + "_q4"], dgs4, dsl4, dq
                )
                moe_p[t + "_q4_scale"] = store._set_cow(
                    moe_p[t + "_q4_scale"], dgs4, dsl4, ds4
                )
            # every tensor of every expert in this batch is committed:
            # ready fences may fire now (no half-written slot is observable)
            for g, slot, e, ev in rows:
                self._upload_done(g, s, slot, e, ev)
            self.stats.uploads += len(rows)
            self.stats.uploads_by_shard[shard] = (
                self.stats.uploads_by_shard.get(shard, 0) + len(rows)
            )
        self._staging_inflight[shard][i] = consumed
        for _, _, _, ev in rows:
            ev.set()

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        """Drain queued uploads and join every per-shard transfer thread.

        Idempotent, and safe after thread death: a crashed/dead shard's
        leftover jobs are committed synchronously here (so every fence and
        done-event the pipeline ever handed out fires before close
        returns), leftover pending entries are retired, and the staging
        rings are dropped — no poisoned-but-unreleased tickets, no leaked
        slabs."""
        if self._closed:
            return
        with self._jobs_cv:
            self._closed = True
            self._jobs_cv.notify_all()
        for t in self._threads:
            t.join()
        for m in range(self.shards):
            self._drain_sync(m)   # no-op for shards whose thread drained
        with self._lock:
            # anything still pending after the drains belongs to a job a
            # thread died holding mid-poison: fire the fences so no waiter
            # outlives the pipeline
            for pend in self._pending.values():
                for slots_ev in pend.values():
                    for ev in slots_ev.values():
                        ev.poisoned = True
                        ev.set()
                pend.clear()
        self._staging = [[] for _ in range(self.shards)]
        self._staging_inflight = [[] for _ in range(self.shards)]
        self.store._prefetcher = None
        self._release_switch_interval()

    def __enter__(self) -> "PrefetchPipeline":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
