"""Unified HBM residency: one budget, two demand-paged client pools.

SiDA-MoE's offloading thesis — device memory should hold what the data
actually activates, not what the architecture statically declares — is
applied here to the *other* large residency class: decode-time K/V state.
The `ExpertStore` already manages expert slot pools with host backing,
priority transfer queues, and ready fences; this module generalizes that
machinery into a residency manager with two clients:

* **expert slots** — unchanged, owned by `ExpertStore`/`PrefetchPipeline`;
* **K/V pages** — a shared device pool of fixed-size page blocks per
  attention sublayer, addressed through per-lane page tables
  (`KVPagePool`). Cold pages spill to host and page back in over the
  PrefetchPipeline's per-shard transfer queues (`submit_job`) under the
  same 3-class priorities as expert uploads, with fences so a decode tick
  never reads a half-uploaded page.

Device layout (built by `models.transformer.init_paged_cache`): per
attention sublayer one pool ``kp``/``vp`` of shape [G, P+1, page, K, D].
Page id P is the **trash page**: the pool is shared across lanes, so a
masked-out lane cannot be merged back per-batch-row the way the ring
cache is — instead its writes are *routed* to the trash page, whose
contents no table entry ever references. One page table [lanes, Mp] is
shared by all layers/groups: every layer caches the same token
positions, so entry ``i`` of lane ``b`` names the device page holding
positions [i*page, (i+1)*page) in every pool at once.

Two invariants the jitted decode path relies on:

* **position-ordered allocation** — pages are allocated in position order
  per lane, so a slot's global position is a static function of its table
  index (``i*page + j``); validity inside the kernel/gather is then purely
  "table entry >= 0" ∧ causal ∧ window, with no stored position metadata.
* **fence-before-read** — an async page-in only stages its device copy on
  the transfer thread; the owning (main) thread calls `sync()` to wait the
  fences and commit arrivals into the cache pytree before the next jitted
  step. Cache mutation never happens off-thread.

Eviction shares the α-mass priority framework with expert slots
(`EVICTION_POLICIES`): pages are scored by the decayed attention mass of
the lane that owns them, so one scoring currency ranks *all* HBM
residents, and `ResidencyManager.split_budget` turns one byte budget into
an (expert slots, K/V pages) split proportional to predicted mass.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.offload import EVICTION_POLICIES, ExpertStore, PrefetchPipeline
from repro.models.transformer import period, sub_kind

Array = jax.Array


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PagedKVConfig:
    """Geometry of the paged K/V cache.

    `kv_pages` is the device residency budget (pages shared by all lanes,
    excluding the trash page); `max_seq` is the addressable sequence length
    (page-table width × page size) — it may far exceed the resident budget,
    which is the whole point: spilled pages live on host."""

    page_size: int = 16
    kv_pages: int = 64
    prefill_chunk: int = 0  # 0 => chunked prefill disabled
    max_seq: int = 0        # 0 => kv_pages * page_size (everything resident)

    @property
    def enabled(self) -> bool:
        return self.kv_pages > 0

    @property
    def seq_len(self) -> int:
        return self.max_seq or self.kv_pages * self.page_size

    def pages_per_lane(self) -> int:
        return -(-self.seq_len // self.page_size)


@dataclass
class KVPoolStats:
    allocs: int = 0
    spills: int = 0
    page_ins: int = 0
    bytes_spilled: int = 0
    bytes_paged_in: int = 0
    fence_wait_s: float = 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "kv_pages_allocated": self.allocs,
            "kv_page_spills": self.spills,
            "kv_page_ins": self.page_ins,
            "kv_bytes_spilled": self.bytes_spilled,
            "kv_bytes_paged_in": self.bytes_paged_in,
            "kv_fence_wait_s": self.fence_wait_s,
        }


# COW page write: the old pool array stays valid (older cache versions and
# in-flight jitted steps may still reference it), mirroring the store's
# copy-on-write slot commits. data is [G, page, K, D].
@jax.jit
def _page_write(pool: Array, pid, data: Array) -> Array:
    return pool.at[:, pid].set(data.astype(pool.dtype))


# ---------------------------------------------------------------------------
# K/V page pool
# ---------------------------------------------------------------------------
class KVPagePool:
    """Host-side bookkeeping for the device K/V page pool.

    All methods take and return the cache pytree functionally (device
    arrays are never mutated in place); the page table lives as numpy here
    and is mirrored to a cached device copy (`device_table`) that the
    caller re-installs under ``cache["page_table"]`` after any change."""

    def __init__(
        self,
        cfg: ModelConfig,
        paged: PagedKVConfig,
        n_lanes: int,
        eviction: str = "alpha",
        pipeline: Optional[PrefetchPipeline] = None,
    ):
        assert cfg.block_kind == "attn" and not cfg.enc_dec, (
            "paged K/V supports attention-family decoder-only archs"
        )
        assert paged.kv_pages >= 1 and paged.page_size >= 1
        self.cfg = cfg
        self.paged = paged
        self.page = paged.page_size
        self.n_pages = paged.kv_pages           # excludes the trash page
        self.trash = paged.kv_pages             # trash page id == pool idx P
        self.n_lanes = n_lanes
        self.Mp = paged.pages_per_lane()
        per = period(cfg)
        self.kv_subs = [
            s for s in range(per) if sub_kind(cfg, s)["kind"] == "attn"
        ]
        assert self.kv_subs, "paged K/V needs at least one attention sublayer"
        self.n_groups = cfg.n_layers // per
        windows = [cfg.layer_window(s) for s in range(cfg.n_layers)]
        # residency span: pages a decode tick can actually read. 0 = full
        # attention (every allocated page must stay resident); otherwise
        # only pages reaching back `span` positions need device residency —
        # older spilled pages can stay on host forever.
        self.span = 0 if any(w == 0 for w in windows) else max(windows)
        self.pipeline = pipeline
        self.policy = EVICTION_POLICIES[eviction]()
        self.stats = KVPoolStats()
        self.table = np.full((n_lanes, self.Mp), -1, np.int32)
        self._free: List[int] = list(range(self.n_pages))
        self._owner: Dict[int, Tuple[int, int]] = {}
        self._spill: Dict[Tuple[int, int], Dict[str, Tuple[np.ndarray, np.ndarray]]] = {}
        self._pinned: set = set()
        self._lock = threading.RLock()
        self._dev_table: Optional[Array] = None
        # async page-in staging: transfer thread device_put's here; the
        # main thread commits into the cache after the fence (sync())
        self._arrived: Dict[Tuple[int, int, int], Dict[str, Tuple[Array, Array]]] = {}
        self._fences: List[threading.Event] = []

    # -- geometry / accounting -----------------------------------------
    def page_bytes(self) -> int:
        """Device bytes of one page across every layer pool (K and V)."""
        itm = jnp.dtype(self.cfg.dtype).itemsize
        return (
            len(self.kv_subs) * self.n_groups
            * self.page * self.cfg.n_kv_heads * self.cfg.hd * itm * 2
        )

    def kv_pool_bytes(self) -> int:
        """Bytes held by currently resident pages (pages × page bytes)."""
        return (self.n_pages - len(self._free)) * self.page_bytes()

    def capacity_bytes(self) -> int:
        """Allocated device footprint of the pools (incl. the trash page)."""
        return (self.n_pages + 1) * self.page_bytes()

    def resident_pages(self) -> int:
        return self.n_pages - len(self._free)

    # -- device mirrors -------------------------------------------------
    def device_table(self) -> Array:
        if self._dev_table is None:
            self._dev_table = jnp.asarray(self.table)
        return self._dev_table

    def _invalidate(self) -> None:
        self._dev_table = None

    def init_cache(self) -> dict:
        from repro.models.transformer import init_paged_cache

        cache = init_paged_cache(self.cfg, self.n_lanes, self.paged)
        cache["page_table"] = self.device_table()
        return cache

    # -- policy helpers -------------------------------------------------
    def _policy_drop(self, pid: int) -> None:
        """Remove `pid` from the policy's books (idempotent across the
        three policy shapes — pick_victim already removed the entry)."""
        p = self.policy
        if hasattr(p, "score"):
            p.score.pop(pid, None)
        elif hasattr(p, "order"):
            try:
                del p.order[pid]          # LRU OrderedDict
            except (KeyError, TypeError):
                try:
                    p.order.remove(pid)   # FIFO deque
                except ValueError:
                    pass

    def touch_lane(self, lane: int, pos: int, weight: float = 1.0) -> None:
        """Credit α mass to the lane's in-window pages — the shared
        currency that keeps a decoding lane's working set ahead of stale
        pages (and, via `split_budget`, comparable to expert slots)."""
        with self._lock:
            npages = pos // self.page + 1
            lo = 0 if not self.span else max(0, pos - self.span) // self.page
            for i in range(lo, min(npages, self.Mp)):
                pid = int(self.table[lane, i])
                if pid >= 0:
                    self.policy.touch(pid, weight)

    # -- allocation / spill / page-in -----------------------------------
    def _victim(self) -> int:
        v = self.policy.pick_victim(set(self._pinned))
        if v is None:
            raise RuntimeError(
                "KV page pool exhausted: every resident page is pinned "
                f"({len(self._pinned)} pinned / {self.n_pages} pages)"
            )
        return v

    def alloc(self, cache: dict, lane: int, page_idx: int, weight: float = 1.0):
        """Allocate a device page for (lane, page_idx), spilling the
        coldest unpinned page when the free list is empty. Returns
        (cache, page_id)."""
        with self._lock:
            assert self.table[lane, page_idx] < 0, (
                f"page ({lane}, {page_idx}) already allocated"
            )
            if not self._free:
                victim = self._victim()
                cache = self.spill(cache, *self._owner[victim])
            pid = self._free.pop()
            self.table[lane, page_idx] = pid
            self._owner[pid] = (lane, page_idx)
            self.policy.admit(pid, weight)
            self.stats.allocs += 1
            self._invalidate()
        return cache, pid

    def spill(self, cache: dict, lane: int, page_idx: int) -> dict:
        """Evict (lane, page_idx) to host. The device arrays are not
        touched — the page's slots simply become garbage no table entry
        references, and validity masking in the decode step never reads
        them."""
        with self._lock:
            pid = int(self.table[lane, page_idx])
            assert pid >= 0, f"page ({lane}, {page_idx}) is not resident"
            assert pid not in self._pinned, "cannot spill a pinned page"
            data = {}
            for s in self.kv_subs:
                e = cache[f"sub{s}"]
                data[f"sub{s}"] = (
                    np.asarray(e["kp"][:, pid]), np.asarray(e["vp"][:, pid])
                )
            self._spill[(lane, page_idx)] = data
            self.table[lane, page_idx] = -1
            del self._owner[pid]
            self._policy_drop(pid)
            self._free.append(pid)
            self.stats.spills += 1
            self.stats.bytes_spilled += self.page_bytes()
            self._invalidate()
        return cache

    def page_in(
        self, cache: dict, lane: int, page_idx: int, priority: int = 0,
    ) -> dict:
        """Bring a spilled page back. Without a pipeline the upload runs
        inline; with one, the H2D stage rides the shard-0 transfer queue
        at `priority` and the caller must `sync()` before the next jitted
        step that could read the page."""
        cache, pid = self.alloc(cache, lane, page_idx)
        data = self._spill.pop((lane, page_idx))
        self.stats.page_ins += 1
        self.stats.bytes_paged_in += self.page_bytes()
        if self.pipeline is None:
            cache = dict(cache)
            for skey, (k_np, v_np) in data.items():
                e = dict(cache[skey])
                e["kp"] = _page_write(e["kp"], pid, jnp.asarray(k_np))
                e["vp"] = _page_write(e["vp"], pid, jnp.asarray(v_np))
                cache[skey] = e
            return cache

        def stage(lane=lane, page_idx=page_idx, pid=pid, data=data):
            staged = {
                skey: (jax.device_put(kn), jax.device_put(vn))
                for skey, (kn, vn) in data.items()
            }
            with self._lock:
                self._arrived[(lane, page_idx, pid)] = staged

        self._fences.append(self.pipeline.submit_job(stage, priority=priority))
        return cache

    def sync(self, cache: dict) -> dict:
        """Wait outstanding page-in fences, then commit arrived pages into
        the cache — the paged analogue of a prefetch ticket's `wait`."""
        if self._fences:
            t0 = time.perf_counter()
            for ev in self._fences:
                ev.wait()
            self._fences = []
            self.stats.fence_wait_s += time.perf_counter() - t0
        with self._lock:
            arrived, self._arrived = self._arrived, {}
        if arrived:
            cache = dict(cache)
            for (lane, page_idx, pid), staged in arrived.items():
                for skey, (k_dev, v_dev) in staged.items():
                    e = dict(cache[skey])
                    e["kp"] = _page_write(e["kp"], pid, k_dev)
                    e["vp"] = _page_write(e["vp"], pid, v_dev)
                    cache[skey] = e
        return cache

    def ensure(
        self,
        cache: dict,
        lane: int,
        upto_pos: int,
        priority: int = 0,
        weight: float = 1.0,
        pin: bool = False,
        extra_span: int = 0,
    ) -> dict:
        """Make positions [0, upto_pos) of `lane` safe to read/write:
        allocate unallocated pages in position order and page spilled
        in-span pages back in. Out-of-window spilled pages stay on host —
        no decode tick can read them.

        With `pin`, every in-span page is pinned the moment it is resident
        — a later alloc (for this lane or another) can then never evict a
        page the upcoming tick reads or writes; over-pressure surfaces as
        the explicit pool-exhausted error instead of silent mis-attention.
        The caller unpins after its jitted step (`unpin_lane`/`unpin_all`).

        `extra_span` widens the in-span floor for multi-position steps
        (chunked prefill, speculative verify): the block's EARLIEST query
        reads `window` back from the block's first position, which is
        `block_len - 1` positions before `upto_pos - 1` — pass that as the
        extra span so its in-window pages page back in and pin too. No-op
        for full attention (span 0), where everything is resident."""
        assert upto_pos <= self.Mp * self.page, (
            f"position {upto_pos} exceeds addressable range "
            f"{self.Mp * self.page} (raise PagedKVConfig.max_seq)"
        )
        npages = -(-upto_pos // self.page)
        if not self.span:
            # full attention reads EVERY allocated position: a working set
            # larger than the device pool could only proceed by silently
            # attending past spilled pages — refuse instead
            assert npages <= self.n_pages, (
                f"full-attention working set ({npages} pages) exceeds the "
                f"device pool ({self.n_pages} pages): raise kv_pages or use "
                "windowed attention layers"
            )
        lo = 0
        if self.span:
            lo = max(0, upto_pos - 1 - self.span - extra_span) // self.page
        with self._lock:
            if pin:
                # pin already-resident in-span pages BEFORE any alloc below
                # — otherwise an alloc for an earlier (out-of-span) page
                # could evict an in-span page this very ensure then has to
                # page straight back in
                for i in range(lo, npages):
                    pid = int(self.table[lane, i])
                    if pid >= 0:
                        self._pinned.add(pid)
            for i in range(npages):
                if self.table[lane, i] < 0:
                    if (lane, i) in self._spill:
                        if i < lo:
                            continue  # out of span: stays on host
                        cache = self.page_in(cache, lane, i, priority=priority)
                    else:
                        cache, _ = self.alloc(cache, lane, i, weight)
                if pin and i >= lo:
                    self._pinned.add(int(self.table[lane, i]))
        self.touch_lane(lane, upto_pos - 1, weight)
        return cache

    # -- lane lifecycle -------------------------------------------------
    def seed(
        self,
        cache: dict,
        lane: int,
        kv: Dict[str, Tuple[np.ndarray, np.ndarray]],
        length: int,
    ) -> dict:
        """Scatter a prefill forward's rope-applied K/V into the lane's
        pages. `kv` maps "sub{s}" -> (k, v) each [G, S, K, D] with
        S >= length; positions beyond `length` in the last page are
        zero-padded (masked out by causal validity until overwritten).

        Pages are pinned for the duration: under pool pressure a later
        alloc would otherwise pick a just-allocated, not-yet-written page
        of this very lane as its eviction victim — spilling pre-write
        garbage to host and silently dropping the prompt's K/V."""
        npages = -(-length // self.page)
        pinned_here: List[int] = []
        with self._lock:
            try:
                for i in range(npages):
                    if self.table[lane, i] < 0:
                        # any stale host spill for this page is overwritten
                        # wholesale below — drop it rather than page it in
                        self._spill.pop((lane, i), None)
                        cache, _ = self.alloc(cache, lane, i)
                    pid = int(self.table[lane, i])
                    if pid not in self._pinned:
                        self._pinned.add(pid)
                        pinned_here.append(pid)
                cache = dict(cache)
                for s in self.kv_subs:
                    skey = f"sub{s}"
                    k_np, v_np = (np.asarray(a) for a in kv[skey])
                    e = dict(cache[skey])
                    for i in range(npages):
                        pid = int(self.table[lane, i])
                        assert pid >= 0, (
                            f"page ({lane}, {i}) evicted mid-seed despite pin"
                        )
                        lo, hi = i * self.page, min((i + 1) * self.page, length)
                        kblk = np.zeros(
                            (k_np.shape[0], self.page) + k_np.shape[2:],
                            k_np.dtype,
                        )
                        vblk = np.zeros_like(kblk)
                        kblk[:, : hi - lo] = k_np[:, lo:hi]
                        vblk[:, : hi - lo] = v_np[:, lo:hi]
                        e["kp"] = _page_write(e["kp"], pid, jnp.asarray(kblk))
                        e["vp"] = _page_write(e["vp"], pid, jnp.asarray(vblk))
                    cache[skey] = e
            finally:
                for pid in pinned_here:
                    self._pinned.discard(pid)
        return cache

    def release_lane(self, lane: int) -> None:
        """Free the lane's pages and drop its host spills (request done)."""
        with self._lock:
            for i in range(self.Mp):
                pid = int(self.table[lane, i])
                if pid >= 0:
                    self.table[lane, i] = -1
                    del self._owner[pid]
                    self._policy_drop(pid)
                    self._pinned.discard(pid)
                    self._free.append(pid)
            self._spill = {
                k: v for k, v in self._spill.items() if k[0] != lane
            }
            self._invalidate()

    def pin_lane(self, lane: int) -> None:
        """Pin the lane's resident pages (speculative verify: the rollback
        must find every page the draft wrote still resident)."""
        with self._lock:
            self._pinned.update(
                int(p) for p in self.table[lane] if p >= 0
            )

    def unpin_lane(self, lane: int) -> None:
        with self._lock:
            for p in self.table[lane]:
                if p >= 0:
                    self._pinned.discard(int(p))

    def unpin_all(self) -> None:
        with self._lock:
            self._pinned.clear()


# ---------------------------------------------------------------------------
# unified manager
# ---------------------------------------------------------------------------
class ResidencyManager:
    """One HBM budget over both residency classes.

    Pools are statically shaped (jit stability), so arbitration has two
    layers: a static byte split at construction (`split_budget`,
    proportional to predicted α mass per class) and runtime spill pressure
    — both pools rank victims with the same decayed-α-mass policy, so
    "coldest resident loses" means the same thing for an expert slot and a
    K/V page."""

    def __init__(self, store: ExpertStore, kv_pool: KVPagePool):
        self.store = store
        self.kv_pool = kv_pool

    def device_bytes(self) -> int:
        """Total allocated HBM across both pools (expert slots + K/V
        pages + trash page) — what bench_memory's budget rows report."""
        return self.store.device_bytes() + self.kv_pool.capacity_bytes()

    def resident_bytes(self) -> int:
        """Bytes actually holding live data right now."""
        return self.store.device_bytes() + self.kv_pool.kv_pool_bytes()

    def summary(self) -> Dict[str, float]:
        out = dict(self.kv_pool.stats.summary())
        out["kv_pool_bytes"] = self.kv_pool.kv_pool_bytes()
        out["kv_capacity_bytes"] = self.kv_pool.capacity_bytes()
        out["expert_device_bytes"] = self.store.device_bytes()
        return out

    @staticmethod
    def split_budget(
        total_bytes: int,
        expert_slot_bytes: int,
        page_bytes: int,
        n_moe_layers: int,
        expert_mass: float = 1.0,
        kv_mass: float = 1.0,
        min_slots: int = 1,
        min_pages: int = 1,
    ) -> Tuple[int, int]:
        """Split one device budget into (slots_per_moe_layer, kv_pages)
        proportional to the predicted α mass each class absorbs. Masses
        come from the hash predictor's activation statistics (experts) and
        the expected attention working set (K/V); equal masses give a
        50/50 byte split. Floors guarantee both pools stay functional."""
        assert total_bytes > 0 and expert_slot_bytes > 0 and page_bytes > 0
        floor = (
            min_slots * expert_slot_bytes * max(n_moe_layers, 1)
            + (min_pages + 1) * page_bytes
        )
        assert total_bytes >= floor, (
            f"budget {total_bytes}B below the functional floor {floor}B"
        )
        kv_share = kv_mass / max(expert_mass + kv_mass, 1e-9)
        kv_budget = int(total_bytes * kv_share)
        pages = max(min_pages, kv_budget // page_bytes - 1)  # -1: trash page
        while (pages + 1) * page_bytes + min_slots * expert_slot_bytes * max(
            n_moe_layers, 1
        ) > total_bytes and pages > min_pages:
            pages -= 1
        left = total_bytes - (pages + 1) * page_bytes
        slots = max(min_slots, left // (expert_slot_bytes * max(n_moe_layers, 1)))
        return int(slots), int(pages)

    @staticmethod
    def split_budget_tiered(
        total_bytes: int,
        hot_slot_bytes: int,
        warm_slot_bytes: int,
        page_bytes: int,
        n_moe_layers: int,
        tier_split: float = 0.5,
        expert_mass: float = 1.0,
        kv_mass: float = 1.0,
        min_slots: int = 1,
        min_pages: int = 1,
    ) -> Tuple[int, int, int]:
        """Tiered variant of `split_budget`: the expert share of the budget
        further splits `tier_split` into int8 hot slots and the remainder
        into int4 warm slots (per-tier bytes from
        `ExpertStore.tier_slot_bytes` — scale planes included), returning
        (hot_slots, warm_slots, kv_pages) per MoE layer. The same expert
        byte budget buys ~2x the resident experts once the warm share
        dominates, which is the point of the warm tier."""
        assert 0.0 < tier_split <= 1.0, tier_split
        assert warm_slot_bytes > 0, warm_slot_bytes
        hot, pages = ResidencyManager.split_budget(
            total_bytes, hot_slot_bytes, page_bytes, n_moe_layers,
            expert_mass=expert_mass, kv_mass=kv_mass,
            min_slots=min_slots, min_pages=min_pages,
        )
        hot8 = max(min_slots, int(round(hot * tier_split)))
        warm_bytes = (hot - hot8) * hot_slot_bytes
        warm4 = int(warm_bytes // warm_slot_bytes)
        return int(hot8), int(warm4), int(pages)
