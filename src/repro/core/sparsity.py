"""Expert-activation sparsity & cross-embedding dependency analyses.

Reproduces the paper's motivating measurements:
  Fig. 2  — effective GPU memory utilisation vs sentence length
  Fig. 4  — ratio of idle experts vs sentence length
  Fig. 6  — Eq. 2: E[p̂] as a function of (p, c, L)
  Fig. 7  — corruption study: probability a token's expert activation changes
            when a fraction p of other tokens/positions are corrupted
  ĉ       — the sparse cross-embedding dependency estimate (1–4 in the paper)
"""
from __future__ import annotations

from math import comb, lgamma
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, n_moe_layers


# ---------------------------------------------------------------------------
# Eq. 2 — probability the corrupted set hits >=1 critical token
# ---------------------------------------------------------------------------


def _log_comb(n: int, k: int) -> float:
    if k < 0 or k > n:
        return -np.inf
    return lgamma(n + 1) - lgamma(k + 1) - lgamma(n - k + 1)


def expected_phat(p: float, c: int, L: int) -> float:
    """E[p̂] = 1 - C(L-1-c, ⌊pL⌋) / C(L-1, ⌊pL⌋)   (paper Eq. 2)."""
    m = int(p * L)
    num = _log_comb(L - 1 - c, m)
    den = _log_comb(L - 1, m)
    if not np.isfinite(num):
        return 1.0
    return 1.0 - float(np.exp(num - den))


def estimate_c(
    ps: Sequence[float], phats: Sequence[float], L: int, c_max: int = 64
) -> int:
    """Least-squares inversion of Eq. 2 over a grid of c (paper: ĉ ∈ [1,4])."""
    best_c, best_err = 1, np.inf
    for c in range(1, c_max + 1):
        pred = np.array([expected_phat(p, c, L) for p in ps])
        err = float(np.mean((pred - np.asarray(phats)) ** 2))
        if err < best_err:
            best_c, best_err = c, err
    return best_c


# ---------------------------------------------------------------------------
# activation sparsity (Figs. 2 & 4)
# ---------------------------------------------------------------------------


def routing_ids(
    params: dict, cfg: ModelConfig, tokens: np.ndarray, ctx=ShardingCtx()
) -> np.ndarray:
    """Router argmax ids [L_moe, B, S] from a full forward."""
    out = forward(params, cfg, ctx, jnp.asarray(tokens), collect_router_logits=True)
    rl = out["router_logits"]  # [L_moe, B, S, E]
    return np.asarray(jnp.argmax(rl, axis=-1))


def sentence_sparsity(ids: np.ndarray, num_experts: int) -> np.ndarray:
    """Per-sentence ratio of idle experts (Fig. 4). ids: [L, B, S] -> [B]."""
    L, B, S = ids.shape
    ratios = np.empty((B,), np.float64)
    for b in range(B):
        active = np.array([len(np.unique(ids[l, b])) for l in range(L)])
        ratios[b] = 1.0 - active.mean() / num_experts
    return ratios


def effective_memory_utilization(
    cfg: ModelConfig, idle_ratio: float
) -> Dict[str, float]:
    """Fig. 2: fraction of device memory doing useful work for this batch."""
    counts = cfg.param_counts()
    bpp = cfg.bytes_per_param()
    moe_b = counts["moe"] * bpp
    total_b = counts["total"] * bpp
    effective = total_b - moe_b * idle_ratio
    return {
        "total_gb": total_b / 1e9,
        "moe_gb": moe_b / 1e9,
        "moe_fraction": moe_b / total_b,
        "effective_utilization": effective / total_b,
        "ineffective_gb": moe_b * idle_ratio / 1e9,
    }


# ---------------------------------------------------------------------------
# corruption study (Fig. 7)
# ---------------------------------------------------------------------------


def corruption_study(
    params: dict,
    cfg: ModelConfig,
    tokens: np.ndarray,          # [B, L] token ids
    ps: Sequence[float],
    n_positions: int = 8,
    n_trials: int = 4,
    mode: str = "token",         # "token" | "position"
    seed: int = 0,
    ctx=ShardingCtx(),
) -> Dict[float, float]:
    """Empirical P(expert activation of token i changes | corrupt fraction p).

    mode="token": replace a random fraction p of other tokens with random ids
    distinct from original and from token i (paper §3.4.1).
    mode="position": swap a random fraction p of other positions.
    """
    rng = np.random.default_rng(seed)
    B, L = tokens.shape
    base_ids = routing_ids(params, cfg, tokens, ctx)        # [Lm, B, S]
    results: Dict[float, List[float]] = {p: [] for p in ps}
    positions = rng.choice(L, size=min(n_positions, L), replace=False)

    for p in ps:
        m = max(1, int(p * L))
        for i in positions:
            for _ in range(n_trials):
                corrupt = tokens.copy()
                others = np.setdiff1d(np.arange(L), [i])
                sel = rng.choice(others, size=min(m, len(others)), replace=False)
                if mode == "token":
                    for b in range(B):
                        for j in sel:
                            orig = corrupt[b, j]
                            new = rng.integers(0, cfg.vocab_size)
                            while new == orig or new == tokens[b, i]:
                                new = rng.integers(0, cfg.vocab_size)
                            corrupt[b, j] = new
                else:
                    perm = rng.permutation(sel)
                    corrupt[:, sel] = corrupt[:, perm]
                new_ids = routing_ids(params, cfg, corrupt, ctx)
                changed = (new_ids[:, :, i] != base_ids[:, :, i]).mean()
                results[p].append(float(changed))
    return {p: float(np.mean(v)) for p, v in results.items()}
