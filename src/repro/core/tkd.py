"""Truncated Knowledge Distillation (paper §3.5) + hash-function training.

Objective:  λ·L_CE + L_TKD(T)

L_TKD only matches the teacher router's **top-T** softmax logits — the
2-layer-LSTM student cannot model the full E-way distribution; truncation
focuses capacity on the experts that can actually be activated. L_CE (teacher
argmax as hard label) guarantees prediction accuracy (the hash hit rate).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hash_fn import hash_fn_apply, hash_hit_rate

Array = jax.Array


def tkd_loss(
    student_logits: Array,   # [B, S, L, E]
    teacher_logits: Array,   # [L, B, S, E] (router logits from the MoE model)
    T: int = 30,
    lam: float = 0.005,
    tau: float = 1.0,
) -> Tuple[Array, Dict[str, Array]]:
    t = jnp.moveaxis(teacher_logits, 0, 2).astype(jnp.float32)   # [B,S,L,E]
    s = student_logits.astype(jnp.float32)
    E = t.shape[-1]
    T = min(T, E)

    # --- truncated KD over teacher top-T ---------------------------------
    # (mask-based rather than gathers: softmax restricted to the teacher's
    # top-T slots; -inf elsewhere)
    t_top, _ = jax.lax.top_k(t, T)                                # [B,S,L,T]
    thresh = t_top[..., -1:]                                      # T-th logit
    mask = t >= thresh                                            # [B,S,L,E]
    neg = jnp.float32(-1e30)
    p = jax.nn.softmax(jnp.where(mask, t / tau, neg), axis=-1)
    logq = jax.nn.log_softmax(jnp.where(mask, s / tau, neg), axis=-1)
    kd = -(p * jnp.where(mask, logq, 0.0)).sum(-1).mean() * tau**2

    # --- CE on the teacher argmax (hash-hit accuracy) ---------------------
    labels = jnp.argmax(t, axis=-1)                               # [B,S,L]
    onehot = jax.nn.one_hot(labels, E)
    ce = -(jax.nn.log_softmax(s, axis=-1) * onehot).sum(-1).mean()

    loss = lam * ce + kd
    acc = (jnp.argmax(s, -1) == labels).mean()
    return loss, {"kd": kd, "ce": ce, "acc": acc}


@partial(jax.jit, static_argnames=("T", "lam", "opt_update"))
def _train_step(params, opt_state, emb, teacher_logits, T, lam, opt_update):
    E = teacher_logits.shape[-1]

    def loss_fn(p):
        s = hash_fn_apply(p, emb, num_experts=E)
        return tkd_loss(s, teacher_logits, T=T, lam=lam)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt_state = opt_update(grads, params, opt_state)
    metrics["loss"] = loss
    return params, opt_state, metrics


def train_hash_fn(
    params: dict,
    batches: Iterator[Tuple[Array, Array]],  # (embeddings, teacher router logits)
    steps: int,
    lr: float = 5e-5,
    T: int = 30,
    lam: float = 0.005,
    log_every: int = 50,
    verbose: bool = True,
):
    """Offline hash-function training (paper: AdamW, lr 5e-5, λ 0.005, T 30)."""
    from repro.optim.adamw import adamw_init, adamw_update

    opt_state = adamw_init(params)
    update = partial(adamw_update, lr=lr, weight_decay=0.01)
    history = []
    for step in range(steps):
        emb, teacher = next(batches)
        params, opt_state, m = _train_step(
            params, opt_state, emb, teacher, T, lam, update
        )
        if step % log_every == 0 or step == steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = step
            history.append(rec)
            if verbose:
                print(
                    f"  hash-fn step {step:4d}  loss={rec['loss']:.4f} "
                    f"kd={rec['kd']:.4f} ce={rec['ce']:.4f} acc={rec['acc']:.3f}"
                )
    return params, history


@partial(jax.jit, static_argnames=("num_experts", "opt_update"))
def _draft_step(draft_p, opt_state, base_params, emb, embed_table,
                teacher_lm_logits, num_experts, opt_update):
    labels = jnp.argmax(teacher_lm_logits.astype(jnp.float32), axis=-1)  # [B,S]

    def loss_fn(dp):
        p = {**base_params, **dp}
        _, draft = hash_fn_apply(
            p, emb, num_experts=num_experts, causal=True,
            embed_table=embed_table,
        )
        lp = jax.nn.log_softmax(draft, axis=-1)
        ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        acc = (jnp.argmax(draft, -1) == labels).mean()
        return ce, acc

    (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(draft_p)
    draft_p, opt_state = opt_update(grads, draft_p, opt_state)
    return draft_p, opt_state, loss, acc


def train_draft_head(
    params: dict,
    embed_table,
    batches: Iterator[Tuple[Array, Array]],  # (embeddings, teacher LM logits)
    steps: int,
    num_experts: int,
    lr: float = 3e-3,
    verbose: bool = False,
):
    """Distill the serving model's greedy next-token behaviour into the
    tied-embedding draft head (speculative decode, beyond paper).

    Only `draft_proj` trains — the router heads and LSTM trunk are frozen,
    so a cached/distilled predictor keeps its expert hit rate bit-for-bit
    while gaining a draft head on the same state. The teacher signal is the
    model's own next-token argmax (hard-label CE): greedy speculative
    acceptance only cares about matching the model's argmax, not its full
    distribution."""
    from repro.optim.adamw import adamw_init, adamw_update

    assert "draft_proj" in params, "attach a draft head first (init_draft_head)"
    draft_p = {"draft_proj": params["draft_proj"]}
    base = {k: v for k, v in params.items() if k != "draft_proj"}
    opt_state = adamw_init(draft_p)
    update = partial(adamw_update, lr=lr, weight_decay=0.0)
    history = []
    for step in range(steps):
        emb, teacher_lm = next(batches)
        draft_p, opt_state, loss, acc = _draft_step(
            draft_p, opt_state, base, emb, embed_table, teacher_lm,
            num_experts, update,
        )
        if step % 50 == 0 or step == steps - 1:
            history.append({"step": step, "loss": float(loss), "acc": float(acc)})
            if verbose:
                print(f"  draft step {step:4d}  ce={float(loss):.4f} "
                      f"argmax_match={float(acc):.3f}")
    return {**base, **draft_p}, history


def evaluate_hash_fn(params, emb, teacher_logits, top: int = 3) -> Dict[str, float]:
    s = hash_fn_apply(params, emb, num_experts=teacher_logits.shape[-1])
    labels = jnp.argmax(jnp.moveaxis(teacher_logits, 0, 2), axis=-1)
    labels = jnp.moveaxis(labels, 2, 0)  # [L,B,S]
    return {
        "top1_hit": float(hash_hit_rate(s, labels, top=1)),
        f"top{top}_hit": float(hash_hit_rate(s, labels, top=top)),
    }
