"""Synthetic corpora with planted routing structure.

GLUE/C4 are unavailable offline (DESIGN.md §7), so we synthesise data whose
statistics matter for SiDA:

* **domain structure**: each sequence is drawn from one of `n_domains`
  latent domains, each with its own zipf-weighted token cluster. MoE routers
  trained on this data specialise experts per domain — giving the
  *sentence-level expert sparsity* the paper observes (Figs. 2/4) — and the
  activation pattern becomes predictable from the input alone, which is what
  the hash function exploits.
* **length distributions** mimicking the paper's datasets: "sst2" (short),
  "mrpc" (mid), "multirc" (long).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

LENGTH_PROFILES = {
    # (min_len, max_len, mode) loosely matching Fig. 2/8 histograms
    "sst2": (4, 60, 12),
    "mrpc": (30, 90, 55),
    "multirc": (150, 480, 280),
}


@dataclass
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    n_domains: int = 8
    shared_frac: float = 0.2      # tokens shared across domains
    zipf_a: float = 1.3
    profile: Optional[str] = None  # variable-length profile or None (fixed len)
    pad_id: int = 0


class SyntheticLM:
    """Deterministic, seedable synthetic LM stream."""

    def __init__(self, cfg: SyntheticConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        V, D = cfg.vocab_size, cfg.n_domains
        n_shared = max(1, int(V * cfg.shared_frac))
        self.shared = np.arange(1, 1 + n_shared) % V
        per = max(1, (V - n_shared) // D)
        self.clusters = [
            (1 + n_shared + d * per + np.arange(per)) % V for d in range(D)
        ]
        ranks = np.arange(1, per + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_a)
        self.zipf_w = w / w.sum()
        ranks_s = np.arange(1, n_shared + 1, dtype=np.float64)
        ws = ranks_s ** (-cfg.zipf_a)
        self.zipf_shared = ws / ws.sum()

    def _length(self) -> int:
        cfg = self.cfg
        if cfg.profile is None:
            return cfg.seq_len
        lo, hi, mode = LENGTH_PROFILES[cfg.profile]
        return int(np.clip(self.rng.triangular(lo, mode, hi), lo, cfg.seq_len))

    def sample(self, batch: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (tokens [B,S], labels [B,S] (-100 pad), domains [B])."""
        cfg = self.cfg
        toks = np.full((batch, cfg.seq_len), cfg.pad_id, np.int32)
        labels = np.full((batch, cfg.seq_len), -100, np.int32)
        domains = self.rng.integers(0, cfg.n_domains, size=batch)
        for b in range(batch):
            L = self._length()
            d = domains[b]
            from_shared = self.rng.random(L) < cfg.shared_frac
            seq = np.where(
                from_shared,
                self.rng.choice(self.shared, size=L, p=self.zipf_shared),
                self.rng.choice(self.clusters[d], size=L, p=self.zipf_w),
            )
            toks[b, :L] = seq
            labels[b, : L - 1] = seq[1:]
        return toks, labels, domains.astype(np.int32)

    def batches(self, batch: int, steps: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        for _ in range(steps):
            t, l, _ = self.sample(batch)
            yield t, l
