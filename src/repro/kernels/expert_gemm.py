"""Pallas TPU kernel: slot-stacked expert (G)LU FFN — SiDA's serving hot spot.

The inference thread's MoE compute is a batched per-expert FFN over the
compacted slot buffer: xe [E, C, d] -> act(xe@w_gate) * (xe@w_in) @ w_out.
On GPU the paper relies on per-expert kernel launches; on TPU we instead
tile the whole slot stack through one systolic-friendly kernel:

  grid = (E, C/bc, F/bf)   — the f-axis innermost so the [bc, d] output
                             block accumulates in VMEM across f-tiles
  VMEM working set per step: x [bc,d] + w_in/w_gate [d,bf] + w_out [bf,d]
  + out [bc,d] ≈ 10 MB at (bc, bf) = (128, 128), d = 4096 — fits v5e's
  16 MB VMEM with MXU-aligned (multiples of 128) matmul dims.

Weights stream expert-by-expert from HBM; compute per expert scales with
its occupied capacity — the TPU analogue of "only invoke activated experts".

`expert_ffn_q` is the fused-dequant variant for int8 device-resident slots
(SiDA quantized slots): weight operands stream from HBM as int8 (2–4×
fewer bytes), widen to the compute dtype one [d, bf] tile at a time in
VMEM, and the per-output-channel scales fold into the f32 matmul epilogue
— a materialized fp expert copy never exists at any memory tier.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _act(h, act: str):
    if act == "silu":
        return h * jax.nn.sigmoid(h)
    if act == "gelu":
        return jax.nn.gelu(h)
    return jnp.maximum(h, 0.0)


def _ffn_kernel(x_ref, wi_ref, wg_ref, wo_ref, o_ref, *, act: str, glu: bool):
    j = pl.program_id(2)  # f-tile index (innermost)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                  # [bc, d]
    h = jnp.dot(x, wi_ref[0], preferred_element_type=jnp.float32)   # [bc, bf]
    if glu:
        g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        h = _act(g, act) * h
    else:
        h = _act(h, act)
    o_ref[...] += jnp.dot(
        h.astype(x.dtype), wo_ref[0], preferred_element_type=jnp.float32
    )[None].astype(o_ref.dtype)


def _ffn_kernel_q(
    x_ref, wi_ref, wis_ref, wg_ref, wgs_ref, wo_ref, wos_ref, o_ref,
    *, act: str, glu: bool,
):
    """Fused-dequant variant: weight tiles arrive int8 and are widened to the
    compute dtype *in VMEM* (a [d, bf] tile at a time — the full fp expert
    copy never exists anywhere), and the per-output-channel scales are folded
    into the f32 matmul product. Scales are per output channel, so
    (x @ (q·s)) == (x @ q)·s exactly — the MXU contracts raw int8-widened
    tiles and the epilogue applies s to the [bc, bf] block."""
    j = pl.program_id(2)  # f-tile index (innermost)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                               # [bc, d]
    wi = wi_ref[0].astype(x.dtype)                             # int8 -> VMEM tile
    h = jnp.dot(x, wi, preferred_element_type=jnp.float32)
    h = h * wis_ref[...].astype(jnp.float32)                   # [bc,bf] * [1,bf]
    if glu:
        wg = wg_ref[0].astype(x.dtype)
        g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
        g = g * wgs_ref[...].astype(jnp.float32)
        h = _act(g, act) * h
    else:
        h = _act(h, act)
    wo = wo_ref[0].astype(x.dtype)
    out = jnp.dot(h.astype(x.dtype), wo, preferred_element_type=jnp.float32)
    out = out * wos_ref[...].astype(jnp.float32)               # [bc,d] * [1,d]
    o_ref[...] += out[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("act", "glu", "bc", "bf", "interpret")
)
def expert_ffn_q(
    xe: Array,                      # [E, C, d]
    w_in_q: Array,                  # [E, d, F] int8
    w_in_scale: Array,              # [E, 1, F] or [E, F] f32
    w_gate_q: Optional[Array],      # [E, d, F] int8 (None => non-gated)
    w_gate_scale: Optional[Array],  # [E, 1, F] or [E, F] f32
    w_out_q: Array,                 # [E, F, d] int8
    w_out_scale: Array,             # [E, 1, d] or [E, d] f32
    act: str = "silu",
    bc: int = 128,
    bf: int = 128,
    interpret: bool = False,
    glu: Optional[bool] = None,
) -> Array:
    """Slot-stacked expert FFN over int8-resident weights (SiDA quantized
    slots): same grid/accumulation scheme as `expert_ffn`, but the weight
    operands stream from HBM as int8 (2–4× fewer bytes than fp slots) and
    dequantization is fused into the kernel epilogue."""
    E, C, d = xe.shape
    F = w_in_q.shape[-1]
    glu = (w_gate_q is not None) if glu is None else glu
    bc = min(bc, C)
    bf = min(bf, F)
    assert C % bc == 0 and F % bf == 0, (C, bc, F, bf)
    w_in_scale = w_in_scale.reshape(E, F)
    w_out_scale = w_out_scale.reshape(E, d)
    if w_gate_q is None:
        w_gate_q = w_in_q          # placeholder operands (never read)
        w_gate_scale = w_in_scale
    else:
        w_gate_scale = w_gate_scale.reshape(E, F)

    grid = (E, C // bc, F // bf)
    return pl.pallas_call(
        functools.partial(_ffn_kernel_q, act=act, glu=glu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, d, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, bf), lambda e, i, j: (e, j)),
            pl.BlockSpec((1, d, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, bf), lambda e, i, j: (e, j)),
            pl.BlockSpec((1, bf, d), lambda e, i, j: (e, j, 0)),
            pl.BlockSpec((1, d), lambda e, i, j: (e, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, i, j: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), xe.dtype),
        interpret=interpret,
    )(xe, w_in_q, w_in_scale, w_gate_q, w_gate_scale, w_out_q, w_out_scale)


def _unpack_nibbles(p, k: int):
    """[k//2, n] nibble-packed uint8 -> [k, n] signed int4 values as int8.

    Byte i holds contraction rows 2i (low nibble) / 2i+1 (high nibble),
    two's complement in [-8, 7] — must match `ref.unpack_int4_ref` and the
    numpy packer in core/offload.py bit-for-bit."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = (p >> 4).astype(jnp.int8)
    v = jnp.stack([lo, hi], axis=1).reshape(k, p.shape[-1])
    return jnp.where(v >= 8, v - 16, v)


def _ffn_kernel_q4(
    x_ref, wi_ref, wis_ref, wg_ref, wgs_ref, wo_ref, wos_ref, o_ref,
    *, act: str, glu: bool,
):
    """Int4 fused-dequant variant: weight tiles arrive nibble-packed uint8
    (4× fewer bytes than fp32, 2× fewer than int8) and are unpacked to the
    compute dtype in VMEM. Scales are per GROUP along the contraction axis,
    so they do NOT commute with the full contraction — instead each f-tile
    contracts group-by-group (a batched [bc, g] x [g, bf] dot with the group
    axis as the batch dim), applies the [n_groups, bf] scale plane to the
    stacked partials in f32, and sums over groups in the epilogue."""
    j = pl.program_id(2)  # f-tile index (innermost)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                               # [bc, d]
    bc, d = x.shape
    gd_n = wis_ref.shape[1]                                    # groups along d
    gd = d // gd_n
    bf = wis_ref.shape[2]
    gf_n = wos_ref.shape[1]                                    # w_out groups in tile
    gf = bf // gf_n

    def grouped_dot(lhs, w_packed, scale, n_groups, gsz):
        # lhs [bc, k] x packed [k//2, n] with scale [n_groups, n] -> [bc, n]
        k = n_groups * gsz
        w = _unpack_nibbles(w_packed, k).astype(lhs.dtype)     # [k, n]
        lg = lhs.reshape(bc, n_groups, gsz).swapaxes(0, 1)     # [ng, bc, g]
        wg_ = w.reshape(n_groups, gsz, -1)                     # [ng, g, n]
        part = jax.lax.dot_general(
            lg, wg_, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                                      # [ng, bc, n]
        return (part * scale[:, None, :].astype(jnp.float32)).sum(0)

    h = grouped_dot(x, wi_ref[0], wis_ref[0], gd_n, gd)        # [bc, bf]
    if glu:
        g = grouped_dot(x, wg_ref[0], wgs_ref[0], gd_n, gd)
        h = _act(g, act) * h
    else:
        h = _act(h, act)
    out = grouped_dot(h.astype(x.dtype), wo_ref[0], wos_ref[0], gf_n, gf)
    o_ref[...] += out[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("act", "glu", "bc", "bf", "interpret")
)
def expert_ffn_q4(
    xe: Array,                      # [E, C, d]
    w_in_q4: Array,                 # [E, d//2, F] uint8 (packed along d)
    w_in_scale: Array,              # [E, d//g, F] f32 per-group scales
    w_gate_q4: Optional[Array],     # [E, d//2, F] uint8 (None => non-gated)
    w_gate_scale: Optional[Array],  # [E, d//g, F] f32
    w_out_q4: Array,                # [E, F//2, d] uint8 (packed along F)
    w_out_scale: Array,             # [E, F//g, d] f32
    act: str = "silu",
    bc: int = 128,
    bf: int = 128,
    interpret: bool = False,
    glu: Optional[bool] = None,
) -> Array:
    """Slot-stacked expert FFN over int4-resident weights (SiDA warm-tier
    slots): same grid/accumulation scheme as `expert_ffn_q`, but the weight
    operands stream from HBM nibble-packed (two int4 values per byte) and
    the per-group scales fold into a grouped-contraction f32 epilogue."""
    E, C, d = xe.shape
    F = w_in_q4.shape[-1]
    glu = (w_gate_q4 is not None) if glu is None else glu
    bc = min(bc, C)
    bf = min(bf, F)
    assert C % bc == 0 and F % bf == 0, (C, bc, F, bf)
    assert d % 2 == 0 and F % 2 == 0, (d, F)  # nibble packing needs even dims
    gd_n = w_in_scale.shape[1]
    gf_n = w_out_scale.shape[1]
    assert d % gd_n == 0 and F % gf_n == 0, (d, gd_n, F, gf_n)
    g_out = F // gf_n
    # each f-tile must cover whole w_out scale groups so the (1, bf//g, d)
    # scale block at index j lines up with the packed (1, bf//2, d) block
    assert bf % g_out == 0, (bf, g_out)
    if w_gate_q4 is None:
        w_gate_q4 = w_in_q4        # placeholder operands (never read)
        w_gate_scale = w_in_scale

    grid = (E, C // bc, F // bf)
    return pl.pallas_call(
        functools.partial(_ffn_kernel_q4, act=act, glu=glu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, d // 2, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, gd_n, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, d // 2, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, gd_n, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, bf // 2, d), lambda e, i, j: (e, j, 0)),
            pl.BlockSpec((1, bf // g_out, d), lambda e, i, j: (e, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, i, j: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), xe.dtype),
        interpret=interpret,
    )(xe, w_in_q4, w_in_scale, w_gate_q4, w_gate_scale, w_out_q4, w_out_scale)


@functools.partial(
    jax.jit, static_argnames=("act", "glu", "bc", "bf", "interpret")
)
def expert_ffn(
    xe: Array,                  # [E, C, d]
    w_in: Array,                # [E, d, F]
    w_gate: Optional[Array],    # [E, d, F] (None => non-gated)
    w_out: Array,               # [E, F, d]
    act: str = "silu",
    bc: int = 128,
    bf: int = 128,
    interpret: bool = False,
    glu: Optional[bool] = None,
) -> Array:
    E, C, d = xe.shape
    F = w_in.shape[-1]
    glu = (w_gate is not None) if glu is None else glu
    bc = min(bc, C)
    bf = min(bf, F)
    assert C % bc == 0 and F % bf == 0, (C, bc, F, bf)
    if w_gate is None:
        w_gate = w_in  # placeholder operand (never read when glu=False)

    grid = (E, C // bc, F // bf)
    return pl.pallas_call(
        functools.partial(_ffn_kernel, act=act, glu=glu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, d, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, d, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, bf, d), lambda e, i, j: (e, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, i, j: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), xe.dtype),
        interpret=interpret,
    )(xe, w_in, w_gate, w_out)
