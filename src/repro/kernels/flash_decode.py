"""Pallas TPU kernel: single-token flash-decode attention over a KV cache.

The serving-path hot spot once experts are resident: one query token
against a long (possibly ring-buffer) cache. Online-softmax accumulation
over sequence tiles — running (m, l, acc) live in VMEM scratch; K/V stream
tile-by-tile from HBM so the cache never occupies VMEM.

  grid = (B, K, S/bs)  — seq tiles innermost
  scratch: m,l [G, 128], acc [G, D]
  block: k/v [bs, D], q [G, D]

This kernel is the per-shard "local" computation of the distributed
flash-decode in models/attention.py (the cross-shard merge stays in
shard_map); its oracle is kernels/ref.py::flash_decode_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG = -1e30


def _decode_kernel(
    q_ref,        # [1, 1, G, D]
    k_ref,        # [1, bs, 1, D]
    v_ref,        # [1, bs, 1, D]
    sp_ref,       # [1, bs]  slot positions
    pos_ref,      # [1]      current decode position
    o_ref,        # [1, 1, G, D]
    m_ref, l_ref, acc_ref,   # scratch: [G,1], [G,1], [G,D]
    *, window: int, cap: float, scale: float, n_s: int,
):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)                   # [bs, D]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if cap:
        logits = cap * jnp.tanh(logits / cap)
    sp = sp_ref[0]                                           # [bs]
    pos = pos_ref[0]
    valid = (sp >= 0) & (sp <= pos)
    if window:
        valid &= sp > pos - window
    logits = jnp.where(valid[None, :], logits, NEG)          # [G, bs]

    m_prev = m_ref[...]                                      # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)                              # [G, bs]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    v = v_ref[0, :, 0].astype(jnp.float32)                   # [bs, D]
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("window", "cap", "bs", "interpret"))
def flash_decode(
    q: Array,         # [B, H, D]
    k: Array,         # [B, S, K, D]
    v: Array,         # [B, S, K, D]
    slot_pos: Array,  # [B, S] int32
    pos: Array,       # [B] int32
    window: int = 0,
    cap: float = 0.0,
    bs: int = 512,
    interpret: bool = False,
) -> Array:
    B, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    qg = q.reshape(B, K, G, D)
    grid = (B, K, S // bs)
    out = pl.pallas_call(
        functools.partial(
            _decode_kernel,
            window=window, cap=cap, scale=1.0 / math.sqrt(D), n_s=S // bs,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, slot_pos, pos)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------------------
# paged variant: K/V stream through the page table (scalar prefetch)
# ---------------------------------------------------------------------------


def _paged_decode_kernel(
    pt_ref,       # [B, Mp]  page table (scalar-prefetched, SMEM)
    q_ref,        # [1, 1, G, D]
    k_ref,        # [1, page, 1, D]  the page this grid step's DMA fetched
    v_ref,        # [1, page, 1, D]
    pos_ref,      # [1]
    o_ref,        # [1, 1, G, D]
    m_ref, l_ref, acc_ref,   # scratch: [G,1], [G,1], [G,D]
    *, window: int, cap: float, scale: float, n_p: int, page: int,
):
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)                   # [page, D]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if cap:
        logits = cap * jnp.tanh(logits / cap)
    # slot global positions are static in the table index (pages are
    # allocated in position order — core/residency.py); validity is the
    # table entry being live plus the causal/window band
    pos = pos_ref[0]
    spos = p * page + jax.lax.iota(jnp.int32, page)
    valid = (pt_ref[b, p] >= 0) & (spos <= pos)
    if window:
        valid &= spos > pos - window
    logits = jnp.where(valid[None, :], logits, NEG)          # [G, page]

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pw = jnp.exp(logits - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pw, -1, keepdims=True)
    v = v_ref[0, :, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pw, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(p == n_p - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("window", "cap", "interpret"))
def flash_decode_paged(
    q: Array,           # [B, H, D]
    kp: Array,          # [P+1, page, K, D] shared page pool (trash page last)
    vp: Array,          # [P+1, page, K, D]
    page_table: Array,  # [B, Mp] int32 (-1 = unallocated/spilled)
    pos: Array,         # [B] int32
    window: int = 0,
    cap: float = 0.0,
    interpret: bool = False,
) -> Array:
    """Flash-decode reading K/V *through the page map*: the page table rides
    scalar prefetch (`PrefetchScalarGridSpec`), so each sequence-grid step's
    K/V DMA is addressed by the table entry — only resident pages are ever
    fetched, and -1 entries redirect to the trash page whose logits the
    validity mask zeroes exactly. One page per grid step keeps the online
    softmax identical to `_decode_kernel` with bs=page."""
    B, H, D = q.shape
    P1, page, K, _ = kp.shape
    Mp = page_table.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, D)
    grid = (B, K, Mp)

    def kv_map(b, h, p, pt):
        pid = pt[b, p]
        return (jnp.where(pid >= 0, pid, P1 - 1), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p, pt: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D), kv_map),
            pl.BlockSpec((1, page, 1, D), kv_map),
            pl.BlockSpec((1,), lambda b, h, p, pt: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, p, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_decode_kernel,
            window=window, cap=cap, scale=1.0 / math.sqrt(D),
            n_p=Mp, page=page,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(page_table, qg, kp, vp, pos)
    return out.reshape(B, H, D)
