"""Pallas TPU kernel: causal flash attention for prefill/training.

Online-softmax over KV tiles with (m, l, acc) scratch in VMEM — the
standard flash forward, plus the repo's attention dialect: GQA (grouped
query heads), sliding windows (gemma2/hymba local layers) and logit
softcaps. Tiles are MXU-aligned; the KV tile loop is the innermost grid
dim so each (batch, kv-head, q-tile) block accumulates in VMEM.

Oracle: kernels/ref.py::flash_prefill_ref (== models/attention._attend_chunk).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG = -1e30


def _prefill_kernel(
    q_ref,        # [1, 1, G, bq, D]
    k_ref,        # [1, bs, 1, D]
    v_ref,        # [1, bs, 1, D]
    o_ref,        # [1, 1, G, bq, D]
    m_ref, l_ref, acc_ref,  # scratch [G*bq, 1], [G*bq, 1], [G*bq, D]
    *, window: int, cap: float, scale: float, causal: bool,
    bq: int, bs: int, n_s: int,
):
    s = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G, D = q_ref.shape[2], q_ref.shape[4]
    q = q_ref[0, 0].astype(jnp.float32).reshape(G * bq, D)
    k = k_ref[0, :, 0].astype(jnp.float32)                  # [bs, D]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if cap:
        logits = cap * jnp.tanh(logits / cap)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (G, bq), 1).reshape(
        G * bq
    )
    k_pos = s * bs + jax.lax.iota(jnp.int32, bs)
    mask = jnp.ones((G * bq, bs), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask, logits, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # fully-masked tiles: exp(NEG - NEG) would be 1 — zero them via the mask
    p = jnp.where(mask, jnp.exp(logits - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    v = v_ref[0, :, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.reshape(G, bq, D).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "cap", "causal", "bq", "bs", "interpret")
)
def flash_prefill(
    q: Array,       # [B, S, H, D]
    k: Array,       # [B, S, K, D]
    v: Array,       # [B, S, K, D]
    window: int = 0,
    cap: float = 0.0,
    causal: bool = True,
    bq: int = 256,
    bs: int = 256,
    interpret: bool = False,
) -> Array:
    """Full-sequence GQA flash attention. Returns [B, S, H, D]."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    bq, bs = min(bq, S), min(bs, S)
    assert S % bq == 0 and S % bs == 0, (S, bq, bs)
    qg = q.reshape(B, S, K, G, D).transpose(0, 2, 3, 1, 4)   # [B, K, G, S, D]
    grid = (B, K, S // bq, S // bs)
    out = pl.pallas_call(
        functools.partial(
            _prefill_kernel,
            window=window, cap=cap, scale=1.0 / math.sqrt(D), causal=causal,
            bq=bq, bs=bs, n_s=S // bs,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, s: (b, h, 0, i, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, i, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, i, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, s: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, S // bq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * bq, 1), jnp.float32),
            pltpu.VMEM((G * bq, 1), jnp.float32),
            pltpu.VMEM((G * bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)
