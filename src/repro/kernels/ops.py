"""jit'd public wrappers over the Pallas kernels.

On this CPU container the kernels run with interpret=True (Pallas-TPU can't
lower to CPU); on a real TPU set REPRO_PALLAS_INTERPRET=0 (the default when
a TPU backend is detected).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import expert_gemm as _eg
from repro.kernels import flash_decode as _fd
from repro.kernels import sparsemax as _sm


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def expert_ffn(xe, w_in, w_gate, w_out, act: str = "silu", **kw):
    return _eg.expert_ffn(
        xe, w_in, w_gate, w_out, act=act, interpret=_interpret(), **kw
    )


def expert_ffn_q(xe, w_in_q, w_in_scale, w_gate_q, w_gate_scale,
                 w_out_q, w_out_scale, act: str = "silu", **kw):
    """Fused-dequant expert FFN over int8-resident weights (quantized slots)."""
    return _eg.expert_ffn_q(
        xe, w_in_q, w_in_scale, w_gate_q, w_gate_scale, w_out_q, w_out_scale,
        act=act, interpret=_interpret(), **kw
    )


def expert_ffn_q4(xe, w_in_q4, w_in_scale, w_gate_q4, w_gate_scale,
                  w_out_q4, w_out_scale, act: str = "silu", **kw):
    """Fused-dequant expert FFN over nibble-packed int4 weights with
    per-group scales (warm-tier residency slots)."""
    return _eg.expert_ffn_q4(
        xe, w_in_q4, w_in_scale, w_gate_q4, w_gate_scale,
        w_out_q4, w_out_scale, act=act, interpret=_interpret(), **kw
    )


def sparsemax(z, **kw):
    return _sm.sparsemax(z, interpret=_interpret(), **kw)


def flash_decode(q, k, v, slot_pos, pos, window: int = 0, cap: float = 0.0, **kw):
    return _fd.flash_decode(
        q, k, v, slot_pos, pos, window=window, cap=cap, interpret=_interpret(), **kw
    )


def flash_decode_paged(q, kp, vp, page_table, pos,
                       window: int = 0, cap: float = 0.0, **kw):
    """Single-token attention reading K/V through a page table (the paged
    residency path — see core/residency.py for the pool invariants)."""
    return _fd.flash_decode_paged(
        q, kp, vp, page_table, pos, window=window, cap=cap,
        interpret=_interpret(), **kw
    )


def flash_prefill(q, k, v, window: int = 0, cap: float = 0.0,
                  causal: bool = True, **kw):
    from repro.kernels import flash_prefill as _fp

    return _fp.flash_prefill(
        q, k, v, window=window, cap=cap, causal=causal,
        interpret=_interpret(), **kw
    )
