"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle with
interpret=True on CPU; on TPU the same oracles validate the compiled
kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def expert_ffn_ref(
    xe: Array,      # [E, C, d]
    w_in: Array,    # [E, d, F]
    w_gate: Array,  # [E, d, F] or None
    w_out: Array,   # [E, F, d]
    act: str = "silu",
) -> Array:
    """Per-expert (G)LU FFN over the capacity buffer."""
    f = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    h = jnp.einsum("ecd,edf->ecf", xe, w_in)
    if w_gate is not None:
        h = f(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * h
    else:
        h = f(h)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def dequantize_ref(q: Array, scale: Array) -> Array:
    """int8 tensor + per-output-channel scale plane -> f32 weights."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def expert_ffn_q_ref(
    xe: Array,             # [E, C, d]
    w_in_q: Array,         # [E, d, F] int8
    w_in_scale: Array,     # [E, 1, F] (or [E, F])
    w_gate_q: Array,       # [E, d, F] int8 or None
    w_gate_scale: Array,   # [E, 1, F] or None
    w_out_q: Array,        # [E, F, d] int8
    w_out_scale: Array,    # [E, 1, d] (or [E, d])
    act: str = "silu",
) -> Array:
    """Fused-dequant expert FFN oracle: dequantize-then-compute in pure jnp.

    Because scales are per *output* channel, (x @ (q·s)) == (x @ q)·s exactly
    (s is constant along the contraction), so this materialized-dequant form
    is the mathematical contract for the in-kernel fused path.
    """
    E = xe.shape[0]
    wi = dequantize_ref(w_in_q, w_in_scale.reshape(E, 1, -1)).astype(xe.dtype)
    wg = None
    if w_gate_q is not None:
        wg = dequantize_ref(w_gate_q, w_gate_scale.reshape(E, 1, -1)).astype(xe.dtype)
    wo = dequantize_ref(w_out_q, w_out_scale.reshape(E, 1, -1)).astype(xe.dtype)
    return expert_ffn_ref(xe, wi, wg, wo, act=act)


def unpack_int4_ref(packed: Array, k: int) -> Array:
    """Nibble-packed uint8 [..., ceil(k/2), n] -> int8 [..., k, n].

    Byte i holds contraction rows 2i (low nibble) and 2i+1 (high nibble);
    nibbles are two's-complement int4 in [-8, 7]. The in-kernel unpack in
    `expert_gemm._ffn_kernel_q4` is this exact computation."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = (packed >> 4).astype(jnp.int8)
    v = jnp.stack([lo, hi], axis=-2)                 # [..., k/2, 2, n]
    v = v.reshape(*packed.shape[:-2], -1, packed.shape[-1])[..., :k, :]
    return jnp.where(v >= 8, v - 16, v)


def dequantize_q4_ref(packed: Array, scale: Array, k: int) -> Array:
    """int4-packed tensor + per-group per-output-channel scales -> f32.

    scale [..., n_groups, n] carries one f32 per `k // n_groups` contraction
    rows per output channel; groups tile the contraction axis in order."""
    q = unpack_int4_ref(packed, k).astype(jnp.float32)
    ng = scale.shape[-2]
    gs = k // ng
    s = jnp.repeat(scale.astype(jnp.float32), gs, axis=-2)
    return q * s


def expert_ffn_q4_ref(
    xe: Array,             # [E, C, d]
    w_in_q4: Array,        # [E, d//2, F] uint8 (nibble-packed along d)
    w_in_scale: Array,     # [E, d//g, F] f32 per-group scales
    w_gate_q4: Array,      # [E, d//2, F] uint8 or None
    w_gate_scale: Array,   # [E, d//g, F] or None
    w_out_q4: Array,       # [E, F//2, d] uint8 (packed along F)
    w_out_scale: Array,    # [E, F//g, d] f32
    act: str = "silu",
) -> Array:
    """Int4 group-quantized expert FFN oracle: dequantize-then-compute.

    Unlike the int8 per-output-channel case, per-GROUP scales do NOT commute
    with the contraction — the fused kernel computes per-group partial dots
    and applies the scales in the f32 epilogue, which is mathematically this
    materialized-dequant form (same sum, reassociated per group)."""
    d = xe.shape[-1]
    F = w_out_q4.shape[-2] * 2
    wi = dequantize_q4_ref(w_in_q4, w_in_scale, d).astype(xe.dtype)
    wg = None
    if w_gate_q4 is not None:
        wg = dequantize_q4_ref(w_gate_q4, w_gate_scale, d).astype(xe.dtype)
    wo = dequantize_q4_ref(w_out_q4, w_out_scale, F).astype(xe.dtype)
    return expert_ffn_ref(xe, wi, wg, wo, act=act)


def sparsemax_ref(z: Array) -> Array:
    """Row-wise Euclidean projection onto the simplex (Martins & Astudillo)."""
    K = z.shape[-1]
    z_sorted = jnp.sort(z, axis=-1)[..., ::-1]
    z_cum = jnp.cumsum(z_sorted, axis=-1)
    ks = jnp.arange(1, K + 1, dtype=z.dtype)
    support = z_sorted * ks > (z_cum - 1.0)
    k_z = jnp.sum(support, axis=-1, keepdims=True)
    tau = (jnp.take_along_axis(z_cum, k_z - 1, axis=-1) - 1.0) / k_z.astype(z.dtype)
    return jnp.maximum(z - tau, 0.0)


def flash_prefill_ref(
    q: Array,       # [B, S, H, D]
    k: Array,       # [B, S, K, D]
    v: Array,       # [B, S, K, D]
    window: int = 0,
    cap: float = 0.0,
    causal: bool = True,
) -> Array:
    """Full-sequence GQA attention with windows/softcaps (exact softmax)."""
    import math

    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D).astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    logits = logits / math.sqrt(D)
    if cap:
        logits = cap * jnp.tanh(logits / cap)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, D)


def flash_decode_ref(
    q: Array,         # [B, H, D]
    k: Array,         # [B, S, K, D]
    v: Array,         # [B, S, K, D]
    slot_pos: Array,  # [B, S] int32 (-1 = invalid)
    pos: Array,       # [B] int32
    window: int = 0,
    cap: float = 0.0,
) -> Array:
    """One-token attention over a (ring-buffer) KV cache with masking."""
    import math

    B, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, D).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) / math.sqrt(D)
    if cap:
        logits = cap * jnp.tanh(logits / cap)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window:
        valid &= slot_pos > (pos[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(B, H, D)


def flash_decode_paged_ref(
    q: Array,           # [B, H, D]
    kp: Array,          # [P+1, page, K, D] shared page pool (trash page last)
    vp: Array,          # [P+1, page, K, D]
    page_table: Array,  # [B, Mp] int32 (-1 = unallocated/spilled)
    pos: Array,         # [B] int32
    window: int = 0,
    cap: float = 0.0,
) -> Array:
    """Paged decode oracle: gather K/V through the page table, derive each
    slot's global position statically from its table index (pages are
    position-ordered — core/residency.py), then exact masked softmax."""
    B = q.shape[0]
    P1, page, K, D = kp.shape
    Mp = page_table.shape[1]
    pt = jnp.where(page_table >= 0, page_table, P1 - 1)
    k = kp[pt].reshape(B, Mp * page, K, D)
    v = vp[pt].reshape(B, Mp * page, K, D)
    spos = (jnp.arange(Mp)[:, None] * page + jnp.arange(page)[None, :]).reshape(-1)
    slot_pos = jnp.where(
        jnp.repeat(page_table >= 0, page, axis=1), spos[None, :], -1
    )
    return flash_decode_ref(q, k, v, slot_pos, pos, window=window, cap=cap)
