"""Pallas TPU kernel: row-wise SparseMax (hash-function sparse attention).

The reference algorithm sorts each row — sorting maps poorly onto the TPU's
vector unit, so the kernel instead finds the simplex threshold τ by
**bisection** on the monotone function  g(τ) = Σ max(z-τ, 0) − 1
(g is piecewise-linear and strictly decreasing on [max(z)−1, max(z)]):
~60 elementwise iterations, fully vectorised over rows, no data movement.
Validated bit-tight against the sort-based oracle in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_ITERS = 60  # bisection halves the bracket each step: 2^-60 ≈ exact in f32


def _sparsemax_kernel(z_ref, o_ref):
    z = z_ref[...].astype(jnp.float32)            # [br, L]
    z_max = jnp.max(z, axis=-1, keepdims=True)
    lo = z_max - 1.0
    hi = z_max

    def body(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        g = jnp.sum(jnp.maximum(z - mid, 0.0), axis=-1, keepdims=True) - 1.0
        lo = jnp.where(g > 0, mid, lo)
        hi = jnp.where(g > 0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, _ITERS, body, (lo, hi))
    tau = 0.5 * (lo + hi)
    o_ref[...] = jnp.maximum(z - tau, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def sparsemax(z: Array, br: int = 256, interpret: bool = False) -> Array:
    """z: [..., L] -> simplex projection along the last axis."""
    shape = z.shape
    L = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    z2 = z.reshape(rows, L)
    br = min(br, rows)
    pad = (-rows) % br
    if pad:
        z2 = jnp.pad(z2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _sparsemax_kernel,
        grid=((rows + pad) // br,),
        in_specs=[pl.BlockSpec((br, L), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, L), z.dtype),
        interpret=interpret,
    )(z2)
    return out[:rows].reshape(shape)
