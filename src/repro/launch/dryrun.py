import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh and dump roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod|both]

Per combination this produces experiments/dryrun/<arch>__<shape>__<mesh>.json
with: HLO FLOPs, bytes accessed, per-device memory stats, per-collective
byte counts parsed from the compiled HLO, and wall times. Failures here are
bugs in the sharding policy, not in the harness.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig, get_config, list_configs, shape_supported
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import AUDIO_ENC_FRAMES, input_specs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.transformer import init_params
from repro.optim.adamw import adamw_init
from repro.sharding import policy

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

# ---------------------------------------------------------------------------


def build_lowering(cfg: ModelConfig, shape_name, mesh, donate: bool = True):
    """Returns (lowered, meta) for the right step function.

    `shape_name` may be a key of INPUT_SHAPES or an InputShape (tests use
    reduced shapes on small fake-device meshes).
    """
    shape = INPUT_SHAPES[shape_name] if isinstance(shape_name, str) else shape_name
    ctx = policy.make_ctx(mesh)
    pspecs = policy.param_specs(cfg, mesh)
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(partial(init_params, cfg=cfg), key)
    ins = input_specs(cfg, shape)
    B = shape.global_batch
    tok_spec = policy.token_specs(mesh, B)

    def nshard(spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    if shape.kind == "train":
        # bf16 optimizer moments at production scale (§Perf iteration 5)
        o_shapes = jax.eval_shape(
            partial(adamw_init, moment_dtype=jnp.bfloat16), p_shapes
        )
        ospecs = policy.opt_specs(cfg, mesh, pspecs)
        step = make_train_step(cfg, ctx, param_pspecs=pspecs)
        args = [p_shapes, o_shapes, ins["tokens"], ins["labels"]]
        in_shardings = [nshard(pspecs), nshard(ospecs), nshard(tok_spec), nshard(tok_spec)]
        if cfg.enc_dec:
            args.append(ins["enc_input"])
            in_shardings.append(
                NamedSharding(mesh, P(policy.batch_axes_for(mesh, B), None, None))
            )
        jitted = jax.jit(
            step,
            in_shardings=tuple(in_shardings),
            donate_argnums=(0, 1) if donate else (),
        )
        return jitted.lower(*args), {"ctx": ctx}

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, ctx)
        args = [p_shapes, ins["tokens"]]
        in_shardings = [nshard(pspecs), nshard(tok_spec)]
        if cfg.enc_dec:
            args.append(ins["enc_input"])
            in_shardings.append(
                NamedSharding(mesh, P(policy.batch_axes_for(mesh, B), None, None))
            )
        jitted = jax.jit(step, in_shardings=tuple(in_shardings))
        return jitted.lower(*args), {"ctx": ctx}

    # decode
    b_ax, seq_axes = policy.decode_plan(mesh, B)
    ctx = dataclasses.replace(ctx, decode_seq_axis=seq_axes)
    cspecs = policy.cache_specs(
        cfg, mesh, B, shape.seq_len, AUDIO_ENC_FRAMES if cfg.enc_dec else 0
    )
    step = make_serve_step(cfg, ctx)
    jitted = jax.jit(
        step,
        in_shardings=(nshard(pspecs), nshard(cspecs), NamedSharding(mesh, P(b_ax))),
        donate_argnums=(1,) if donate else (),
    )
    return jitted.lower(p_shapes, ins["cache"], ins["tokens"]), {"ctx": ctx}


def analyse(cfg: ModelConfig, shape_name: str, mesh_kind: str, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    shape = INPUT_SHAPES[shape_name]
    rec = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_kind,
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "status": "ok",
    }
    t0 = time.perf_counter()
    try:
        lowered, _ = build_lowering(cfg, shape_name, mesh)
        rec["lower_s"] = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1
        ca = compiled.cost_analysis() or {}
        # NB: XLA's cost_analysis visits while bodies once — kept for
        # reference only; the roofline uses the trip-count-aware numbers.
        rec["xla_flops_loopbody_once"] = float(ca.get("flops", 0.0))
        rec["xla_bytes_loopbody_once"] = float(ca.get("bytes accessed", 0.0))
        ma = compiled.memory_analysis()
        for f in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            rec[f] = int(getattr(ma, f, 0))
        txt = compiled.as_text()
        from repro.launch.hlo_analysis import analyse_hlo

        hlo = analyse_hlo(txt)
        rec["flops"] = hlo["flops"]                       # per device
        rec["bytes_accessed"] = hlo["bytes"]              # per device (writes proxy)
        rec["collectives"] = {
            "bytes": hlo["collective_bytes"],
            "counts": hlo["collective_counts"],
        }
        rec["collective_total_bytes"] = hlo["collective_total_bytes"]
        rec["hlo_lines"] = txt.count("\n")
        if verbose:
            dev_gb = (rec["argument_size_in_bytes"] + rec["temp_size_in_bytes"]
                      + rec["output_size_in_bytes"] - rec["alias_size_in_bytes"]) / 1e9
            print(
                f"  OK   lower {rec['lower_s']:.1f}s compile {rec['compile_s']:.1f}s "
                f"flops/dev {rec['flops']:.3e} bytes/dev {rec['bytes_accessed']:.3e} "
                f"mem/dev ~{dev_gb:.2f} GB "
                f"coll/dev {rec['collective_total_bytes']/1e9:.3f} GB"
            )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"  FAIL {type(e).__name__}: {str(e)[:200]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = list_configs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, why = shape_supported(cfg, INPUT_SHAPES[shape_name])
            if not ok:
                print(f"{arch} × {shape_name}: SKIP ({why})")
                continue
            for mesh_kind in meshes:
                print(f"{arch} × {shape_name} × {mesh_kind}:")
                rec = analyse(cfg, shape_name, mesh_kind)
                failures += rec["status"] != "ok"
                fname = f"{arch}__{shape_name}__{mesh_kind}.json"
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=2)
    print(f"\ndry-run complete; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
