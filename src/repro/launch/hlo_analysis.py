"""Trip-count-aware HLO analysis.

XLA's `compiled.cost_analysis()` visits `while` bodies ONCE (verified
empirically: a 10-step scan reports 10x fewer FLOPs than its unrolled
equivalent). Every model here runs its depth dimension under `lax.scan`,
so naive cost_analysis would undercount by ~n_layers. This module parses
`compiled.as_text()` into its computation graph, multiplies each
computation's costs by the product of enclosing loop trip counts
(`backend_config={"known_trip_count":{"n":...}}`), and reports:

  * flops      — 2 x MACs of every dot (batch x M x N x K from shapes +
                 contracting dims). Elementwise FLOPs are excluded (dots
                 dominate every model here); documented in EXPERIMENTS.md.
  * bytes      — sum of result-shape bytes of all value-producing
                 instructions (proxy for HBM write traffic; reads are the
                 same order). Bookkeeping ops excluded.
  * collectives — result-shape bytes + op counts per collective type.

All numbers are per-device (the HLO is the SPMD-partitioned module).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+([\w\-]+)\("
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{")
_CALLEE_RE = re.compile(
    r"(?:calls|body|to_apply|true_computation|false_computation)=(%[\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _dims(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)  # value name -> shape str


def parse_computations(txt: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = Computation(mc.group(2))
            comps[cur.name] = cur
            if mc.group(1):
                entry = cur.name
            # parameters appear in the signature; they also appear as
            # `%x = shape parameter(n)` instructions, handled below.
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            ins = Instr(mi.group(1), mi.group(2), mi.group(3), line)
            cur.instrs.append(ins)
            cur.defs[ins.name] = ins.shape
    return comps, entry


_SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
}


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for _, dims in _dims(ins.shape):
        for d in dims:
            out_elems *= d
        break  # single result
    mcd = _CONTRACT_RE.search(ins.line)
    # operand shapes: first operand name inside parens. Operands may be
    # bare (`dot(%a, %b)`) or typed (`dot(f32[64,64]{1,0} %a, ...)`,
    # newer XLA) — and typed shapes contain commas, so pull the %names
    # out by token instead of comma-splitting the operand list.
    mop = re.search(r"\(([^)]*)\)", ins.line[ins.line.index(ins.op) :])
    k = 1
    if mcd and mop:
        names = re.findall(r"%[\w.\-]+", mop.group(1))
        lhs_shape = comp.defs.get(names[0]) if names else None
        if lhs_shape:
            dims = _dims(lhs_shape)[0][1]
            for ci in (int(c) for c in mcd.group(1).split(",") if c):
                if ci < len(dims):
                    k *= dims[ci]
    return 2.0 * out_elems * k


def analyse_hlo(txt: str) -> Dict:
    comps, entry = parse_computations(txt)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    from functools import lru_cache

    def comp_cost(name: str) -> Dict:
        comp = comps.get(name)
        res = {
            "flops": 0.0,
            "bytes": 0.0,
            "coll_bytes": {c: 0.0 for c in COLLECTIVES},
            "coll_counts": {c: 0.0 for c in COLLECTIVES},
        }
        if comp is None:
            return res
        for ins in comp.instrs:
            mult = 1.0
            callee_costs = []
            mt = _TRIP_RE.search(ins.line)
            if ins.op == "while" and mt:
                mult = float(mt.group(1))
            for cm in _CALLEE_RE.finditer(ins.line):
                callee_costs.append(cache_cost(cm.group(1)))
            mb = _BRANCHES_RE.search(ins.line)
            if mb:
                for b in mb.group(1).split(","):
                    callee_costs.append(cache_cost(b.strip()))
            # Fusion bodies execute their FLOPs but keep intermediates in
            # registers/VMEM — only the fusion's OWN result reaches HBM, so
            # callee bytes are not propagated through fusion call-sites.
            include_callee_bytes = ins.op != "fusion"
            for cc in callee_costs:
                res["flops"] += mult * cc["flops"]
                if include_callee_bytes:
                    res["bytes"] += mult * cc["bytes"]
                for c in COLLECTIVES:
                    res["coll_bytes"][c] += mult * cc["coll_bytes"][c]
                    res["coll_counts"][c] += mult * cc["coll_counts"][c]
            if ins.op == "dot":
                res["flops"] += _dot_flops(ins, comp)
            base = None
            for c in COLLECTIVES:
                if ins.op == c or ins.op.startswith(c + "-"):
                    base = c
                    break
            if base and not ins.op.endswith("-done"):
                b = shape_bytes(ins.shape)
                res["coll_bytes"][base] += b
                res["coll_counts"][base] += 1
            if ins.op not in _SKIP_BYTES_OPS:
                res["bytes"] += shape_bytes(ins.shape)
        return res

    @lru_cache(maxsize=None)
    def cache_cost(name: str) -> Dict:
        return comp_cost(name)

    total = cache_cost(entry)
    return {
        "flops": total["flops"],
        "bytes": total["bytes"],
        "collective_bytes": dict(total["coll_bytes"]),
        "collective_counts": dict(total["coll_counts"]),
        "collective_total_bytes": sum(total["coll_bytes"].values()),
    }
