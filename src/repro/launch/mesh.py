"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") —
the `pod` axis is pure data parallelism across ICI-disjoint pods (DCN).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """`axis_types` only where this jax has it (added in 0.4.34); older
    versions default to Auto semantics anyway."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests use small fake-device meshes)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh() -> Optional[jax.sharding.Mesh]:
    """Degenerate mesh over whatever devices exist (smoke/CPU runs)."""
    n = len(jax.devices())
    if n == 1:
        return None
    return make_mesh((1, n), ("data", "model"))


def make_ep_mesh(ep_shards: int) -> jax.sharding.Mesh:
    """1-D expert-parallel serving mesh over the first `ep_shards` devices
    (the sharded slot pools partition over its single "model" axis)."""
    assert ep_shards >= 1
    assert len(jax.devices()) >= ep_shards, (
        f"need {ep_shards} devices, have {len(jax.devices())}"
    )
    return make_mesh((ep_shards,), ("model",))
