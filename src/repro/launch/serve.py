"""Serving launcher: SiDA engine vs baselines on a (reduced) MoE arch.

Batch mode (the paper's setting — static, pre-formed batches):

    PYTHONPATH=src python -m repro.launch.serve --arch switch-base-8 \
        --engine sida --slots 2 --batches 8 --batch 4 --seq 32

Request mode (continuous batching + SLA-aware scheduling over a Poisson
arrival stream):

    PYTHONPATH=src python -m repro.launch.serve --engine server \
        --requests 16 --rate 4 --lanes 4 --slots 2 --slo 60

Trains nothing: random weights + untrained hash function (use
examples/serve_sida.py for the full train->distill->serve pipeline).
Prints throughput / latency / device-memory for the chosen engine;
request mode emits the full telemetry snapshot as JSON.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import TierConfig, get_config
from repro.core.baselines import OnDemandServer, PrefetchAllServer, StandardServer
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.offload import ShardedStoreConfig
from repro.models.attention import ShardingCtx
from repro.models.transformer import init_params, n_moe_layers
from repro.serving.config import (
    ServingConfig,
    ServingConfigError,
    add_serving_args,
)


def ep_setup(ep_shards: int, replicate_hot: int = 0):
    """(ctx, sharded) for --ep-shards: a 1-D "model" mesh over `ep_shards`
    devices with the expert-parallel serving context (slot pools + expert
    FFN sharded, everything else replicated), or the single-device defaults
    when ep_shards <= 1. `replicate_hot` lets α-hot experts hold that many
    extra copies on other shards (see ShardedStoreConfig)."""
    if ep_shards <= 1:
        return ShardingCtx(), None
    from repro.launch.mesh import make_ep_mesh
    from repro.sharding.policy import serve_ctx

    mesh = make_ep_mesh(ep_shards)
    return serve_ctx(mesh), ShardedStoreConfig(
        ep_shards=ep_shards, replicate_hot=replicate_hot
    )


def build_engine(engine: str, cfg, params, slots: int, eviction: str = "fifo",
                 prefetch_depth: int = 0, staging_buffers: int = 2,
                 host_quant: str = "none", quantized_slots: bool = False,
                 scale_granularity: str = "channel", ep_shards: int = 1,
                 replicate_hot: int = 0, tier: TierConfig | None = None):
    if engine == "standard":
        return StandardServer(cfg, params)
    if engine == "ondemand":
        return OnDemandServer(cfg, params, slots_per_layer=slots)
    if engine == "prefetchall":
        return PrefetchAllServer(cfg, params, slots_per_layer=slots)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=64,
    )
    ctx, sharded = ep_setup(ep_shards, replicate_hot)
    return SiDAEngine(
        cfg, params, hp, slots_per_layer=slots, eviction=eviction,
        prefetch_depth=prefetch_depth, staging_buffers=staging_buffers,
        host_quant=host_quant, quantized_slots=quantized_slots,
        scale_granularity=scale_granularity, tier=tier, ctx=ctx, sharded=sharded,
    )


def serve_tier(args) -> TierConfig | None:
    """TierConfig for --int4-slots (hot int8 / warm int4 residency tiers),
    or None when tiering is off (the untiered path must stay byte-identical,
    so no TierConfig object is threaded at all)."""
    if not args.int4_slots:
        return None
    return TierConfig(
        int4_slots=True, tier_split=args.tier_split,
        group_size=args.quant_group,
    )


def validate_serve_args(args) -> "ServingConfig":
    """Fail fast on incoherent flag combinations, with actionable errors —
    the alternative is a mid-run assert deep inside the server/pool.

    All cross-field CONFIG rules live in `ServingConfig.from_args/validate`
    (serving/config.py) — this wrapper adds only the launcher-level checks
    (which flags require `--engine server`) and converts the structured
    `ServingConfigError` into the CLI's SystemExit."""

    def die(msg: str) -> None:
        raise SystemExit(f"serve: invalid flags: {msg}")

    if args.engine != "server":
        server_only = {
            "--rebalance-interval": args.rebalance_interval,
            "--kv-pages": args.kv_pages,
            "--max-seq": args.max_seq,
            "--prefill-chunk": args.prefill_chunk,
            "--fault-plan": args.fault_plan,
            "--fence-timeout": args.fence_timeout,
            "--shed-margin": args.shed_margin,
            "--tenants": args.tenants,
        }
        for flag, val in server_only.items():
            if val:
                die(f"{flag} applies to the request server: "
                    "use --engine server")
    if args.shed_margin and args.slo is None and not args.tenants:
        die("--shed-margin needs a deadline to protect: also pass --slo "
            "(or tenant default SLOs)")
    try:
        return ServingConfig.from_args(args)
    except ServingConfigError as e:
        die(str(e))


def run_request_server(cfg, params, args, serving_cfg=None) -> None:
    from repro.serving import RequestServer, poisson_requests

    if serving_cfg is None:
        serving_cfg = validate_serve_args(args)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=64, draft=args.spec_mode == "draft",
    )
    ctx, _ = ep_setup(args.ep_shards, args.replicate_hot)
    srv = RequestServer(cfg, params, hp, serving_cfg, ctx=ctx)
    rng = np.random.default_rng(0)
    if serving_cfg.multitenant:
        # one independent Poisson stream per tenant, all at --rate; rid
        # ranges are disjoint so logs stay unambiguous
        reqs = []
        for i, t in enumerate(serving_cfg.tenants):
            reqs.extend(poisson_requests(
                rng, args.requests, rate_rps=args.rate,
                vocab_size=cfg.vocab_size, prompt_len_range=(4, args.seq),
                max_new_range=(2, args.new_tokens),
                slo_s=args.slo, tenant=t.name, rid_base=i * args.requests,
            ))
    else:
        reqs = poisson_requests(
            rng, args.requests, rate_rps=args.rate, vocab_size=cfg.vocab_size,
            prompt_len_range=(4, args.seq), max_new_range=(2, args.new_tokens),
            slo_s=args.slo,
        )
    srv.run(reqs, realtime=not args.no_realtime)
    print(f"engine=server slots={args.slots} lanes={args.lanes} "
          f"eviction={args.eviction} rate={args.rate}rps "
          f"prefetch_depth={args.prefetch_depth} "
          f"quantized_slots={args.quantized_slots} "
          f"int4_slots={args.int4_slots} "
          f"tier_split={args.tier_split} "
          f"spec={args.spec_mode}/k{args.spec_k} "
          f"ep_shards={args.ep_shards} "
          f"replicate_hot={args.replicate_hot} "
          f"rebalance_interval={args.rebalance_interval} "
          f"kv_pages={args.kv_pages}x{args.page_size} "
          f"prefill_chunk={args.prefill_chunk} "
          f"fault_plan={args.fault_plan or 'none'} "
          f"shed_margin={args.shed_margin} "
          f"tenants={args.tenants or 'none'}")
    for k, v in srv.summary().items():
        print(f"  {k:20s} {v:.4f}")
    for name, block in srv.tenant_summary().items():
        print(f"  tenant {name}:")
        for k, v in block.items():
            print(f"    {k:20s} {v:.4f}")
    print(srv.telemetry.to_json())
    srv.close()


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI: launcher/workload flags declared here, every serving
    knob registered from `SERVE_FLAGS` (serving/config.py) — one table
    drives argparse, `ServingConfig.from_args`, and the README flag table
    (tools/gen_flags.py), so the three cannot drift."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="switch-base-8",
                    help="architecture config name (configs/)")
    ap.add_argument("--engine", default="sida",
                    choices=["sida", "standard", "ondemand", "prefetchall",
                             "server"],
                    help="batch engines (sida | standard | ondemand | "
                         "prefetchall) or the continuous-batching request "
                         "server")
    ap.add_argument("--batches", type=int, default=8,
                    help="batch-mode workload: number of batches")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch-mode workload: sequences per batch")
    ap.add_argument("--seq", type=int, default=32,
                    help="workload sequence / max prompt length")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced() laptop size)")
    ap.add_argument("--requests", type=int, default=16,
                    help="(server) Poisson-arrival requests (per tenant)")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="(server) arrival rate, requests/sec")
    ap.add_argument("--new-tokens", type=int, default=8,
                    help="(server) max decode budget per request")
    ap.add_argument("--slo", type=float, default=None,
                    help="(server) latency SLO in seconds (EDF deadline)")
    ap.add_argument("--no-realtime", action="store_true",
                    help="(server) ignore arrival gaps (fast smoke runs)")
    add_serving_args(ap)
    return ap


def main():
    args = build_parser().parse_args()
    serving_cfg = validate_serve_args(args)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    assert cfg.moe.enabled, "serving engines target MoE architectures"
    params = init_params(jax.random.PRNGKey(0), cfg)

    if args.engine == "server":
        run_request_server(cfg, params, args, serving_cfg)
        return

    rng = np.random.default_rng(0)
    batches = [
        rng.integers(0, cfg.vocab_size, (args.batch, args.seq)).astype(np.int32)
        for _ in range(args.batches)
    ]
    srv = build_engine(args.engine, cfg, params, args.slots, args.eviction,
                       args.prefetch_depth, args.staging_buffers,
                       args.host_quant, args.quantized_slots,
                       args.scale_granularity, args.ep_shards,
                       tier=serve_tier(args))
    metrics = srv.serve(batches)
    print(f"engine={args.engine} slots={args.slots}")
    for k, v in metrics.summary().items():
        print(f"  {k:20s} {v:.4f}")
    print(f"  device_mem_mb        {srv.device_memory_bytes()/1e6:.2f}")
    if isinstance(srv, SiDAEngine):
        for k, v in srv.memory_saving().items():
            print(f"  {k:20s} {v:.4f}")
        st = srv.store.stats
        print(f"  loads={st.loads} hits={st.hits} evictions={st.evictions} "
              f"h2d_mb={st.bytes_h2d/1e6:.2f} sync_upload_s={st.prepare_time:.4f}")
        if srv.prefetcher is not None:
            for k, v in srv.prefetcher.stats.summary().items():
                print(f"  {k:22s} {v:.4f}")
        srv.close()


if __name__ == "__main__":
    main()
