"""Serving launcher: SiDA engine vs baselines on a (reduced) MoE arch.

Batch mode (the paper's setting — static, pre-formed batches):

    PYTHONPATH=src python -m repro.launch.serve --arch switch-base-8 \
        --engine sida --slots 2 --batches 8 --batch 4 --seq 32

Request mode (continuous batching + SLA-aware scheduling over a Poisson
arrival stream):

    PYTHONPATH=src python -m repro.launch.serve --engine server \
        --requests 16 --rate 4 --lanes 4 --slots 2 --slo 60

Trains nothing: random weights + untrained hash function (use
examples/serve_sida.py for the full train->distill->serve pipeline).
Prints throughput / latency / device-memory for the chosen engine;
request mode emits the full telemetry snapshot as JSON.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import TierConfig, get_config
from repro.core.baselines import OnDemandServer, PrefetchAllServer, StandardServer
from repro.core.engine import SiDAEngine
from repro.core.faults import KNOWN_SITES, FaultPlan
from repro.core.hash_fn import init_hash_fn
from repro.core.offload import ShardedStoreConfig
from repro.models.attention import ShardingCtx
from repro.models.transformer import init_params, n_moe_layers


def ep_setup(ep_shards: int, replicate_hot: int = 0):
    """(ctx, sharded) for --ep-shards: a 1-D "model" mesh over `ep_shards`
    devices with the expert-parallel serving context (slot pools + expert
    FFN sharded, everything else replicated), or the single-device defaults
    when ep_shards <= 1. `replicate_hot` lets α-hot experts hold that many
    extra copies on other shards (see ShardedStoreConfig)."""
    if ep_shards <= 1:
        return ShardingCtx(), None
    from repro.launch.mesh import make_ep_mesh
    from repro.sharding.policy import serve_ctx

    mesh = make_ep_mesh(ep_shards)
    return serve_ctx(mesh), ShardedStoreConfig(
        ep_shards=ep_shards, replicate_hot=replicate_hot
    )


def build_engine(engine: str, cfg, params, slots: int, eviction: str = "fifo",
                 prefetch_depth: int = 0, staging_buffers: int = 2,
                 host_quant: str = "none", quantized_slots: bool = False,
                 scale_granularity: str = "channel", ep_shards: int = 1,
                 replicate_hot: int = 0, tier: TierConfig | None = None):
    if engine == "standard":
        return StandardServer(cfg, params)
    if engine == "ondemand":
        return OnDemandServer(cfg, params, slots_per_layer=slots)
    if engine == "prefetchall":
        return PrefetchAllServer(cfg, params, slots_per_layer=slots)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=64,
    )
    ctx, sharded = ep_setup(ep_shards, replicate_hot)
    return SiDAEngine(
        cfg, params, hp, slots_per_layer=slots, eviction=eviction,
        prefetch_depth=prefetch_depth, staging_buffers=staging_buffers,
        host_quant=host_quant, quantized_slots=quantized_slots,
        scale_granularity=scale_granularity, tier=tier, ctx=ctx, sharded=sharded,
    )


def serve_tier(args) -> TierConfig | None:
    """TierConfig for --int4-slots (hot int8 / warm int4 residency tiers),
    or None when tiering is off (the untiered path must stay byte-identical,
    so no TierConfig object is threaded at all)."""
    if not args.int4_slots:
        return None
    return TierConfig(
        int4_slots=True, tier_split=args.tier_split,
        group_size=args.quant_group,
    )


def validate_serve_args(args) -> None:
    """Fail fast on incoherent flag combinations, with actionable errors —
    the alternative is a mid-run assert deep inside the server/pool."""

    def die(msg: str) -> None:
        raise SystemExit(f"serve: invalid flags: {msg}")

    if args.int4_slots:
        if not args.quantized_slots:
            die("--int4-slots extends the quantized slot pool: also pass "
                "--quantized-slots (hot tier stays int8)")
        if args.replicate_hot:
            die("--int4-slots and --replicate-hot are mutually exclusive "
                "(replicas assume a single uniform slot pool)")
        if not (0.0 < args.tier_split <= 1.0):
            die(f"--tier-split {args.tier_split} must be in (0, 1]: the "
                "fraction of the slot byte budget held as int8 hot slots")
        if args.quant_group <= 0:
            die("--quant-group must be >= 1 (int4 scale group size along "
                "the contraction axis)")
    if args.kv_pages < 0 or args.page_size <= 0 or args.prefill_chunk < 0:
        die("--kv-pages/--prefill-chunk must be >= 0 and --page-size >= 1")
    if args.replicate_hot < 0 or args.rebalance_interval < 0:
        die("--replicate-hot and --rebalance-interval must be >= 0")
    if (args.replicate_hot or args.rebalance_interval) and args.ep_shards <= 1:
        die("--replicate-hot/--rebalance-interval need --ep-shards > 1 "
            "(replication and placement act across expert-parallel shards)")
    if args.rebalance_interval and args.engine != "server":
        die("--rebalance-interval applies to the request server: "
            "use --engine server")
    if args.prefill_chunk and not args.kv_pages:
        die("--prefill-chunk needs the paged K/V cache: also pass --kv-pages")
    if args.kv_pages:
        if args.engine != "server":
            die("--kv-pages applies to the request server: use --engine server")
        resident = args.kv_pages * args.page_size
        seq_len = args.max_seq or resident
        if args.max_seq and args.max_seq < resident:
            die(
                f"--max-seq {args.max_seq} is below the resident pool "
                f"({args.kv_pages} x {args.page_size} = {resident}); drop "
                "--max-seq or shrink the pool"
            )
        if args.seq > serve_bucket_limit(args) and not args.prefill_chunk:
            die(
                f"--seq {args.seq} exceeds the largest prefill bucket "
                f"({serve_bucket_limit(args)}): such prompts would be "
                "rejected at admission — pass --prefill-chunk to stream "
                "them through the paged cache, or raise --kv-pages"
            )
        if args.seq + args.new_tokens > seq_len:
            die(
                f"--seq {args.seq} + --new-tokens {args.new_tokens} exceeds "
                f"the addressable range {seq_len}: such requests would be "
                "rejected at admission — raise --max-seq (spilled pages "
                "live on host, so it may exceed the resident pool)"
            )
        need = -(-serve_bucket_limit(args) // args.page_size)
        if args.kv_pages < need:
            die(
                f"--kv-pages {args.kv_pages} cannot seed one full prefill "
                f"bucket ({serve_bucket_limit(args)} tokens = {need} pages "
                f"of {args.page_size}); raise --kv-pages to >= {need}"
            )
        if args.spec_mode == "draft" and args.spec_k > resident:
            die(
                f"--spec-k {args.spec_k} exceeds the resident K/V pool "
                f"({resident} positions); a verify block must fit in "
                "device pages"
            )
    elif args.max_seq:
        die("--max-seq needs the paged K/V cache: also pass --kv-pages")
    if args.fault_plan:
        if args.engine != "server":
            die("--fault-plan applies to the request server: use "
                "--engine server")
        try:
            plan = FaultPlan.parse(args.fault_plan, seed=args.fault_seed)
        except ValueError as e:
            die(f"--fault-plan: {e}")
        for spec in plan.specs:
            if spec.site not in KNOWN_SITES:
                die(f"--fault-plan: site {spec.site!r} is not instrumented "
                    f"(known sites: {', '.join(KNOWN_SITES)})")
    if args.fence_timeout < 0 or args.shed_margin < 0:
        die("--fence-timeout and --shed-margin must be >= 0")
    if (args.fence_timeout or args.shed_margin) and args.engine != "server":
        die("--fence-timeout/--shed-margin apply to the request server: "
            "use --engine server")
    if args.shed_margin and args.slo is None:
        die("--shed-margin needs a deadline to protect: also pass --slo")


def serve_bucket_limit(args) -> int:
    """Largest prefill bucket the launcher will build. Paged serving caps
    buckets at what the resident pool can seed in one shot (and, with
    chunked prefill on, at the default 128 — longer prompts stream)."""
    limit = args.seq
    if args.kv_pages:
        limit = min(limit, args.kv_pages * args.page_size)
        if args.prefill_chunk:
            limit = min(limit, 128)
    bucket = 8
    while bucket < limit:
        bucket *= 2
    return bucket


def run_request_server(cfg, params, args) -> None:
    from repro.core.residency import PagedKVConfig
    from repro.serving import AdmissionController, RequestServer, poisson_requests

    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=64, draft=args.spec_mode == "draft",
    )
    buckets = [8]
    while buckets[-1] < serve_bucket_limit(args):
        buckets.append(2 * buckets[-1])
    paged = None
    if args.kv_pages:
        paged = PagedKVConfig(
            page_size=args.page_size, kv_pages=args.kv_pages,
            prefill_chunk=args.prefill_chunk, max_seq=args.max_seq,
        )
    ctx, sharded = ep_setup(args.ep_shards, args.replicate_hot)
    faults = (
        FaultPlan.parse(args.fault_plan, seed=args.fault_seed)
        if args.fault_plan else None
    )
    shed = (
        AdmissionController(margin=args.shed_margin)
        if args.shed_margin else None
    )
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=args.slots,
        max_lanes=args.lanes, max_prefill_batch=args.prefill_batch,
        buckets=tuple(buckets), eviction=args.eviction,
        drop_expired=args.drop_expired,
        prefetch_depth=args.prefetch_depth,
        staging_buffers=args.staging_buffers,
        host_quant=args.host_quant,
        quantized_slots=args.quantized_slots,
        scale_granularity=args.scale_granularity,
        tier=serve_tier(args),
        spec_mode=args.spec_mode,
        spec_k=args.spec_k,
        ctx=ctx, sharded=sharded,
        rebalance_interval=args.rebalance_interval,
        paged=paged,
        faults=faults,
        fence_timeout_s=args.fence_timeout or None,
        shed=shed,
    )
    rng = np.random.default_rng(0)
    reqs = poisson_requests(
        rng, args.requests, rate_rps=args.rate, vocab_size=cfg.vocab_size,
        prompt_len_range=(4, args.seq), max_new_range=(2, args.new_tokens),
        slo_s=args.slo,
    )
    srv.run(reqs, realtime=not args.no_realtime)
    print(f"engine=server slots={args.slots} lanes={args.lanes} "
          f"eviction={args.eviction} rate={args.rate}rps "
          f"prefetch_depth={args.prefetch_depth} "
          f"quantized_slots={args.quantized_slots} "
          f"int4_slots={args.int4_slots} "
          f"tier_split={args.tier_split} "
          f"spec={args.spec_mode}/k{args.spec_k} "
          f"ep_shards={args.ep_shards} "
          f"replicate_hot={args.replicate_hot} "
          f"rebalance_interval={args.rebalance_interval} "
          f"kv_pages={args.kv_pages}x{args.page_size} "
          f"prefill_chunk={args.prefill_chunk} "
          f"fault_plan={args.fault_plan or 'none'} "
          f"shed_margin={args.shed_margin}")
    for k, v in srv.summary().items():
        print(f"  {k:20s} {v:.4f}")
    print(srv.telemetry.to_json())
    srv.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="switch-base-8")
    ap.add_argument("--engine", default="sida",
                    choices=["sida", "standard", "ondemand", "prefetchall",
                             "server"])
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--full", action="store_true", help="full-size config")
    ap.add_argument("--eviction", default="fifo",
                    choices=["fifo", "lru", "alpha"])
    ap.add_argument("--prefetch-depth", type=int, default=0,
                    help="async prefetch lookahead (0 = synchronous uploads)")
    ap.add_argument("--staging-buffers", type=int, default=2,
                    help="host staging slabs for the transfer thread")
    ap.add_argument("--host-quant", default="none", choices=["none", "int8"],
                    help="host expert tier format (int8 halves H2D bytes; "
                         "dequantised at slot write unless --quantized-slots)")
    ap.add_argument("--quantized-slots", action="store_true",
                    help="int8 device-resident slots + fused-dequant expert "
                         "FFN (2-4x resident experts per slot byte; implies "
                         "--host-quant int8)")
    ap.add_argument("--scale-granularity", default="channel",
                    choices=["channel", "tensor"],
                    help="int8 scale granularity per expert tensor")
    ap.add_argument("--int4-slots", action="store_true",
                    help="hierarchical residency tiers: keep the hot tier "
                         "int8 and add a warm tier of nibble-packed int4 "
                         "slots with per-group scales (~2x experts per "
                         "byte); requires --quantized-slots")
    ap.add_argument("--tier-split", type=float, default=0.5,
                    help="fraction of the slot byte budget held as int8 hot "
                         "slots; the remainder becomes int4 warm slots "
                         "(1.0 = all-hot, degenerate to --quantized-slots)")
    ap.add_argument("--quant-group", type=int, default=64,
                    help="int4 scale group size along the contraction axis "
                         "(smaller = tighter error, more scale-plane bytes)")
    ap.add_argument("--spec-mode", default="off", choices=["off", "draft"],
                    help="speculative decode: 'draft' unrolls the hash "
                         "predictor's tied-embedding next-token head and "
                         "verifies k tokens per step (request-server mode)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per verify step; the union "
                         "of all k positions' predicted experts ships as "
                         "one superset prefetch ticket")
    ap.add_argument("--ep-shards", type=int, default=1,
                    help="expert-parallel serving shards: partition the "
                         "slot pools (and prefetch transfer queues) over a "
                         "1-D 'model' mesh of this many devices; the expert "
                         "FFN runs inside shard_map (fused dequant when "
                         "--quantized-slots). 1 = single-device serving")
    ap.add_argument("--replicate-hot", type=int, default=0,
                    help="extra copies an α-mass-hot expert may hold on "
                         "other shards (free slots only; translation "
                         "round-robins tokens over the copies). Requires "
                         "--ep-shards > 1; 0 = fixed single-copy placement")
    ap.add_argument("--rebalance-interval", type=float, default=0.0,
                    help="seconds between online home-shard re-placements "
                         "driven by the decayed α-mass EMA (request-server "
                         "mode; requires --ep-shards > 1; 0 = off)")
    # request-server mode
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="paged K/V cache: device page budget shared by all "
                         "lanes (0 = ring cache). Spilled pages live on "
                         "host and page back in over the prefetch queues")
    ap.add_argument("--page-size", type=int, default=16,
                    help="K/V page size in token positions")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: stream prompts longer than the "
                         "largest bucket through the paged cache in chunks "
                         "of this many tokens, interleaved with decode "
                         "ticks (0 = off; requires --kv-pages)")
    ap.add_argument("--max-seq", type=int, default=0,
                    help="addressable sequence length (page-table width); "
                         "0 = kv-pages * page-size (everything resident). "
                         "May exceed the resident pool: the excess spills")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0, help="arrivals/sec")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--prefill-batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slo", type=float, default=None, help="latency SLO (s)")
    ap.add_argument("--drop-expired", action="store_true")
    ap.add_argument("--fault-plan", default="",
                    help="seeded chaos schedule for the serving stack, e.g. "
                         "'upload:fail,p=0.2;thread:crash@2' — see "
                         "core/faults.py for the grammar. Exercises the "
                         "supervision machinery (retry/backoff, fence "
                         "poisoning, degraded sync fallback) deterministically")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="RNG seed for probabilistic (p=) fault specs")
    ap.add_argument("--fence-timeout", type=float, default=0.0,
                    help="bound (s) a serve tick waits on prefetch fences "
                         "before falling back to a synchronous prepare "
                         "(0 = wait indefinitely)")
    ap.add_argument("--shed-margin", type=float, default=0.0,
                    help="overload shedding: reject at admission when "
                         "estimated queue wait exceeds this fraction of a "
                         "request's deadline slack (0 = no shedding; "
                         "requires --slo)")
    ap.add_argument("--no-realtime", action="store_true",
                    help="ignore arrival gaps (fast smoke runs)")
    args = ap.parse_args()
    validate_serve_args(args)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    assert cfg.moe.enabled, "serving engines target MoE architectures"
    params = init_params(jax.random.PRNGKey(0), cfg)

    if args.engine == "server":
        run_request_server(cfg, params, args)
        return

    rng = np.random.default_rng(0)
    batches = [
        rng.integers(0, cfg.vocab_size, (args.batch, args.seq)).astype(np.int32)
        for _ in range(args.batches)
    ]
    srv = build_engine(args.engine, cfg, params, args.slots, args.eviction,
                       args.prefetch_depth, args.staging_buffers,
                       args.host_quant, args.quantized_slots,
                       args.scale_granularity, args.ep_shards,
                       tier=serve_tier(args))
    metrics = srv.serve(batches)
    print(f"engine={args.engine} slots={args.slots}")
    for k, v in metrics.summary().items():
        print(f"  {k:20s} {v:.4f}")
    print(f"  device_mem_mb        {srv.device_memory_bytes()/1e6:.2f}")
    if isinstance(srv, SiDAEngine):
        for k, v in srv.memory_saving().items():
            print(f"  {k:20s} {v:.4f}")
        st = srv.store.stats
        print(f"  loads={st.loads} hits={st.hits} evictions={st.evictions} "
              f"h2d_mb={st.bytes_h2d/1e6:.2f} sync_upload_s={st.prepare_time:.4f}")
        if srv.prefetcher is not None:
            for k, v in srv.prefetcher.stats.summary().items():
                print(f"  {k:22s} {v:.4f}")
        srv.close()


if __name__ == "__main__":
    main()
