"""Serving launcher: SiDA engine vs baselines on a (reduced) MoE arch.

    PYTHONPATH=src python -m repro.launch.serve --arch switch-base-8 \
        --engine sida --slots 2 --batches 8 --batch 4 --seq 32

Trains nothing: random weights + untrained hash function (use
examples/serve_sida.py for the full train->distill->serve pipeline).
Prints throughput / latency / device-memory for the chosen engine.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.baselines import OnDemandServer, PrefetchAllServer, StandardServer
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import init_hash_fn
from repro.models.transformer import init_params, n_moe_layers


def build_engine(engine: str, cfg, params, slots: int):
    if engine == "standard":
        return StandardServer(cfg, params)
    if engine == "ondemand":
        return OnDemandServer(cfg, params, slots_per_layer=slots)
    if engine == "prefetchall":
        return PrefetchAllServer(cfg, params, slots_per_layer=slots)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=64,
    )
    return SiDAEngine(cfg, params, hp, slots_per_layer=slots)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="switch-base-8")
    ap.add_argument("--engine", default="sida",
                    choices=["sida", "standard", "ondemand", "prefetchall"])
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--full", action="store_true", help="full-size config")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    assert cfg.moe.enabled, "serving engines target MoE architectures"
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batches = [
        rng.integers(0, cfg.vocab_size, (args.batch, args.seq)).astype(np.int32)
        for _ in range(args.batches)
    ]
    srv = build_engine(args.engine, cfg, params, args.slots)
    metrics = srv.serve(batches)
    print(f"engine={args.engine} slots={args.slots}")
    for k, v in metrics.summary().items():
        print(f"  {k:20s} {v:.4f}")
    print(f"  device_mem_mb        {srv.device_memory_bytes()/1e6:.2f}")
    if isinstance(srv, SiDAEngine):
        for k, v in srv.memory_saving().items():
            print(f"  {k:20s} {v:.4f}")
        st = srv.store.stats
        print(f"  loads={st.loads} hits={st.hits} evictions={st.evictions} "
              f"h2d_mb={st.bytes_h2d/1e6:.2f}")


if __name__ == "__main__":
    main()
