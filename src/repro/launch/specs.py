"""input_specs(): ShapeDtypeStruct stand-ins for every model input, per
(arch × input shape) — weak-type-correct, shardable, no device allocation.

Shapes (assigned):
  train_4k     seq 4096,   batch 256  -> train_step(params, opt, tokens, labels)
  prefill_32k  seq 32768,  batch 32   -> prefill_step(params, tokens)
  decode_32k   seq 32768,  batch 128  -> serve_step(params, cache, tokens)
  long_500k    seq 524288, batch 1    -> serve_step (sub-quadratic archs only)

[audio]/[vlm] carve-out: the modality frontend is a stub — `enc_input` is a
precomputed frame-embedding tensor of the right shape (audio), and VLM image
tokens are ordinary vocabulary ids (early fusion).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.transformer import init_cache

AUDIO_ENC_FRAMES = 1536  # ~30 s of 20 ms frames (stub conv frontend output)


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Model inputs for the given input shape (excluding params/opt/cache)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
        }
        if cfg.enc_dec:
            out["enc_input"] = sds((B, AUDIO_ENC_FRAMES, cfg.d_model), cfg.dtype)
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.enc_dec:
            out["enc_input"] = sds((B, AUDIO_ENC_FRAMES, cfg.d_model), cfg.dtype)
        return out
    # decode: one new token vs a seq_len cache
    cache = jax.eval_shape(
        partial(init_cache, cfg, B, S, AUDIO_ENC_FRAMES if cfg.enc_dec else 0)
    )
    return {"tokens": sds((B,), jnp.int32), "cache": cache}
