"""Jittable step functions: train_step / prefill_step / serve_step.

These are what the launcher jits, what the dry-run lowers, and what the
roofline reads — one definition for every architecture.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import ShardingCtx
from repro.models.transformer import decode_step, forward, lm_loss
from repro.optim.adamw import adamw_update


def make_train_step(cfg: ModelConfig, ctx: ShardingCtx, lr: float = 1e-4,
                    grad_clip: float = 1.0, param_pspecs=None):
    def train_step(params, opt_state, tokens, labels, enc_input=None,
                   lr_runtime=None):
        """`lr_runtime` (traced scalar) overrides the baked-in lr so LR
        schedules don't retrace the step."""
        def loss_fn(p):
            out = forward(
                p, cfg, ctx, tokens, enc_input=enc_input,
                scan_mode="assoc", remat=True,
            )
            loss = lm_loss(out["logits"], labels)
            total = (
                loss
                + cfg.moe.router_aux_coef * out["aux_loss"]
                + cfg.moe.router_z_coef * out["z_loss"]
            )
            return total, {"lm_loss": loss, "aux_loss": out["aux_loss"]}

        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if param_pspecs is not None and ctx.mesh is not None:
            # §Perf iteration 4: pin gradients to the parameter sharding so
            # the data-axis gradient sync lowers as reduce-scatter rather
            # than all-reduce (remat boundaries block GSPMD's own inference)
            from jax.sharding import NamedSharding

            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, NamedSharding(ctx.mesh, s)
                ),
                grads, param_pspecs,
            )
        params, opt_state = adamw_update(
            grads, params, opt_state,
            lr=lr if lr_runtime is None else lr_runtime,
            weight_decay=0.01, grad_clip=grad_clip,
        )
        metrics["total_loss"] = total
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: ShardingCtx):
    def prefill_step(params, tokens, enc_input=None):
        out = forward(params, cfg, ctx, tokens, enc_input=enc_input, scan_mode="assoc")
        return out["logits"][:, -1]

    return prefill_step


def make_serve_step(cfg: ModelConfig, ctx: ShardingCtx):
    def serve_step(params, cache, tokens):
        logits, cache = decode_step(params, cache, tokens, cfg, ctx)
        return logits, cache

    return serve_step
