"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 200 --batch 8 --seq 64 --ckpt /tmp/ck

On real hardware drop --reduced and point --mesh at the production topology;
on this CPU container --reduced trains a laptop-scale variant end-to-end
(the quickstart example drives a ~100M-param run).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.configs.base import get_config
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.transformer import init_params, param_count
from repro.optim.adamw import adamw_init
from repro.optim.schedule import linear_warmup_cosine
from repro.sharding import policy


def train(
    arch: str,
    steps: int = 100,
    batch: int = 8,
    seq: int = 64,
    lr: float = 3e-4,
    reduced: bool = True,
    ckpt: str = "",
    log_every: int = 10,
    seed: int = 0,
    collect_router_stats: bool = False,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    ctx = policy.make_ctx(mesh) if mesh is not None else policy.make_ctx(None)
    print(f"arch={cfg.name} reduced={reduced} devices={len(jax.devices())}")
    params = init_params(jax.random.PRNGKey(seed), cfg)
    print(f"params: {param_count(params):,}")
    opt = adamw_init(params)
    data = SyntheticLM(
        SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=seq, n_domains=8),
        seed=seed,
    )
    sched = linear_warmup_cosine(lr, warmup=min(50, steps // 10 + 1), total=steps)
    step = jax.jit(make_train_step(cfg, ctx))  # lr passed at runtime (no retrace)

    history = []
    t0 = time.perf_counter()
    for i, (toks, labels) in enumerate(data.batches(batch, steps)):
        cur_lr = jnp.float32(sched(i))
        enc = None
        if cfg.enc_dec:
            enc = jnp.asarray(
                np.random.default_rng(i).normal(size=(batch, 16, cfg.d_model)),
                jnp.dtype(cfg.dtype),
            )
        params, opt, m = step(
            params, opt, jnp.asarray(toks), jnp.asarray(labels), enc,
            lr_runtime=cur_lr,
        )
        if i % log_every == 0 or i == steps - 1:
            loss = float(m["lm_loss"])
            history.append({"step": i, "loss": loss, "lr": float(cur_lr)})
            rate = (i + 1) * batch * seq / (time.perf_counter() - t0)
            print(
                f"step {i:5d}  loss {loss:.4f}  lr {float(cur_lr):.2e}  "
                f"tok/s {rate:,.0f}"
            )
    if ckpt:
        save_checkpoint(ckpt, params, step=steps, extra={"arch": cfg.name})
        print(f"saved checkpoint to {ckpt}")
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, reduced=args.reduced, ckpt=args.ckpt,
    )


if __name__ == "__main__":
    main()
