"""GQA attention: full (train/prefill, flash-style chunked) and decode paths.

Decode supports a mesh-sharded KV cache (seq dim sharded over the `model`
axis) via a shard_map partial-softmax merge (flash-decode) — see
DESIGN.md §5. All paths are pure jnp so they lower on any backend; the
Pallas TPU kernels in repro/kernels mirror `decode_attention_local`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, init_rmsnorm, rmsnorm, softcap

Array = jax.Array

NEG_INF = -1e30
Q_CHUNK = 1024  # flash-style query chunking for long prefill


# ---------------------------------------------------------------------------
# Sharding context threaded through the model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardingCtx:
    """Names of mesh axes used by the model; all-None => single device."""

    mesh: Optional[jax.sharding.Mesh] = None
    batch_axes: Optional[Tuple[str, ...]] = None   # e.g. ("pod", "data")
    model_axis: Optional[str] = None               # e.g. "model"
    # axes the decode KV-cache seq dim is sharded over (flash-decode merge)
    decode_seq_axis: Optional[Tuple[str, ...]] = None
    # expert-parallel serving: the mesh axis the MoE slot pools (and the
    # expert FFN inside shard_map) shard over, WITHOUT also sharding
    # attention heads / the residual stream the way `model_axis` does.
    # Keeping every non-expert tensor replicated is what lets sharded
    # serving stay byte-identical to the single-device path.
    expert_axis: Optional[str] = None

    def constrain(self, x: Array, spec: P) -> Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )

    def batch_spec(self, batch: int) -> Optional[Tuple[str, ...]]:
        """batch axes if the batch is divisible by the mesh extent."""
        if self.mesh is None or not self.batch_axes:
            return None
        ext = 1
        for a in self.batch_axes:
            ext *= self.mesh.shape[a]
        return self.batch_axes if batch % ext == 0 else None

    def act_constrain(self, x: Array) -> Array:
        """Residual-stream constraint: batch -> data axes, d_model -> model.

        Sharding the carried activation over the model axis keeps the
        per-device live set of the layer scan (and its remat checkpoints)
        small enough for 10B+ configs at 4k sequence length. For small-d
        architectures the trade inverts (§Perf iteration on seamless/xlstm):
        a 48-64-element d-shard makes every matmul re-gather the stream, so
        the model axis is only used when each shard keeps >=256 features.
        """
        if self.mesh is None:
            return x
        d_ax = None
        if self.model_axis:
            ext = self.mesh.shape[self.model_axis]
            if x.shape[-1] % ext == 0 and x.shape[-1] // ext >= 256:
                d_ax = self.model_axis
        mid = [None] * (x.ndim - 2)
        return self.constrain(x, P(self.batch_spec(x.shape[0]), *mid, d_ax))


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nq * hd, d, dtype),
    }
    if cfg.attn.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.attn.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _project_q(p: dict, x: Array, cfg: ModelConfig) -> Array:
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(*x.shape[:-1], cfg.n_heads, cfg.hd)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    return q


def _project_kv(p: dict, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.hd)
    v = v.reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.hd)
    if "k_norm" in p:
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# full attention (train / prefill) — chunked over queries
# ---------------------------------------------------------------------------


def _attend_chunk(
    q: Array,           # [B, Tq, H, D] (rope applied)
    k: Array,           # [B, S, K, D]
    v: Array,           # [B, S, K, D]
    q_pos: Array,       # [Tq]
    k_pos: Array,       # [S]
    window: int,
    cap: float,
    causal: bool,
    k_valid: Optional[Array] = None,  # [S] extra key validity (paged gather)
) -> Array:
    B, Tq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Tq, K, G, D)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(D)
    if cap:
        logits = softcap(logits, cap)
    mask = jnp.ones((Tq, k_pos.shape[0]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    if k_valid is not None:
        mask &= k_valid[None, :]
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return out.reshape(B, Tq, H, D)


def attend_full(
    params: dict,
    x: Array,                 # [B, S, d]
    cfg: ModelConfig,
    layer: int,
    ctx: ShardingCtx,
    positions: Optional[Array] = None,
    causal: bool = True,
    kv_from: Optional[Array] = None,   # cross-attention source (enc output)
    return_kv: bool = False,
):
    B, S, _ = x.shape
    window = cfg.layer_window(layer) if causal else 0
    q = _project_q(params, x, cfg)
    src = kv_from if kv_from is not None else x
    k, v = _project_kv(params, src, cfg)
    Skv = src.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    kv_pos = jnp.arange(Skv)
    if kv_from is None:  # self-attention => rope
        q = apply_rope(q, positions, cfg.attn.rope_theta)
        k = apply_rope(k, kv_pos, cfg.attn.rope_theta)
    if ctx.model_axis:
        # heads sharded over model axis when divisible
        spec_q = P(ctx.batch_spec(B), None, ctx.model_axis if cfg.n_heads % ctx.mesh.shape[ctx.model_axis] == 0 else None, None)
        q = ctx.constrain(q, spec_q)

    cap = cfg.attn.logit_softcap
    if S <= Q_CHUNK:
        out = _attend_chunk(q, k, v, positions, kv_pos, window, cap, causal)
    else:
        nchunk = math.ceil(S / Q_CHUNK)
        pad = nchunk * Q_CHUNK - S
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qc = qp.reshape(B, nchunk, Q_CHUNK, *q.shape[2:]).swapaxes(0, 1)

        # positions derive from the loop counter (a chunk-indexed position
        # table would be hoisted out of the while loop as a giant stacked
        # constant together with the masks), and the body is checkpointed so
        # the backward pass recomputes each chunk's softmax instead of
        # stacking [nchunk, ..., S] f32 residuals.
        #
        # windowed layers slice K/V to the window-reachable band per chunk
        # (§Perf: sliding-window banding) — logits go from [chunk, S] to
        # [chunk, window+chunk], a 10x+ cut for local layers at 32k.
        span = window + Q_CHUNK
        banded = bool(window) and causal and S > span

        @jax.checkpoint
        def body(i, qi):
            pi = i * Q_CHUNK + jnp.arange(Q_CHUNK)
            if banded:
                start = jnp.clip(i * Q_CHUNK + Q_CHUNK - span, 0, S - span)
                kw = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
                vw = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
                kp = start + jnp.arange(span)
                return i + 1, _attend_chunk(qi, kw, vw, pi, kp, window, cap, causal)
            return i + 1, _attend_chunk(qi, k, v, pi, kv_pos, window, cap, causal)

        _, oc = jax.lax.scan(body, jnp.zeros((), jnp.int32), qc)
        out = oc.swapaxes(0, 1).reshape(B, nchunk * Q_CHUNK, cfg.n_heads, cfg.hd)[:, :S]
    y = out.reshape(B, S, cfg.n_heads * cfg.hd) @ params["wo"]
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# decode attention — one token vs a (possibly seq-sharded) KV cache
# ---------------------------------------------------------------------------


def decode_attention_local(
    q: Array,        # [B, H, D] (rope applied)
    k: Array,        # [B, S, K, D]
    v: Array,        # [B, S, K, D]
    slot_pos: Array, # [B, S] global position stored in each cache slot (-1 invalid)
    pos: Array,      # [B] current decode position
    window: int,
    cap: float,
) -> Tuple[Array, Array, Array]:
    """Returns partial (out*l, l, m) for safe-softmax merging across shards."""
    B, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, D)
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(D)
    if cap:
        logits = softcap(logits, cap)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window:
        valid &= slot_pos > (pos[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                        # [B,K,G]
    e = jnp.exp(logits - m[..., None])
    l = jnp.sum(e, axis=-1)                             # [B,K,G]
    o = jnp.einsum("bkgs,bskd->bkgd", e, v.astype(jnp.float32))
    return o.reshape(B, H, D), l.reshape(B, H), m.reshape(B, H)


def _merge_partials(o, l, m, axes: Tuple[str, ...]):
    """Merge flash-decode partials across mesh axes inside shard_map."""
    m_g = jax.lax.pmax(m, axes)
    scale = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * scale, axes)
    o_g = jax.lax.psum(o * scale[..., None], axes)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


def decode_attention(
    q: Array,
    k: Array,
    v: Array,
    slot_pos: Array,
    pos: Array,
    window: int,
    cap: float,
    ctx: ShardingCtx,
) -> Array:
    """[B,H,D] attention of one token over the cache; shard-aware."""
    seq_div = True
    if ctx.mesh is not None and ctx.decode_seq_axis is not None:
        ext = 1
        for a in ctx.decode_seq_axis:
            ext *= ctx.mesh.shape[a]
        seq_div = k.shape[1] % ext == 0
    if ctx.mesh is None or ctx.decode_seq_axis is None or not seq_div:
        o, l, m = decode_attention_local(q, k, v, slot_pos, pos, window, cap)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    ax = tuple(ctx.decode_seq_axis)
    b_ax = ctx.batch_spec(q.shape[0])

    def inner(q, k, v, slot_pos, pos):
        o, l, m = decode_attention_local(q, k, v, slot_pos, pos, window, cap)
        return _merge_partials(o, l, m, ax).astype(q.dtype)

    return shard_map(
        inner,
        mesh=ctx.mesh,
        in_specs=(
            P(b_ax, None, None),
            P(b_ax, ax, None, None),
            P(b_ax, ax, None, None),
            P(b_ax, ax),
            P(b_ax),
        ),
        out_specs=P(b_ax, None, None),
    )(q, k, v, slot_pos, pos)


def attend_decode(
    params: dict,
    x_tok: Array,            # [B, d] current-token activations
    cache_k: Array,          # [B, Sc, K, D]
    cache_v: Array,
    pos: Array,              # [B] decode position
    cfg: ModelConfig,
    layer: int,
    ctx: ShardingCtx,
    cross: bool = False,
    cross_len: Optional[Array] = None,
):
    """One decode step. Returns (y [B,d], new_k, new_v).

    Self-attention writes the new token's K/V into slot ``pos % Sc`` (ring
    buffer — Sc equals the full seq budget for dense archs, or the sliding
    window for windowed archs). Cross-attention reads a fixed cache.
    """
    B = x_tok.shape[0]
    Sc = cache_k.shape[1]
    q = _project_q(params, x_tok[:, None, :], cfg)[:, 0]  # [B, H, D]
    if cross:
        # cross-attn: cache holds encoder K/V; all slots < cross_len valid
        slot_pos = jnp.where(
            jnp.arange(Sc)[None, :] < cross_len[:, None], 0, -1
        )
        o = decode_attention(
            q, cache_k, cache_v, slot_pos,
            jnp.zeros((B,), jnp.int32), 0, cfg.attn.logit_softcap, ctx,
        )
        y = o.reshape(B, cfg.n_heads * cfg.hd) @ params["wo"]
        return y, cache_k, cache_v

    window = cfg.layer_window(layer)
    q = apply_rope(q[:, None], pos[:, None], cfg.attn.rope_theta)[:, 0]
    k_new, v_new = _project_kv(params, x_tok[:, None, :], cfg)
    k_new = apply_rope(k_new, pos[:, None], cfg.attn.rope_theta)
    slot = pos % Sc
    bidx = jnp.arange(B)
    new_k = cache_k.at[bidx, slot].set(k_new[:, 0].astype(cache_k.dtype))
    new_v = cache_v.at[bidx, slot].set(v_new[:, 0].astype(cache_v.dtype))
    # global position held by each slot s: largest p <= pos with p % Sc == s
    s_idx = jnp.arange(Sc)[None, :]
    slot_pos = pos[:, None] - ((pos[:, None] - s_idx) % Sc)
    slot_pos = jnp.where(slot_pos >= 0, slot_pos, -1)
    o = decode_attention(
        q, new_k, new_v, slot_pos, pos, window, cfg.attn.logit_softcap, ctx
    )
    y = o.reshape(B, cfg.n_heads * cfg.hd).astype(x_tok.dtype) @ params["wo"]
    return y, new_k, new_v


# ---------------------------------------------------------------------------
# paged K/V — decode and chunked prefill through a page table
# ---------------------------------------------------------------------------
# The pool layout and its invariants live in core/residency.py: a shared
# [P+1, page, K, D] pool per sublayer (last page = trash), one [B, Mp]
# page table for all layers, pages allocated in position order so slot
# i*page+j always holds global position i*page+j. Entry -1 = unallocated
# or spilled to host — never read (validity masks it exactly to NEG_INF).


def _paged_gather(
    kp: Array,          # [P+1, page, K, D] shared pool (trash page last)
    vp: Array,
    page_table: Array,  # [B, Mp] (-1 invalid)
    last_pos: Array,    # [B] highest position a query can reach
    span: int,          # 0 = gather every page; else positions reachable back
) -> Tuple[Array, Array, Array]:
    """Gather K/V through the page table -> (k [B,S,K,D], v, slot_pos).

    Windowed layers gather only the pages the attention span can reach
    (bounded S keeps the per-step gather proportional to the window, not
    to the 32k+ addressable range); full-attention layers gather the
    first n_pages table entries — allocation is position-ordered and the
    full-attention working set is asserted to fit the resident pool
    (KVPagePool.ensure), so entries past n_pages are always -1 and the
    gather stays proportional to the pool, not the addressable range."""
    B, Mp = page_table.shape
    page = kp.shape[1]
    trash = kp.shape[0] - 1
    if span:
        Wp = min(Mp, span // page + 2)
        base = jnp.clip(last_pos // page - (Wp - 1), 0, Mp - Wp)
        idx = base[:, None] + jnp.arange(Wp)[None, :]             # [B, Wp]
        pt = jnp.take_along_axis(page_table, idx, axis=1)
    else:
        Wp = min(Mp, trash)  # trash == n_pages (pool width P+1, trash last)
        idx = jnp.broadcast_to(jnp.arange(Wp)[None, :], (B, Wp))
        pt = page_table[:, :Wp]
    kg = kp[jnp.where(pt >= 0, pt, trash)]                        # [B,Np,page,K,D]
    vg = vp[jnp.where(pt >= 0, pt, trash)]
    spos = idx[:, :, None] * page + jnp.arange(page)[None, None, :]
    slot_pos = jnp.where((pt >= 0)[:, :, None], spos, -1).reshape(B, -1)
    S = slot_pos.shape[1]
    return (
        kg.reshape(B, S, kp.shape[2], kp.shape[3]),
        vg.reshape(B, S, vp.shape[2], vp.shape[3]),
        slot_pos,
    )


def attend_decode_paged(
    params: dict,
    x_tok: Array,        # [B, d] current-token activations
    kp: Array,           # [P+1, page, K, D] shared page pool
    vp: Array,
    page_table: Array,   # [B, Mp]
    pos: Array,          # [B] decode position
    cfg: ModelConfig,
    layer: int,
    ctx: ShardingCtx,
    active: Optional[Array] = None,  # [B] bool — inactive lanes write trash
):
    """One paged decode step. Returns (y [B,d], new_kp, new_vp).

    The new token's K/V lands in the page the table maps ``pos`` to; lanes
    that are masked out (or whose page is unallocated) are routed to the
    trash page — the pool is shared across lanes, so a stale lane cannot
    be merged back per-batch-row the way the ring cache is. Reads gather
    through the table, where a slot's global position is static in its
    table index, keeping validity purely causal."""
    B = x_tok.shape[0]
    page = kp.shape[1]
    trash = kp.shape[0] - 1
    window = cfg.layer_window(layer)
    q = _project_q(params, x_tok[:, None, :], cfg)[:, 0]
    q = apply_rope(q[:, None], pos[:, None], cfg.attn.rope_theta)[:, 0]
    k_new, v_new = _project_kv(params, x_tok[:, None, :], cfg)
    k_new = apply_rope(k_new, pos[:, None], cfg.attn.rope_theta)
    # positions past the addressable range (speculative overdraft at the
    # edge) must land in the trash page — an unclamped OOB table index
    # would silently alias the last real page under jit's clamping
    Mp = page_table.shape[1]
    pidx = pos // page
    pid = jnp.take_along_axis(
        page_table, jnp.clip(pidx, 0, Mp - 1)[:, None], axis=1
    )[:, 0]
    pid = jnp.where((pidx < Mp) & (pid >= 0), pid, trash)
    if active is not None:
        pid = jnp.where(active, pid, trash)
    off = pos % page
    new_kp = kp.at[pid, off].set(k_new[:, 0].astype(kp.dtype))
    new_vp = vp.at[pid, off].set(v_new[:, 0].astype(vp.dtype))
    kg, vg, slot_pos = _paged_gather(new_kp, new_vp, page_table, pos, window)
    o = decode_attention(
        q, kg, vg, slot_pos, pos, window, cfg.attn.logit_softcap, ctx
    )
    y = o.reshape(B, cfg.n_heads * cfg.hd).astype(x_tok.dtype) @ params["wo"]
    return y, new_kp, new_vp


def attend_prefill_chunk(
    params: dict,
    x: Array,            # [1, T, d] one lane's prompt chunk
    kp: Array,           # [P+1, page, K, D]
    vp: Array,
    page_table: Array,   # [1, Mp] the lane's table row
    pos0: Array,         # [1] chunk start position
    cfg: ModelConfig,
    layer: int,
    ctx: ShardingCtx,
):
    """Chunked-prefill attention for one paged lane (B == 1): write the
    chunk's K/V through the page table at absolute positions, then attend
    causally over the gathered paged cache. Returns (y, new_kp, new_vp).

    Each chunk position maps to a distinct (page, offset) — allocation is
    position-ordered — so the scatter has no collisions. Spilled
    out-of-window pages show up as -1 table entries and are masked via
    `k_valid` (windowed archs never need them resident)."""
    B, T, _ = x.shape
    page = kp.shape[1]
    trash = kp.shape[0] - 1
    window = cfg.layer_window(layer)
    q = _project_q(params, x, cfg)
    q_pos = pos0[:, None] + jnp.arange(T)[None, :]   # [1, T]
    q = apply_rope(q, q_pos, cfg.attn.rope_theta)
    k_new, v_new = _project_kv(params, x, cfg)
    k_new = apply_rope(k_new, q_pos, cfg.attn.rope_theta)
    p = q_pos[0]                                     # [T]
    # the last chunk's pad tail can reach past the addressable range when
    # it is not a chunk multiple — route those writes to the trash page
    # rather than letting jit's index clamping alias the last real page
    Mp = page_table.shape[1]
    pidx = p // page
    pid = page_table[0][jnp.clip(pidx, 0, Mp - 1)]
    pid = jnp.where((pidx < Mp) & (pid >= 0), pid, trash)
    off = p % page
    new_kp = kp.at[pid, off].set(k_new[0].astype(kp.dtype))
    new_vp = vp.at[pid, off].set(v_new[0].astype(vp.dtype))
    span = window + T if window else 0
    kg, vg, slot_pos = _paged_gather(
        new_kp, new_vp, page_table, pos0 + T - 1, span
    )
    out = _attend_chunk(
        q, kg, vg, p, slot_pos[0], window, cfg.attn.logit_softcap,
        causal=True, k_valid=slot_pos[0] >= 0,
    )
    y = out.reshape(B, T, cfg.n_heads * cfg.hd).astype(x.dtype) @ params["wo"]
    return y, new_kp, new_vp
