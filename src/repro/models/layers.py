"""Basic layers: norms, FFN variants, embeddings, RoPE, initialisers.

Pure-JAX (pytree params as nested dicts). Every `init_*` returns a params
pytree; every `apply_*` is a pure function `f(params, x, ...)`.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype=dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Activation / FFN
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_ffn(key, d: int, d_ff: int, glu: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], d, d_ff, dtype),
        "w_out": dense_init(ks[1], d_ff, d, dtype),
    }
    if glu:
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def ffn(params: dict, x: Array, act: str, glu: bool) -> Array:
    h = x @ params["w_in"]
    if glu:
        h = act_fn(act)(x @ params["w_gate"]) * h
    else:
        h = act_fn(act)(h)
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# softcap
# ---------------------------------------------------------------------------


def softcap(x: Array, cap: float) -> Array:
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
