"""Mixture-of-Experts layer.

Supports:
  * top-k routing with capacity-based one-hot dispatch/combine einsums
    (GSPMD-friendly: the expert dim shards over the `model` mesh axis =>
    expert parallelism),
  * DeepSeek-style shared (always-active) experts,
  * `routing_override` — externally supplied (expert_ids, weights) per token,
    which is exactly the hook SiDA-MoE's hash table uses to replace the
    router at serving time (the router matmul is skipped entirely),
  * returning router logits (teacher signal for hash-function training).

Two dispatch strategies (see EXPERIMENTS.md §Perf):
  * "einsum"  — classic [T, E, C] one-hot dispatch (baseline; robust under
    GSPMD but its dispatch einsum costs T·E·C·d MACs),
  * "gather"  — capacity-gather compact dispatch: tokens are gathered into
    the per-expert [E, C] buffer with `take` instead of a one-hot matmul,
    cutting HLO FLOPs by orders of magnitude for large E·C.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.attention import ShardingCtx
from repro.models.layers import act_fn, dense_init

Array = jax.Array


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], d, m.num_experts, jnp.float32, scale=0.02),
        "w_in": _stack_init(ks[1], m.num_experts, d, m.d_expert, dtype),
        "w_gate": _stack_init(ks[2], m.num_experts, d, m.d_expert, dtype),
        "w_out": _stack_init(ks[3], m.num_experts, m.d_expert, d, dtype),
    }
    if m.num_shared_experts:
        ds = m.d_shared * m.num_shared_experts
        p["shared_w_in"] = dense_init(ks[4], d, ds, dtype)
        p["shared_w_gate"] = dense_init(ks[5], d, ds, dtype)
        p["shared_w_out"] = dense_init(ks[6], ds, d, dtype)
    return p


def _stack_init(key, e, d_in, d_out, dtype):
    import math

    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def router_topk(
    logits: Array, k: int
) -> Tuple[Array, Array]:
    """[T, E] -> (ids [T, k], weights [T, k]); weights renormalised softmax."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(gates, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return ids, w


def load_balance_loss(logits: Array, ids: Array, num_experts: int) -> Array:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(gates, axis=0)                               # [E]
    one_hot = jax.nn.one_hot(ids[..., 0], num_experts)         # top-1 counts
    ce = jnp.mean(one_hot, axis=0)
    return num_experts * jnp.sum(me * ce)


def router_z_loss(logits: Array) -> Array:
    return jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)))


# ---------------------------------------------------------------------------
# expert compute over the capacity buffer
# ---------------------------------------------------------------------------


def apply_expert_stack(p: dict, xe: Array, cfg: ModelConfig) -> Array:
    """xe: [E, C, d] -> [E, C, d] through each expert's (G)LU FFN."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    if cfg.glu:
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
        h = act_fn(cfg.act)(g) * h
    else:
        h = act_fn(cfg.act)(h)
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def _capacity(cfg: ModelConfig, n_tokens: int, num_experts: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.top_k / num_experts)
    return max(8, min(n_tokens, c))


def _block_tokens(T: int, target: int = 4096) -> int:
    """Largest divisor of T that is <= target (token blocking for dispatch).

    Capacity is enforced per block (Switch-style per-group capacity): the
    dispatch working set scales with blk·E·C instead of T·E·C, and blocks
    shard over the data axis.
    """
    if T <= target:
        return T
    for blk in range(target, 0, -1):
        if T % blk == 0:
            return blk
    return T


# ---------------------------------------------------------------------------
# MoE layer forward
# ---------------------------------------------------------------------------


def moe_layer(
    params: dict,
    x: Array,                       # [B, S, d]
    cfg: ModelConfig,
    ctx: ShardingCtx,
    routing_override: Optional[Tuple[Array, Array]] = None,  # ids [B,S,k], w [B,S,k]
    dispatch: str = "auto",
):
    """Returns (y [B,S,d], aux) with aux = dict(router_logits, aux_loss, z_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    if routing_override is not None:
        ids, w = routing_override
        ids = ids.reshape(T, -1)[:, : m.top_k]
        w = w.reshape(T, -1)[:, : m.top_k].astype(jnp.float32)
        router_logits = None
        aux_loss = jnp.zeros((), jnp.float32)
        z_loss = jnp.zeros((), jnp.float32)
    else:
        router_logits = xt.astype(jnp.float32) @ params["router"]  # [T, E]
        ids, w = router_topk(router_logits, m.top_k)
        aux_loss = load_balance_loss(router_logits, ids, m.num_experts)
        z_loss = router_z_loss(router_logits)

    y = _dispatch_combine(
        params, xt, ids, w, cfg, ctx, dispatch,
        served=routing_override is not None,
    )

    if m.num_shared_experts:
        h = xt @ params["shared_w_in"]
        g = act_fn(cfg.act)(xt @ params["shared_w_gate"])
        y = y + (g * h) @ params["shared_w_out"]

    aux = {
        "router_logits": (
            router_logits.reshape(B, S, m.num_experts)
            if router_logits is not None
            else None
        ),
        "aux_loss": aux_loss,
        "z_loss": z_loss,
    }
    return y.reshape(B, S, d).astype(x.dtype), aux


def _dispatch_combine(params, xt, ids, w, cfg, ctx, dispatch, served=False):
    """Token-blocked dispatch -> expert compute -> combine.

    dispatch="einsum": classic one-hot dispatch/combine matmuls. Exact
      (bit-identical to the reference) but costs blk·E·C·d MACs — used for
      the paper-scale Switch models and as the test oracle.
    dispatch="gather": index-based. The [n, E, C] token-index table is built
      by scatter, experts gather their tokens (zero FLOPs), and the combine
      scatter-adds per-expert outputs back (partial-sum + all-reduce under
      expert parallelism). This is the path the 235B dry-runs use.
    dispatch="auto": einsum for small working sets, gather otherwise.

    `served=True` marks a slot-translated serving forward (routing override
    present): under a mesh with an expert-parallel axis it ALWAYS takes the
    shard_map EP path — the slot pools are partitioned over that axis, so
    the single-shard paths would gather the whole pool per device.
    """
    m = cfg.moe
    T, d = xt.shape
    # E comes from the weight stack, not the config: SiDA serving passes slot
    # buffers with S_slots << num_experts and slot-translated ids. A tiered
    # store publishes TWO stacks — int8 hot slots [S8] plus nibble-packed
    # int4 warm slots [S4] — addressed as one combined slot space [S8+S4)
    # (hot first), so the dispatch below needs no tier awareness beyond the
    # stack split inside apply_expert_stack_blocked.
    E, K = params["w_in"].shape[0], ids.shape[-1]
    if "w_in_q4" in params:
        E += params["w_in_q4"].shape[0]
    blk = _block_tokens(T)
    n = T // blk
    C = _capacity(cfg, blk, E)
    if dispatch == "auto":
        dispatch = "einsum" if blk * E * C <= (1 << 24) else "gather"

    # §Perf hillclimb #1 (H1c, confirmed): under a mesh, run the whole
    # dispatch->expert-FFN->combine as true expert parallelism inside
    # shard_map. GSPMD cannot partition the fancy-index scatter/gather
    # (the block coordinate travels as index *data*), so it replicates a
    # [n, blk, d] f32 combine per device and all-reduces ~17 GB per MoE
    # layer over the full mesh. Inside shard_map every index op is local
    # and the only collective is one psum_scatter over `model`.
    # int8-resident stacks take it too: apply_expert_stack_blocked runs the
    # (fused-dequant) expert FFN on each shard's local slots inside the
    # shard_map body, so quantized slot pools no longer force the
    # single-shard path.
    eax = ctx.expert_axis or ctx.model_axis
    ep_ok = (
        ctx.mesh is not None
        and eax is not None
        and ctx.mesh.shape[eax] > 1
        and E % ctx.mesh.shape[eax] == 0
    )
    if ep_ok and (dispatch == "gather" or served):
        return _dispatch_combine_ep(
            params, xt, ids, w, cfg, ctx, blk, n, C, maxis=eax, served=served
        )

    ids_b = ids.reshape(n, blk, K)
    w_b = w.reshape(n, blk, K)
    x_b = xt.reshape(n, blk, d)

    # position of each (token, k) assignment within its expert's per-block
    # capacity buffer (cumsum over the block)
    onehot_e = jax.nn.one_hot(ids_b, E, dtype=jnp.int32)            # [n,blk,K,E]
    flat_oh = onehot_e.reshape(n, blk * K, E)
    pos = (jnp.cumsum(flat_oh, axis=1) - 1).reshape(n, blk, K, E)
    pos = jnp.take_along_axis(pos, ids_b[..., None], axis=-1)[..., 0]  # [n,blk,K]
    keep = pos < C
    w_b = w_b * keep

    if dispatch == "gather":
        tok_idx = jnp.broadcast_to(jnp.arange(blk)[None, :, None], (n, blk, K))
        slot = jnp.where(keep, ids_b * C + pos, E * C)              # [n,blk,K]
        # token-index table: table[n, e, c] = which token sits in slot (e,c)
        table = (
            jnp.full((n, E * C + 1), blk, jnp.int32)
            .at[jnp.arange(n)[:, None, None], slot]
            .set(tok_idx, mode="drop")[:, : E * C]
            .reshape(n, E, C)
        )
        table = _constrain_necd(table, ctx, P_dims=3)
        x_pad = jnp.concatenate([x_b, jnp.zeros((n, 1, d), xt.dtype)], axis=1)
        xe = x_pad[jnp.arange(n)[:, None, None], table]             # [n,E,C,d]
        xe = _constrain_necd(xe, ctx)
        ye = apply_expert_stack_blocked(params, xe, cfg)
        ye = _constrain_necd(ye, ctx)
        # combine: scatter-add expert outputs back to their tokens
        gate = jnp.zeros((n, E * C + 1), jnp.float32).at[
            jnp.arange(n)[:, None, None], slot
        ].add(w_b.astype(jnp.float32), mode="drop")[:, : E * C].reshape(n, E, C)
        # §Perf hillclimb #1: the scatter-add *operand* must carry the block
        # sharding — an unsharded zeros buffer makes GSPMD replicate the
        # whole [n, blk, d] f32 combine per device and all-reduce 17 GB/op
        # over the full mesh. With n -> data, each expert shard scatter-adds
        # a partial y of [n/|data|, blk, d] and the all-reduce runs over
        # `model` only.
        y0 = jnp.zeros((n, blk + 1, d), jnp.float32)
        if ctx.mesh is not None:
            y0 = ctx.constrain(y0, P(ctx.batch_spec(n), None, None))
        y = (
            y0.at[jnp.arange(n)[:, None, None], table]
            .add(ye.astype(jnp.float32) * gate[..., None], mode="drop")[:, :blk]
        )
        if ctx.mesh is not None:
            d_ax = None
            if ctx.model_axis and d % ctx.mesh.shape[ctx.model_axis] == 0:
                d_ax = ctx.model_axis
            y = ctx.constrain(y, P(ctx.batch_spec(n), None, d_ax))
        return y.reshape(T, d)

    # einsum dispatch (exact oracle; fine for small blk·E·C)
    disp = (
        jax.nn.one_hot(ids_b, E, dtype=xt.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=xt.dtype)[..., None, :C]
    )                                                               # [n,blk,K,E,C]
    disp = disp.sum(2)                                              # [n,blk,E,C]
    xe = jnp.einsum("nbd,nbec->necd", x_b, disp)
    xe = _constrain_necd(xe, ctx)
    ye = apply_expert_stack_blocked(params, xe, cfg)
    ye = _constrain_necd(ye, ctx)
    comb = jnp.einsum("nbkec,nbk->nbec",
        jax.nn.one_hot(ids_b, E, dtype=xt.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=xt.dtype)[..., None, :C],
        w_b.astype(xt.dtype),
    )
    y = jnp.einsum("necd,nbec->nbd", ye, comb).astype(jnp.float32)
    return y.reshape(T, d)


def _dispatch_combine_ep(
    params, xt, ids, w, cfg, ctx, blk, n, C, maxis=None, served=False
):
    """Expert-parallel dispatch/combine under shard_map (see moe_layer).

    Per (data×model) shard: mask the token->expert assignments to the
    shard's local expert range — under slot-translated serving the global
    ids ARE slot-pool indices, so this masking is exactly the per-shard
    (id, slot) split of the routing override — build the local [E_loc, C]
    capacity table, gather tokens, run the expert FFN over the shard's
    local stack via `apply_expert_stack_blocked` (fp einsum, inline-dequant
    einsum, or the fp/fused-dequant Pallas kernels — all INSIDE the
    shard_map body), scatter-add the local partial y, and reduce over the
    expert axis.

    `served=True` (slot-translated serving) reduces with a plain psum into
    a replicated y: every partial is exact (a token's contributions are the
    products its resident experts computed, zeros elsewhere), so the
    replicated sum is bitwise the single-device combine for top-k routing —
    the sharded server's greedy byte-equivalence rests on this. Training
    keeps the psum_scatter into the d-sharded residual layout.

    Hot-expert REPLICATION (ExpertStore.replica_cand) needs no code here:
    a replica is just another global slot id on a different shard holding
    bit-identical weights, the slot-range masking above routes each token
    to whichever shard owns its chosen copy, and each token still hits
    exactly one copy — so the psum keeps summing one real contribution
    plus zeros per token, and the `served` exactness argument is
    unchanged. The same holds for rebalanced placements: moves only change
    WHICH slot a translation names, never how this dispatch consumes it.
    """
    mesh = ctx.mesh
    maxis = maxis or ctx.expert_axis or ctx.model_axis
    mext = mesh.shape[maxis]
    S8 = params["w_in"].shape[0]
    tiered = "w_in_q4" in params
    S4 = params["w_in_q4"].shape[0] if tiered else 0
    E = S8 + S4
    S8_loc, S4_loc = S8 // mext, S4 // mext
    E_loc = E // mext
    T, d = xt.shape
    K = ids.shape[-1]
    b_ax = ctx.batch_spec(n)
    quantized = expert_params_quantized(params)
    use_pallas = _use_pallas_default()
    d_scatter = d % mext == 0 and not served  # psum_scatter needs d divisible

    wnames = ["w_in", "w_gate", "w_out"]
    if quantized:
        wnames += [t + "_scale" for t in ("w_in", "w_gate", "w_out")]
    if tiered:
        for t in ("w_in", "w_gate", "w_out"):
            wnames += [t + "_q4", t + "_q4_scale"]
    wvals = [params[t] for t in wnames]

    def inner(x_b, ids_b, w_b, *wts):
        p_loc = dict(zip(wnames, wts))      # this shard's slot-pool slice
        nl = x_b.shape[0]
        if tiered:
            # tiered slot space: each shard owns TWO contiguous global
            # ranges — hot [m*S8_loc, (m+1)*S8_loc) and warm
            # [S8 + m*S4_loc, S8 + (m+1)*S4_loc) — mapped onto the local
            # combined stack [0, S8_loc) ++ [S8_loc, S8_loc + S4_loc).
            # With S4 = 0 this never runs: the params tree has no q4 keys,
            # so the untiered single-range check below stays bit-identical.
            mi = jax.lax.axis_index(maxis)
            hot_l = ids_b - mi * S8_loc
            is_hot = (ids_b < S8) & (hot_l >= 0) & (hot_l < S8_loc)
            warm_l = ids_b - S8 - mi * S4_loc
            is_warm = (ids_b >= S8) & (warm_l >= 0) & (warm_l < S4_loc)
            local = is_hot | is_warm
            idsl = jnp.where(is_warm, S8_loc + warm_l, hot_l)
        else:
            e0 = jax.lax.axis_index(maxis) * E_loc
            idsl = ids_b - e0                               # [nl, blk, K]
            local = (idsl >= 0) & (idsl < E_loc)
        idsl_c = jnp.clip(idsl, 0, E_loc - 1)
        oh = jax.nn.one_hot(
            jnp.where(local, idsl_c, E_loc), E_loc + 1, dtype=jnp.int32
        )[..., :E_loc]                                      # [nl, blk, K, E_loc]
        pos = (jnp.cumsum(oh.reshape(nl, blk * K, E_loc), 1) - 1).reshape(
            nl, blk, K, E_loc
        )
        pos = jnp.take_along_axis(pos, idsl_c[..., None], -1)[..., 0]
        keep = local & (pos < C)
        wk = (w_b * keep).astype(jnp.float32)
        tok = jnp.broadcast_to(jnp.arange(blk)[None, :, None], (nl, blk, K))
        slot = jnp.where(keep, idsl_c * C + pos, E_loc * C)
        nidx = jnp.arange(nl)[:, None, None]
        table = (
            jnp.full((nl, E_loc * C + 1), blk, jnp.int32)
            .at[nidx, slot].set(tok, mode="drop")[:, : E_loc * C]
            .reshape(nl, E_loc, C)
        )
        xp = jnp.concatenate([x_b, jnp.zeros((nl, 1, d), x_b.dtype)], 1)
        xe = xp[jnp.arange(nl)[:, None, None], table]       # [nl, E_loc, C, d]
        ye = apply_expert_stack_blocked(p_loc, xe, cfg, use_pallas=use_pallas)
        gate = (
            jnp.zeros((nl, E_loc * C + 1), jnp.float32)
            .at[nidx, slot].add(wk, mode="drop")[:, : E_loc * C]
            .reshape(nl, E_loc, C)
        )
        # §Perf iteration 3b: combine in the model dtype. Each token receives
        # at most top_k (<=8) adds, so bf16 accumulation is safe, and it
        # halves both the local scatter temps and the psum_scatter bytes.
        y0 = (
            jnp.zeros((nl, blk + 1, d), x_b.dtype)
            .at[jnp.arange(nl)[:, None, None], table]
            .add(
                (ye.astype(jnp.float32) * gate[..., None]).astype(x_b.dtype),
                mode="drop",
            )[:, :blk]
        )
        if d_scatter:
            return jax.lax.psum_scatter(y0, maxis, scatter_dimension=2, tiled=True)
        return jax.lax.psum(y0, maxis)

    wspecs = tuple(P(maxis, *([None] * (v.ndim - 1))) for v in wvals)
    y = shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(b_ax, None, None), P(b_ax, None, None), P(b_ax, None, None),
        ) + wspecs,
        out_specs=P(b_ax, None, maxis if d_scatter else None),
        # pallas_call has no replication rule; the specs above are explicit
        check_rep=False,
    )(
        xt.reshape(n, blk, d), ids.reshape(n, blk, K), w.reshape(n, blk, K),
        *wvals,
    )
    return y.reshape(T, d)


def expert_params_quantized(p: dict) -> bool:
    """True when the expert stack is int8-resident (SiDA quantized slots):
    the ExpertStore publishes `w_*_scale` planes alongside the int8 pools."""
    return "w_in_scale" in p


def expert_params_tiered(p: dict) -> bool:
    """True when the stack also carries a warm int4 tier: the tiered
    ExpertStore publishes nibble-packed `w_*_q4` pools (+ per-group
    `w_*_q4_scale` planes) alongside the int8 hot pools."""
    return "w_in_q4" in p


def _use_pallas_default() -> bool:
    """Serving-path default for routing the expert FFN through the Pallas
    kernels: opt-in via REPRO_MOE_PALLAS=1 (the kernels need MXU-aligned
    capacity/d_expert tilings, so auto-enabling would turn a perf knob into
    a shape constraint). On CPU the kernels run in interpret mode — slow,
    but exactly the fused-dequant code path, which CI exercises."""
    import os

    return os.environ.get("REPRO_MOE_PALLAS", "").lower() in ("1", "true")


def apply_expert_stack_blocked(
    p: dict, xe: Array, cfg: ModelConfig, use_pallas: Optional[bool] = None
) -> Array:
    """xe: [n, E, C, d] -> [n, E, C, d].

    use_pallas routes through the TPU kernel (repro/kernels/expert_gemm.py,
    MXU-aligned VMEM tiling); requires C and d_expert multiples of the
    block sizes — the jnp path is the oracle and the CPU fallback.
    None defers to the REPRO_MOE_PALLAS env knob (serving deployments set
    it; tests and CPU runs default to the jnp oracle).

    When the expert stack is int8-resident (quantized slots), the Pallas
    path uses the fused-dequant kernel — weight tiles stream as int8 and
    widen in VMEM, so no materialized fp expert copy ever exists — and the
    jnp path dequantizes inline (transient fp, fused by XLA; the oracle).

    Under expert-parallel serving this runs INSIDE the `_dispatch_combine_ep`
    shard_map body over each shard's local slot stack (`p` is the shard's
    slice of the pool), so the fused-dequant kernel executes per device with
    no cross-shard weight movement.
    """
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if expert_params_tiered(p):
        # mixed-format resident set: rows [0, S8) are int8 hot slots, rows
        # [S8, S8+S4) are nibble-packed int4 warm slots — each block routes
        # through its format's (fused-dequant) kernel / oracle and the
        # outputs concatenate back into the combined slot order
        S8 = p["w_in"].shape[0]
        hot = {k: v for k, v in p.items() if "_q4" not in k}
        y8 = apply_expert_stack_blocked(hot, xe[:, :S8], cfg, use_pallas)
        y4 = _apply_expert_stack_q4(p, xe[:, S8:], cfg, use_pallas)
        return jnp.concatenate([y8, y4], axis=1)
    quantized = expert_params_quantized(p)
    if use_pallas:
        from repro.kernels import ops

        n, E, C, d = xe.shape
        x2 = xe.transpose(1, 0, 2, 3).reshape(E, n * C, d)
        if quantized:
            out = ops.expert_ffn_q(
                x2,
                p["w_in"], p["w_in_scale"],
                p["w_gate"] if cfg.glu else None,
                p["w_gate_scale"] if cfg.glu else None,
                p["w_out"], p["w_out_scale"],
                act=cfg.act,
            )
        else:
            out = ops.expert_ffn(
                x2, p["w_in"], p["w_gate"] if cfg.glu else None, p["w_out"],
                act=cfg.act,
            )
        return out.reshape(E, n, C, d).transpose(1, 0, 2, 3)
    if quantized:
        dq = lambda t: (
            p[t].astype(jnp.float32) * p[t + "_scale"].astype(jnp.float32)
        ).astype(xe.dtype)
        wi, wo = dq("w_in"), dq("w_out")
        wg = dq("w_gate") if cfg.glu else None
    else:
        wi, wo = p["w_in"], p["w_out"]
        wg = p["w_gate"] if cfg.glu else None
    h = jnp.einsum("necd,edf->necf", xe, wi)
    if cfg.glu:
        g = jnp.einsum("necd,edf->necf", xe, wg)
        h = act_fn(cfg.act)(g) * h
    else:
        h = act_fn(cfg.act)(h)
    return jnp.einsum("necf,efd->necd", h, wo)


def _apply_expert_stack_q4(
    p: dict, xe: Array, cfg: ModelConfig, use_pallas: bool
) -> Array:
    """xe: [n, S4, C, d] -> [n, S4, C, d] through the warm-tier int4 slots.

    Pallas path: `ops.expert_ffn_q4` (nibble unpack + per-group scales in
    the f32 epilogue, fused). jnp path: materialized per-group dequant then
    the standard einsum FFN — the oracle, and exactly
    `kernels/ref.expert_ffn_q4_ref` reassociated."""
    wi, wis = p["w_in_q4"], p["w_in_q4_scale"]
    wg, wgs = (
        (p["w_gate_q4"], p["w_gate_q4_scale"]) if cfg.glu else (None, None)
    )
    wo, wos = p["w_out_q4"], p["w_out_q4_scale"]
    if use_pallas:
        from repro.kernels import ops

        n, E, C, d = xe.shape
        x2 = xe.transpose(1, 0, 2, 3).reshape(E, n * C, d)
        out = ops.expert_ffn_q4(x2, wi, wis, wg, wgs, wo, wos, act=cfg.act)
        return out.reshape(E, n, C, d).transpose(1, 0, 2, 3)
    from repro.kernels.ref import dequantize_q4_ref

    d = xe.shape[-1]
    F = wi.shape[-1]
    wi_f = dequantize_q4_ref(wi, wis, d).astype(xe.dtype)
    wo_f = dequantize_q4_ref(wo, wos, F).astype(xe.dtype)
    h = jnp.einsum("necd,edf->necf", xe, wi_f)
    if cfg.glu:
        wg_f = dequantize_q4_ref(wg, wgs, d).astype(xe.dtype)
        g = jnp.einsum("necd,edf->necf", xe, wg_f)
        h = act_fn(cfg.act)(g) * h
    else:
        h = act_fn(cfg.act)(h)
    return jnp.einsum("necf,efd->necd", h, wo_f)


def _constrain_necd(x: Array, ctx: ShardingCtx, P_dims: int = 4) -> Array:
    """Constrain [n, E, ...]: blocks -> batch axes, experts -> model axis."""
    if ctx.mesh is None:
        return x
    n, E = x.shape[0], x.shape[1]
    b_ax = ctx.batch_spec(n)
    e_ax = None
    if ctx.model_axis and E % ctx.mesh.shape[ctx.model_axis] == 0:
        e_ax = ctx.model_axis
    return ctx.constrain(x, P(b_ax, e_ax, *([None] * (P_dims - 2))))


# ---------------------------------------------------------------------------
# decode-path MoE (single token per sequence)
# ---------------------------------------------------------------------------


def moe_decode(
    params: dict,
    x: Array,                      # [B, d]
    cfg: ModelConfig,
    ctx: ShardingCtx,
    routing_override: Optional[Tuple[Array, Array]] = None,
) -> Array:
    y, _ = moe_layer(
        params, x[:, None, :], cfg, ctx, routing_override=(
            (routing_override[0][:, None], routing_override[1][:, None])
            if routing_override is not None
            else None
        ),
    )
    return y[:, 0]
