"""State-space / recurrent mixers: Mamba (hymba branch) and xLSTM blocks.

All recurrences are first-order linear (h_t = a_t ⊙ h_{t-1} + b_t). Memory
discipline matters more than anything here: materialising the full [B, S,
d_inner, N] gate tensors at 32k–500k sequence lengths is terabytes, so the
full-sequence paths are **chunked** — an outer `lax.scan` carries the state
across chunks while the inner chunk runs either

  * mode="assoc": `lax.associative_scan` within the chunk (log-depth,
    TPU-friendly — the production path), or
  * mode="scan": strictly sequential `lax.scan` with gates computed
    per-step (O(1) live gates — the reference/oracle path).

Decode paths are O(1)-state single updates.
"""
from __future__ import annotations

import math
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm

Array = jax.Array

CHUNK = 256  # inner-chunk length for the associative path


def _linear_recurrence_chunk(a: Array, b: Array, h0: Array) -> Array:
    """h_t = a_t*h_{t-1} + b_t over axis 1 within one chunk (assoc)."""
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hs


def _maxplus_chunk(logf: Array, logi: Array, m0: Array) -> Array:
    """m_t = max(logf_t + m_{t-1}, logi_t) within one chunk (assoc)."""
    acum = jnp.cumsum(logf, axis=1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 + a2, jnp.maximum(b1 + a2, b2)

    _, b = jax.lax.associative_scan(combine, (logf, logi), axis=1)
    return jnp.maximum(acum + m0[:, None], b)


def _chunked(x_seq, carry0, chunk_fn, step_fn, mode: str, ck: int = CHUNK):
    """Run a recurrence over [B, S, ...] sequences.

    chunk_fn(carry, xs_chunk) -> (carry, ys_chunk)   (assoc inner)
    step_fn(carry, xs_t) -> (carry, ys_t)            (sequential inner)
    """
    B, S = x_seq.shape[:2]
    if mode == "scan":
        def body(c, xt):
            return step_fn(c, xt)
        c, ys = jax.lax.scan(body, carry0, x_seq.swapaxes(0, 1))
        return ys.swapaxes(0, 1)
    ck = min(ck, S)
    if S % ck:
        # fall back to a divisor (S is a power-of-2-ish in all our shapes)
        for cand in range(min(ck, S), 0, -1):
            if S % cand == 0:
                ck = cand
                break
    nc = S // ck
    xc = x_seq.reshape(B, nc, ck, *x_seq.shape[2:]).swapaxes(0, 1)
    c, ys = jax.lax.scan(chunk_fn, carry0, xc)
    return ys.swapaxes(0, 1).reshape(B, S, *ys.shape[3:])


# ===========================================================================
# Mamba (selective SSM) — used as the parallel branch in hymba blocks
# ===========================================================================


def init_mamba(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    N = s.state_dim
    dt_rank = max(1, math.ceil(d / 16))
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_dim, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_db": dense_init(ks[2], di, dt_rank + 2 * N, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.full((di,), -2.0, dtype),  # softplus => small init dt
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _mamba_gates(p: dict, xz: Array, cfg: ModelConfig):
    """xz: [..., di] conv-ed activations -> (a, b, C) for the recurrence."""
    N = cfg.ssm.state_dim
    dt_rank = p["dt_proj"].shape[0]
    dbc = xz @ p["x_db"]
    dt, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                  # [di, N]
    a = jnp.exp(dt[..., None] * A)                            # [..., di, N]
    b = (dt[..., None] * Bm[..., None, :].astype(jnp.float32)) * xz[
        ..., None
    ].astype(jnp.float32)                                     # [..., di, N]
    return a, b, Cm.astype(jnp.float32)


def _mamba_conv_full(p: dict, xs: Array) -> Array:
    """Depthwise causal conv over [B, S, di]."""
    K = p["conv_w"].shape[0]
    S = xs.shape[1]
    xp = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + S] * p["conv_w"][i] for i in range(K))
    return jax.nn.silu(out + p["conv_b"])


def mamba_forward(
    p: dict, x: Array, cfg: ModelConfig, mode: str = "assoc"
) -> Array:
    """Full-sequence Mamba mixer. x: [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    di = cfg.ssm.expand * d
    N = cfg.ssm.state_dim
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                         # [B,S,di] each
    xs = _mamba_conv_full(p, xs)
    h0 = jnp.zeros((B, di, N), jnp.float32)

    def chunk_fn(h, xs_c):                                    # xs_c [B,ck,di]
        a, b, Cm = _mamba_gates(p, xs_c, cfg)                 # [B,ck,di,N]
        hs = _linear_recurrence_chunk(a, b, h)
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cm)
        return hs[:, -1], y

    def step_fn(h, xs_t):                                     # xs_t [B,di]
        a, b, Cm = _mamba_gates(p, xs_t, cfg)                 # [B,di,N]
        h = a * h + b
        return h, jnp.einsum("bdn,bn->bd", h, Cm)

    # ck=64: the [B, ck, d_inner, N] f32 gate tensors are the live working
    # set (8.4 GB/chunk at ck=256 for hymba train_4k); Mamba-1's per-channel
    # A bars the [ck,ck] chunkwise trick used for mLSTM, so chunk length is
    # the memory knob here (§Perf bonus iteration).
    y = _chunked(xs, h0, chunk_fn, step_fn, mode, ck=64)
    y = y + p["D"] * xs.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    di = cfg.ssm.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.ssm.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.conv_dim - 1, di), dtype),
    }


def mamba_decode(p: dict, x: Array, state: dict, cfg: ModelConfig):
    """One-token Mamba step. x: [B, d] -> (y [B, d], state)."""
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                         # [B, di]
    conv = jnp.concatenate([state["conv"], xs[:, None]], axis=1)  # [B,K,di]
    xs = jax.nn.silu(jnp.einsum("bkd,kd->bd", conv, p["conv_w"]) + p["conv_b"])
    a, b, Cm = _mamba_gates(p, xs, cfg)                       # [B,di,N]
    h = a * state["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, Cm) + p["D"] * xs.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], {"h": h, "conv": conv[:, 1:]}


# ===========================================================================
# xLSTM — mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# ===========================================================================


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.ssm.xlstm_heads
    di = 2 * d                                        # proj factor 2
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], d, 2 * di, dtype),    # [x | gate]
        "wq": dense_init(ks[1], di, di, dtype),
        "wk": dense_init(ks[2], di, di, dtype),
        "wv": dense_init(ks[3], di, di, dtype),
        "w_if": dense_init(ks[4], di, 2 * H, dtype, scale=0.02),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),      # forget-open init
        "out_norm": init_rmsnorm(di, dtype),
        "down": dense_init(ks[5], di, d, dtype),
    }


def _mlstm_qkv(p, xi, H):
    q = xi @ p["wq"]
    k = xi @ p["wk"]
    v = xi @ p["wv"]
    hd = q.shape[-1] // H
    sh = (*q.shape[:-1], H, hd)
    return (
        q.reshape(sh).astype(jnp.float32) / math.sqrt(hd),
        k.reshape(sh).astype(jnp.float32),
        v.reshape(sh).astype(jnp.float32),
    )


def _mlstm_gates(p, xi, H):
    gates = (xi @ p["w_if"]).astype(jnp.float32)
    logi = gates[..., :H] + p["b_i"]
    logf = jax.nn.log_sigmoid(gates[..., H:] + p["b_f"])
    return logi, logf


def _mlstm_out(C, n, m, q, p, zg, cfg):
    num = jnp.einsum("...hkv,...hk->...hv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("...hk,...hk->...h", n, q)), 1.0)
    y = (num / den[..., None]).reshape(*q.shape[:-2], -1).astype(zg.dtype)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(zg)
    return y @ p["down"]


def mlstm_forward(p: dict, x: Array, cfg: ModelConfig, mode: str = "assoc") -> Array:
    """Full-sequence mLSTM. x: [B,S,d]."""
    B, S, d = x.shape
    H = cfg.ssm.xlstm_heads
    up = x @ p["up"]
    xi, zg = jnp.split(up, 2, axis=-1)
    hd = xi.shape[-1] // H
    carry0 = (
        jnp.zeros((B, H, hd, hd)),                # C (stabilised)
        jnp.zeros((B, H, hd)),                    # n
        jnp.full((B, H), -jnp.inf),               # m
    )

    def chunk_fn(carry, xi_c):                    # xi_c [B,ck,di]
        """Chunkwise-parallel mLSTM (§Perf hillclimb #3).

        The associative-scan form materialises the [B, ck, H, hd, hd]
        matrix-memory stack (gigabytes at hd=384). The chunkwise form never
        stacks C: within the chunk, outputs are an attention-like
        [ck, ck]-matmul over decay-weighted q·k scores; across chunks only
        the O(hd²) state carries. Identical math (stabilised), ~ck·hd²/ck²
        ≈ 2300x less intermediate HBM traffic at ck=64, hd=384.
        """
        C0, n0, m0 = carry
        q, k, v = _mlstm_qkv(p, xi_c, H)          # [B,ck,H,hd]
        logi, logf = _mlstm_gates(p, xi_c, H)     # [B,ck,H]
        m = _maxplus_chunk(logf, logi, m0)        # running stabiliser
        F = jnp.cumsum(logf, axis=1)              # [B,ck,H]
        # inter-chunk contribution scale: a_t = exp(F_t + m0 - m_t)
        a = jnp.exp(F + m0[:, None] - m)
        # intra-chunk decay matrix D[t,s] = exp(F_t - F_s + logi_s - m_t), s<=t
        expo = (
            F[:, :, None] - F[:, None, :] + logi[:, None, :] - m[:, :, None]
        )                                          # [B,ck(t),ck(s),H]
        tri = jnp.tril(jnp.ones((xi_c.shape[1], xi_c.shape[1]), bool))
        # mask BEFORE exp: s>t entries have positive exponents (F decreasing)
        D = jnp.exp(jnp.where(tri[None, :, :, None], expo, -jnp.inf))
        qk = jnp.einsum("bthd,bshd->btsh", q, k)  # [B,ck,ck,H]
        w = D * qk
        num = (
            a[..., None] * jnp.einsum("bthk,bhkv->bthv", q, C0)
            + jnp.einsum("btsh,bshv->bthv", w, v)
        )
        den_dot = (
            a * jnp.einsum("bthk,bhk->bth", q, n0)
            + jnp.einsum("btsh->bth", w)
        )
        y = num / jnp.maximum(jnp.abs(den_dot), 1.0)[..., None]
        # carry: state at chunk end (b_W[s] = D[W-1, s])
        bW = D[:, -1]                              # [B,ck,H]
        C1 = a[:, -1][..., None, None] * C0 + jnp.einsum(
            "bsh,bshk,bshv->bhkv", bW, k, v
        )
        n1 = a[:, -1][..., None] * n0 + jnp.einsum("bsh,bshk->bhk", bW, k)
        return (C1, n1, m[:, -1]), y

    def step_fn(carry, xi_t):                     # xi_t [B,di]
        C0, n0, m0 = carry
        q, k, v = _mlstm_qkv(p, xi_t, H)          # [B,H,hd]
        logi, logf = _mlstm_gates(p, xi_t, H)     # [B,H]
        m = jnp.maximum(logf + m0, logi)
        i_st = jnp.exp(logi - m)
        f_st = jnp.exp(logf + m0 - m)
        C = f_st[..., None, None] * C0 + i_st[..., None, None] * jnp.einsum(
            "bhk,bhv->bhkv", k, v
        )
        n = f_st[..., None] * n0 + i_st[..., None] * k
        num = jnp.einsum("bhkv,bhk->bhv", C, q)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
        return (C, n, m), num / den[..., None]

    y = _chunked(xi, carry0, chunk_fn, step_fn, mode, ck=64)
    y = y.reshape(B, S, -1).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(zg)
    return y @ p["down"]


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.ssm.xlstm_heads
    di = 2 * cfg.d_model
    hd = di // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
    }


def mlstm_decode(p: dict, x: Array, state: dict, cfg: ModelConfig):
    B, d = x.shape
    H = cfg.ssm.xlstm_heads
    up = x @ p["up"]
    xi, zg = jnp.split(up, 2, axis=-1)
    q, k, v = _mlstm_qkv(p, xi, H)                    # [B,H,hd]
    logi, logf = _mlstm_gates(p, xi, H)
    m = jnp.maximum(logf + state["m"], logi)
    i_st = jnp.exp(logi - m)
    f_st = jnp.exp(logf + state["m"] - m)
    C = f_st[..., None, None] * state["C"] + i_st[..., None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k, v
    )
    n = f_st[..., None] * state["n"] + i_st[..., None] * k
    y = _mlstm_out(C, n, m, q, p, zg, cfg)
    return y, {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    dff = max(1, int(4 * d // 3))
    return {
        "w_z": dense_init(ks[0], d, d, dtype),
        "w_gates": dense_init(ks[1], d, 3 * d, dtype, scale=0.02),  # i,f,o
        "b_i": jnp.zeros((d,), jnp.float32),
        "b_f": jnp.full((d,), 3.0, jnp.float32),
        "b_o": jnp.zeros((d,), jnp.float32),
        "ffn_in": dense_init(ks[2], d, dff, dtype),
        "ffn_gate": dense_init(ks[3], d, dff, dtype),
        "ffn_out": dense_init(ks[4], dff, d, dtype),
    }


def _slstm_gates(p, x):
    z = jnp.tanh((x @ p["w_z"]).astype(jnp.float32))
    g = (x @ p["w_gates"]).astype(jnp.float32)
    d = z.shape[-1]
    logi = g[..., :d] + p["b_i"]
    logf = jax.nn.log_sigmoid(g[..., d : 2 * d] + p["b_f"])
    o = jax.nn.sigmoid(g[..., 2 * d :] + p["b_o"])
    return z, logi, logf, o


def slstm_forward(p: dict, x: Array, cfg: ModelConfig, mode: str = "assoc") -> Array:
    B, S, d = x.shape
    carry0 = (
        jnp.zeros((B, d)),                        # c
        jnp.zeros((B, d)),                        # n
        jnp.full((B, d), -jnp.inf),               # m
    )

    def chunk_fn(carry, x_c):
        c0, n0, m0 = carry
        z, logi, logf, o = _slstm_gates(p, x_c)   # [B,ck,d]
        m = _maxplus_chunk(logf, logi, m0)
        m_prev = jnp.concatenate([m0[:, None], m[:, :-1]], 1)
        i_st = jnp.exp(logi - m)
        f_st = jnp.exp(logf + m_prev - m)
        cs = _linear_recurrence_chunk(f_st, i_st * z, c0)
        ns = _linear_recurrence_chunk(f_st, i_st, n0)
        h = o * cs / jnp.maximum(ns, 1e-6)
        return (cs[:, -1], ns[:, -1], m[:, -1]), h

    def step_fn(carry, x_t):
        c0, n0, m0 = carry
        z, logi, logf, o = _slstm_gates(p, x_t)   # [B,d]
        m = jnp.maximum(logf + m0, logi)
        i_st = jnp.exp(logi - m)
        f_st = jnp.exp(logf + m0 - m)
        c = f_st * c0 + i_st * z
        n = f_st * n0 + i_st
        return (c, n, m), o * c / jnp.maximum(n, 1e-6)

    h = _chunked(x, carry0, chunk_fn, step_fn, mode).astype(x.dtype)
    # post-FFN (pf = 4/3 GLU) as in the xLSTM sLSTM block
    f = jax.nn.silu(h @ p["ffn_gate"]) * (h @ p["ffn_in"])
    return f @ p["ffn_out"]


def slstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -jnp.inf, jnp.float32),
    }


def slstm_decode(p: dict, x: Array, state: dict, cfg: ModelConfig):
    z, logi, logf, o = _slstm_gates(p, x)         # [B,d]
    m = jnp.maximum(logf + state["m"], logi)
    i_st = jnp.exp(logi - m)
    f_st = jnp.exp(logf + state["m"] - m)
    c = f_st * state["c"] + i_st * z
    n = f_st * state["n"] + i_st
    h = (o * c / jnp.maximum(n, 1e-6)).astype(x.dtype)
    f = jax.nn.silu(h @ p["ffn_gate"]) * (h @ p["ffn_in"])
    return f @ p["ffn_out"], {"c": c, "n": n, "m": m}
