"""Config-driven transformer: one builder for all 10 assigned architectures.

Layers are grouped into repeating *periods* (e.g. gemma2's local/global pair,
Switch's dense/MoE pair, xLSTM's m/s pair) and the period-group params are
stacked so the depth dimension runs under `lax.scan` — keeping HLO size
O(period), not O(n_layers), which is what makes 94-layer dry-runs lower
quickly.

Three entry points:
  forward(...)       train / prefill (full-sequence)
  decode_step(...)   one-token serve step against a cache pytree
  init_cache(...)    cache pytree (ring-buffer KV for windowed layers,
                     recurrent states for ssm/hybrid archs, cross-attn
                     caches for enc-dec)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_lib
from repro.models.attention import (
    ShardingCtx,
    attend_decode,
    attend_decode_paged,
    attend_full,
    attend_prefill_chunk,
    init_attention,
)
from repro.models.layers import embed_init, ffn, init_ffn, init_rmsnorm, rmsnorm, softcap
from repro.models.moe import init_moe, moe_layer

Array = jax.Array


# ---------------------------------------------------------------------------
# layer-kind layout
# ---------------------------------------------------------------------------


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.block_kind == "attn":
        p = _lcm(p, len(cfg.attn.layer_pattern))
        if cfg.moe.enabled:
            p = _lcm(p, cfg.moe.moe_every)
    elif cfg.block_kind == "xlstm":
        p = _lcm(p, max(1, len(cfg.ssm.xlstm_pattern)))
    return p


def sub_kind(cfg: ModelConfig, sub: int) -> Dict[str, Any]:
    """Static description of sublayer `sub` within a period group."""
    if cfg.block_kind == "xlstm":
        pat = cfg.ssm.xlstm_pattern or ("m",)
        return {"kind": "xlstm", "cell": pat[sub % len(pat)]}
    if cfg.block_kind == "hymba":
        return {"kind": "hymba", "moe": False, "window": cfg.attn.window}
    is_moe = cfg.moe.enabled and (sub % cfg.moe.moe_every == cfg.moe.moe_every - 1)
    return {
        "kind": "attn",
        "moe": is_moe,
        "window": cfg.layer_window(sub),
    }


def n_moe_layers(cfg: ModelConfig) -> int:
    if not cfg.moe.enabled:
        return 0
    return cfg.n_layers // cfg.moe.moe_every


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_sublayer(key, cfg: ModelConfig, sub: int, cross: bool) -> dict:
    sk = sub_kind(cfg, sub)
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    p: dict = {"ln1": init_rmsnorm(d, dtype)}
    if sk["kind"] == "xlstm":
        init = ssm_lib.init_mlstm if sk["cell"] == "m" else ssm_lib.init_slstm
        p["mixer"] = init(ks[0], cfg)
        return p
    p["attn"] = init_attention(ks[0], cfg)
    if sk["kind"] == "hymba":
        p["mamba"] = ssm_lib.init_mamba(ks[1], cfg)
        p["attn_norm"] = init_rmsnorm(d, dtype)
        p["mamba_norm"] = init_rmsnorm(d, dtype)
    if cross:
        p["lnx"] = init_rmsnorm(d, dtype)
        p["xattn"] = init_attention(ks[2], cfg, cross=True)
    p["ln2"] = init_rmsnorm(d, dtype)
    if sk.get("moe"):
        p["moe"] = init_moe(ks[3], cfg)
    elif cfg.d_ff:
        p["mlp"] = init_ffn(ks[4], d, cfg.d_ff, cfg.glu, dtype)
    if cfg.post_norm:
        p["ln1_post"] = init_rmsnorm(d, dtype)
        p["ln2_post"] = init_rmsnorm(d, dtype)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    per = period(cfg)
    n_groups = cfg.n_layers // per
    assert cfg.n_layers % per == 0, (cfg.name, cfg.n_layers, per)

    def group(key, cross):
        sks = jax.random.split(key, per)
        return {f"sub{s}": _init_sublayer(sks[s], cfg, s, cross) for s in range(per)}

    gks = jax.random.split(ks[0], n_groups)
    params = {
        "embed": embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": _stack([group(gks[g], cross=cfg.enc_dec) for g in range(n_groups)]),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[2], cfg.padded_vocab, cfg.d_model, dtype).T
    if cfg.enc_dec:
        e_groups = cfg.n_enc_layers // per
        eks = jax.random.split(ks[3], e_groups)
        params["enc_blocks"] = _stack(
            [group(eks[g], cross=False) for g in range(e_groups)]
        )
        params["enc_norm"] = init_rmsnorm(cfg.d_model, dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill / encoder)
# ---------------------------------------------------------------------------


def _apply_sublayer_full(
    bp: dict,
    x: Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    sub: int,
    causal: bool,
    enc_out: Optional[Array],
    routing_override,
    scan_mode: str,
    collect_kv: bool = False,
):
    sk = sub_kind(cfg, sub)
    aux = {}
    if sk["kind"] == "xlstm":
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        fwd = ssm_lib.mlstm_forward if sk["cell"] == "m" else ssm_lib.slstm_forward
        return x + fwd(bp["mixer"], h, cfg, scan_mode), aux

    h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
    layer = sub  # pattern position
    if collect_kv:
        # rope-applied K/V, exactly what attend_decode would have written
        # into the cache at positions 0..S-1 — lets a request server seed
        # decode lanes straight from the prefill forward
        a, aux["kv"] = attend_full(
            bp["attn"], h, cfg, layer, ctx, causal=causal, return_kv=True
        )
    else:
        a = attend_full(bp["attn"], h, cfg, layer, ctx, causal=causal)
    if sk["kind"] == "hymba":
        mmb = ssm_lib.mamba_forward(bp["mamba"], h, cfg, scan_mode)
        a = 0.5 * (
            rmsnorm(bp["attn_norm"], a, cfg.norm_eps)
            + rmsnorm(bp["mamba_norm"], mmb, cfg.norm_eps)
        )
    if cfg.post_norm:
        a = rmsnorm(bp["ln1_post"], a, cfg.norm_eps)
    x = x + a
    if enc_out is not None and "xattn" in bp:
        hx = rmsnorm(bp["lnx"], x, cfg.norm_eps)
        x = x + attend_full(bp["xattn"], hx, cfg, layer, ctx, causal=False, kv_from=enc_out)
    h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if sk.get("moe"):
        y, moe_aux = moe_layer(bp["moe"], h, cfg, ctx, routing_override=routing_override)
        aux.update(moe_aux)
    elif "mlp" in bp:
        y = ffn(bp["mlp"], h, cfg.act, cfg.glu)
    else:
        y = jnp.zeros_like(h)
    if cfg.post_norm:
        y = rmsnorm(bp["ln2_post"], y, cfg.norm_eps)
    return x + y, aux


def _run_stack(
    blocks,
    x: Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    causal: bool,
    enc_out: Optional[Array],
    routing_override,  # (ids [L_moe,B,S,k], w) or None
    collect_router_logits: bool,
    scan_mode: str,
    remat: bool = False,
    collect_kv: bool = False,
):
    per = period(cfg)
    moe_per_group = sum(1 for s in range(per) if sub_kind(cfg, s).get("moe"))

    def body(carry, xs):
        x, g = carry
        gp = xs

        def one(x, moe_seen, rl_list, kv_dict):
            for s in range(per):
                ro = None
                if routing_override is not None and sub_kind(cfg, s).get("moe"):
                    li = g * moe_per_group + moe_seen
                    ro = (routing_override[0][li], routing_override[1][li])
                x, aux = _apply_sublayer_full(
                    gp[f"sub{s}"], x, cfg, ctx, s, causal, enc_out, ro,
                    scan_mode, collect_kv,
                )
                if "kv" in aux:
                    kv_dict[f"sub{s}"] = aux.pop("kv")
                if sub_kind(cfg, s).get("moe"):
                    moe_seen += 1
                    rl_list.append(aux)
            return x, rl_list, kv_dict

        rl_list: list = []
        kv_dict: dict = {}
        x, rl_list, kv_dict = one(x, 0, rl_list, kv_dict)
        x = ctx.act_constrain(x)
        ys = {}
        if moe_per_group:
            ys["aux_loss"] = sum(a["aux_loss"] for a in rl_list)
            ys["z_loss"] = sum(a["z_loss"] for a in rl_list)
            if collect_router_logits:
                ys["router_logits"] = jnp.stack(
                    [a["router_logits"] for a in rl_list]
                )  # [moe_per_group, B, S, E]
        if collect_kv:
            ys["kv"] = kv_dict  # {sub: (k, v)} -> stacked [G, B, S, K, D]
        return (x, g + 1), ys

    if remat:
        body = jax.checkpoint(body)  # recompute group internals in backward
    (x, _), ys = jax.lax.scan(body, (x, 0), blocks)
    x = ctx.constrain(x, P(ctx.batch_spec(x.shape[0]), None, None))
    return x, ys


def embed_tokens(params, cfg: ModelConfig, tokens: Array) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params, cfg: ModelConfig, x: Array) -> Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    logits = softcap(logits, cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padded vocab columns (see ModelConfig.padded_vocab)
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def forward(
    params: dict,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    tokens: Array,                       # [B, S] int32 (decoder tokens)
    enc_input: Optional[Array] = None,   # [B, S_enc, d] stub frontend embeddings
    routing_override=None,
    collect_router_logits: bool = False,
    scan_mode: str = "assoc",
    remat: bool = False,
    collect_kv: bool = False,
) -> Dict[str, Any]:
    """Full forward. Returns dict(logits, aux_loss, z_loss, router_logits?,
    kv?). collect_kv=True additionally returns every self-attention layer's
    rope-applied K/V ({sub: (k, v)} each [G, B, S, K, D]) so a serving loop
    can seed decode caches from the prefill pass."""
    enc_out = None
    if cfg.enc_dec:
        assert enc_input is not None, "enc-dec arch needs encoder input"
        e = enc_input.astype(jnp.dtype(cfg.dtype))
        e, _ = _run_stack(
            params["enc_blocks"], e, cfg, ctx, causal=False, enc_out=None,
            routing_override=None, collect_router_logits=False,
            scan_mode=scan_mode, remat=remat,
        )
        enc_out = rmsnorm(params["enc_norm"], e, cfg.norm_eps)

    x = embed_tokens(params, cfg, tokens)
    x = ctx.act_constrain(x)
    x, ys = _run_stack(
        params["blocks"], x, cfg, ctx, causal=True, enc_out=enc_out,
        routing_override=routing_override,
        collect_router_logits=collect_router_logits,
        scan_mode=scan_mode, remat=remat, collect_kv=collect_kv,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, cfg, x)

    out: Dict[str, Any] = {"logits": logits}
    if "aux_loss" in ys:
        out["aux_loss"] = ys["aux_loss"].sum()
        out["z_loss"] = ys["z_loss"].sum()
        if collect_router_logits:
            rl = ys["router_logits"]  # [G, mpg, B, S, E]
            out["router_logits"] = rl.reshape(-1, *rl.shape[2:])
    else:
        out["aux_loss"] = jnp.zeros((), jnp.float32)
        out["z_loss"] = jnp.zeros((), jnp.float32)
    if collect_kv:
        out["kv"] = ys["kv"]
    return out


def lm_loss(logits: Array, labels: Array, mask: Optional[Array] = None) -> Array:
    """Cross-entropy; labels [B,S] with -100 = ignore."""
    valid = labels >= 0 if mask is None else mask
    lbl = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, lbl[..., None], axis=-1)[..., 0]
    return -(ll * valid).sum() / jnp.maximum(valid.sum(), 1)


# ---------------------------------------------------------------------------
# cache + decode
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, sub: int, seq_budget: int) -> int:
    sk = sub_kind(cfg, sub)
    w = sk.get("window", 0)
    return min(seq_budget, w) if w else seq_budget


def init_cache(
    cfg: ModelConfig,
    batch: int,
    seq_budget: int,
    enc_len: int = 0,
) -> dict:
    """Zeros cache pytree. Layout: {"sub{s}": per-group-stacked state}."""
    per = period(cfg)
    n_groups = cfg.n_layers // per
    dtype = jnp.dtype(cfg.dtype)
    K, D = cfg.n_kv_heads, cfg.hd
    cache: dict = {"pos": jnp.zeros((batch,), jnp.int32)}
    for s in range(per):
        sk = sub_kind(cfg, s)
        entry: dict = {}
        if sk["kind"] == "xlstm":
            init = (
                ssm_lib.mlstm_init_state if sk["cell"] == "m" else ssm_lib.slstm_init_state
            )
            st = init(cfg, batch)
            entry["state"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_groups, *x.shape)).copy(), st
            )
        else:
            Sc = cache_len(cfg, s, seq_budget)
            entry["k"] = jnp.zeros((n_groups, batch, Sc, K, D), dtype)
            entry["v"] = jnp.zeros((n_groups, batch, Sc, K, D), dtype)
            if sk["kind"] == "hymba":
                st = ssm_lib.mamba_init_state(cfg, batch, dtype)
                entry["state"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_groups, *x.shape)).copy(), st
                )
            if cfg.enc_dec:
                entry["cross_k"] = jnp.zeros((n_groups, batch, enc_len, K, D), dtype)
                entry["cross_v"] = jnp.zeros((n_groups, batch, enc_len, K, D), dtype)
        cache[f"sub{s}"] = entry
    if cfg.enc_dec:
        cache["cross_len"] = jnp.full((batch,), enc_len, jnp.int32)
    return cache


def init_paged_cache(cfg: ModelConfig, batch: int, paged) -> dict:
    """Paged-K/V cache pytree (see core/residency.py for the manager).

    Layout: {"pos": [B], "page_table": [B, Mp] int32 (-1 = unallocated),
    "sub{s}": {"kp"/"vp": [G, P+1, page, K, D]}} — pools are *shared*
    across lanes and the last page is the trash page masked-out writes
    are routed to. One table serves every layer: all layers cache the
    same token positions, so entry i of a lane names the device page
    holding positions [i*page, (i+1)*page) in every pool at once. Pages
    must be allocated in position order (`KVPagePool` enforces this) —
    the decode gather derives each slot's global position statically
    from its table index. `paged` is a `residency.PagedKVConfig` (held
    duck-typed to keep the model layer import-free of the manager)."""
    per = period(cfg)
    assert cfg.block_kind == "attn" and not cfg.enc_dec, (
        "paged K/V supports attention-family decoder-only archs"
    )
    assert cfg.n_layers % per == 0
    n_groups = cfg.n_layers // per
    dtype = jnp.dtype(cfg.dtype)
    K, D = cfg.n_kv_heads, cfg.hd
    cache: dict = {
        "pos": jnp.zeros((batch,), jnp.int32),
        "page_table": jnp.full((batch, paged.pages_per_lane()), -1, jnp.int32),
    }
    for s in range(per):
        assert sub_kind(cfg, s)["kind"] == "attn"
        cache[f"sub{s}"] = {
            "kp": jnp.zeros(
                (n_groups, paged.kv_pages + 1, paged.page_size, K, D), dtype
            ),
            "vp": jnp.zeros(
                (n_groups, paged.kv_pages + 1, paged.page_size, K, D), dtype
            ),
        }
    return cache


def _apply_sublayer_decode(
    bp: dict,
    entry: dict,
    x: Array,                  # [B, d]
    pos: Array,                # [B]
    cfg: ModelConfig,
    ctx: ShardingCtx,
    sub: int,
    cross_len: Optional[Array],
    routing_override,
    page_table: Optional[Array] = None,  # [B, Mp] when the cache is paged
    active: Optional[Array] = None,      # [B] bool (paged: trash-route writes)
):
    sk = sub_kind(cfg, sub)
    new_entry = dict(entry)
    if sk["kind"] == "xlstm":
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        dec = ssm_lib.mlstm_decode if sk["cell"] == "m" else ssm_lib.slstm_decode
        y, st = dec(bp["mixer"], h, entry["state"], cfg)
        new_entry["state"] = st
        return x + y, new_entry

    h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
    if "kp" in entry:  # paged K/V pool (see core/residency.py)
        a, nkp, nvp = attend_decode_paged(
            bp["attn"], h, entry["kp"], entry["vp"], page_table, pos,
            cfg, sub, ctx, active=active,
        )
        new_entry["kp"], new_entry["vp"] = nkp, nvp
    else:
        a, nk, nv = attend_decode(
            bp["attn"], h, entry["k"], entry["v"], pos, cfg, sub, ctx
        )
        new_entry["k"], new_entry["v"] = nk, nv
    if sk["kind"] == "hymba":
        mmb, st = ssm_lib.mamba_decode(bp["mamba"], h, entry["state"], cfg)
        new_entry["state"] = st
        a = 0.5 * (
            rmsnorm(bp["attn_norm"], a, cfg.norm_eps)
            + rmsnorm(bp["mamba_norm"], mmb, cfg.norm_eps)
        )
    if cfg.post_norm:
        a = rmsnorm(bp["ln1_post"], a, cfg.norm_eps)
    x = x + a
    if "xattn" in bp and cross_len is not None:
        hx = rmsnorm(bp["lnx"], x, cfg.norm_eps)
        ya, _, _ = attend_decode(
            bp["xattn"], hx, entry["cross_k"], entry["cross_v"],
            pos, cfg, sub, ctx, cross=True, cross_len=cross_len,
        )
        x = x + ya
    h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if sk.get("moe"):
        from repro.models.moe import moe_decode

        y = moe_decode(bp["moe"], h, cfg, ctx, routing_override=routing_override)
    elif "mlp" in bp:
        y = ffn(bp["mlp"], h, cfg.act, cfg.glu)
    else:
        y = jnp.zeros_like(h)
    if cfg.post_norm:
        y = rmsnorm(bp["ln2_post"], y, cfg.norm_eps)
    return x + y, new_entry


def decode_step(
    params: dict,
    cache: dict,
    tokens: Array,            # [B] int32
    cfg: ModelConfig,
    ctx: ShardingCtx,
    routing_override=None,    # (ids [L_moe,B,k], w [L_moe,B,k])
    active: Optional[Array] = None,  # [B] bool; paged caches route inactive
                                     # lanes' K/V writes to the trash page
) -> Tuple[Array, dict]:
    """One serve step: next-token logits [B, V] + updated cache."""
    per = period(cfg)
    moe_per_group = sum(1 for s in range(per) if sub_kind(cfg, s).get("moe"))
    pos = cache["pos"]
    cross_len = cache.get("cross_len")
    page_table = cache.get("page_table")
    x = embed_tokens(params, cfg, tokens)

    def body(carry, xs):
        x, g = carry
        gp, entries = xs
        new_entries = {}
        moe_seen = 0
        for s in range(per):
            ro = None
            if routing_override is not None and sub_kind(cfg, s).get("moe"):
                li = g * moe_per_group + moe_seen
                ro = (routing_override[0][li], routing_override[1][li])
                moe_seen += 1
            x, ne = _apply_sublayer_decode(
                gp[f"sub{s}"], entries[f"sub{s}"], x, pos, cfg, ctx, s,
                cross_len, ro, page_table=page_table, active=active,
            )
            new_entries[f"sub{s}"] = ne
        return (x, g + 1), new_entries

    entries = {k: v for k, v in cache.items() if k.startswith("sub")}
    (x, _), new_entries = jax.lax.scan(body, (x, 0), (params["blocks"], entries))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, cfg, x)
    new_cache = dict(cache)
    new_cache.update(new_entries)
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# speculative verify: k-position decode with accept/reject rollback
# ---------------------------------------------------------------------------


def verify_step(
    params: dict,
    cache: dict,
    tokens: Array,            # [B, kb]: col 0 = last accepted token, 1.. = draft
    cfg: ModelConfig,
    ctx: ShardingCtx,
    routing_override=None,    # (ids [kb, L_moe, B, k], w [kb, L_moe, B, k])
    active: Optional[Array] = None,   # [B] bool; False => lane fully rolled back
) -> Tuple[Array, Array, Array, dict]:
    """Verify a speculative draft block in ONE jitted program.

    Runs `kb` sequential `decode_step`s under `lax.scan` — the per-position
    math (decode attention over the growing ring cache, MoE with the
    position's routing override) is exactly the vanilla one-token step, so
    greedy outputs are bit-identical to `kb` separate decode_step dispatches;
    only the Python/jit round trips collapse from 2·kb to 1.

    Acceptance: position 0's input is the real last token, so its argmax is
    always emitted; position i>0 consumed draft token `tokens[:, i]`, so its
    output counts only while every earlier draft token matched the model's
    argmax. `n_acc ∈ [1, kb]` per lane (0 for inactive lanes).

    Rollback restores the cache to "only the accepted prefix ran":
      * ring K/V — position i wrote slot (pos+i) % Sc; rejected positions'
        slots are restored from the pre-verify cache (requires kb <= Sc so
        block positions never collide in the ring);
      * recurrent states (mamba/xLSTM entries) — the scan stacks each
        position's post-update state and the lane selects position
        n_acc-1's snapshot;
      * pos advances by n_acc.

    Returns (out_tokens [B, kb], n_acc [B], logits [kb, B, V], new_cache).
    """
    B, kb = tokens.shape
    for skey in (k for k in cache if k.startswith("sub")):
        if "k" in cache[skey]:
            assert cache[skey]["k"].shape[2] >= kb, (
                f"draft window {kb} exceeds {skey}'s ring cache "
                f"({cache[skey]['k'].shape[2]} slots)"
            )
    orig = cache
    pos0 = cache["pos"]
    state_subs = [k for k in cache if k.startswith("sub") and "state" in cache[k]]

    def body(c, xs):
        if routing_override is not None:
            tok, ro_ids, ro_w = xs
            ro = (ro_ids, ro_w)
        else:
            tok = xs
            ro = None
        logits, c = decode_step(
            params, c, tok, cfg, ctx, routing_override=ro, active=active
        )
        snap = {sk: c[sk]["state"] for sk in state_subs}
        return c, (jnp.argmax(logits, -1).astype(jnp.int32), logits, snap)

    toks_t = jnp.moveaxis(tokens, 1, 0)                   # [kb, B]
    xs = toks_t if routing_override is None else (
        toks_t, routing_override[0], routing_override[1]
    )
    scanned, (out_t, logits, snaps) = jax.lax.scan(body, cache, xs)
    out = jnp.moveaxis(out_t, 0, 1)                       # [B, kb]

    # longest accepted prefix: 1 (position 0 is real) + leading draft matches
    # (kb == 1 degenerates to the vanilla step: the empty cumprod sums to 0)
    match = (out[:, : kb - 1] == tokens[:, 1:]).astype(jnp.int32)
    n_acc = (1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1)).astype(jnp.int32)
    if active is not None:
        n_acc = jnp.where(active, n_acc, 0)

    i_idx = jnp.arange(kb)
    rejected = i_idx[None, :] >= n_acc[:, None]           # [B, kb]
    bidx = jnp.arange(B)
    new_cache = dict(scanned)
    for skey in (k for k in cache if k.startswith("sub")):
        entry = dict(new_cache[skey])
        if "kp" in entry:
            # paged rollback: position pos0+i wrote (page pid_i, offset off_i)
            # through the shared table; rejected positions restore the
            # pre-verify bytes. Inactive lanes wrote the trash page (active
            # was threaded into the scan), so their "restore" is a no-op on
            # garbage. Static loop over the block — kb is small.
            page = entry["kp"].shape[2]
            trash = entry["kp"].shape[1] - 1
            pt = cache["page_table"]
            Mp = pt.shape[1]
            for i in range(kb):
                p_i = pos0 + i
                # block positions past the addressable range wrote the
                # trash page (see attend_decode_paged) — restore there too
                pidx_i = p_i // page
                pid_i = jnp.take_along_axis(
                    pt, jnp.clip(pidx_i, 0, Mp - 1)[:, None], axis=1
                )[:, 0]
                pid_i = jnp.where((pidx_i < Mp) & (pid_i >= 0), pid_i, trash)
                if active is not None:
                    pid_i = jnp.where(active, pid_i, trash)
                off_i = p_i % page
                rej = rejected[:, i][None, :, None, None]   # [1, B, 1, 1]
                for key in ("kp", "vp"):
                    cur = entry[key][:, pid_i, off_i]       # [G, B, K, D]
                    org = orig[skey][key][:, pid_i, off_i]
                    entry[key] = entry[key].at[:, pid_i, off_i].set(
                        jnp.where(rej, org, cur)
                    )
        if "k" in entry:
            Sc = entry["k"].shape[2]
            slots = (pos0[:, None] + i_idx[None, :]) % Sc  # [B, kb]
            restore = (
                jnp.zeros((B, Sc), jnp.int32)
                .at[bidx[:, None], slots]
                .add(rejected.astype(jnp.int32))
            ) > 0                                          # [B, Sc]
            m = restore[None, :, :, None, None]
            entry["k"] = jnp.where(m, orig[skey]["k"], entry["k"])
            entry["v"] = jnp.where(m, orig[skey]["v"], entry["v"])
        if "state" in entry:
            sel_i = jnp.maximum(n_acc - 1, 0)

            def sel(stk, og):
                # stk [kb, G, B, ...] per-position snapshots; og [G, B, ...]
                s2 = jnp.moveaxis(stk, 2, 0)               # [B, kb, G, ...]
                chosen = jnp.moveaxis(s2[bidx, sel_i], 0, 1)
                keep = (n_acc > 0).reshape(1, B, *([1] * (og.ndim - 2)))
                return jnp.where(keep, chosen, og)

            entry["state"] = jax.tree.map(sel, snaps[skey], orig[skey]["state"])
        new_cache[skey] = entry
    new_cache["pos"] = pos0 + n_acc
    return out, n_acc, logits, new_cache


# ---------------------------------------------------------------------------
# chunked prefill: advance one paged lane through a prompt chunk
# ---------------------------------------------------------------------------


def _apply_sublayer_chunk(
    bp: dict,
    entry: dict,
    x: Array,                  # [1, T, d]
    pos0: Array,               # [1]
    page_table: Array,         # [1, Mp]
    cfg: ModelConfig,
    ctx: ShardingCtx,
    sub: int,
    routing_override,
):
    """`_apply_sublayer_full` semantics for a [1, T] chunk that continues
    at absolute position pos0 against the paged cache."""
    sk = sub_kind(cfg, sub)
    assert sk["kind"] == "attn", "chunked prefill supports attention blocks only"
    new_entry = dict(entry)
    h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
    a, nkp, nvp = attend_prefill_chunk(
        bp["attn"], h, entry["kp"], entry["vp"], page_table, pos0,
        cfg, sub, ctx,
    )
    new_entry["kp"], new_entry["vp"] = nkp, nvp
    if cfg.post_norm:
        a = rmsnorm(bp["ln1_post"], a, cfg.norm_eps)
    x = x + a
    h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if sk.get("moe"):
        y, _ = moe_layer(bp["moe"], h, cfg, ctx, routing_override=routing_override)
    elif "mlp" in bp:
        y = ffn(bp["mlp"], h, cfg.act, cfg.glu)
    else:
        y = jnp.zeros_like(h)
    if cfg.post_norm:
        y = rmsnorm(bp["ln2_post"], y, cfg.norm_eps)
    return x + y, new_entry


def prefill_chunk_step(
    params: dict,
    cache: dict,
    tokens: Array,            # [1, T] one chunk of one lane's prompt
    cfg: ModelConfig,
    ctx: ShardingCtx,
    routing_override=None,    # (ids [L_moe,1,T,k], w) — full-forward layout
) -> Tuple[Array, dict]:
    """Advance one paged lane through a prompt chunk.

    Runs the full-forward math over [1, T] with absolute positions
    pos0..pos0+T-1, writing K/V through the page table as it goes, and
    returns (logits [1, T, V], new_cache with pos advanced by T). The
    caller interleaves these steps with decode ticks — that interleaving
    is what keeps a 100k-token prefill from stalling the continuous batch
    (serving/server.py) — and is responsible for page residency over the
    chunk's attention span before dispatch (KVPagePool.ensure)."""
    per = period(cfg)
    moe_per_group = sum(1 for s in range(per) if sub_kind(cfg, s).get("moe"))
    pos0 = cache["pos"]
    page_table = cache["page_table"]
    x = embed_tokens(params, cfg, tokens)

    def body(carry, xs):
        x, g = carry
        gp, entries = xs
        new_entries = {}
        moe_seen = 0
        for s in range(per):
            ro = None
            if routing_override is not None and sub_kind(cfg, s).get("moe"):
                li = g * moe_per_group + moe_seen
                ro = (routing_override[0][li], routing_override[1][li])
                moe_seen += 1
            x, ne = _apply_sublayer_chunk(
                gp[f"sub{s}"], entries[f"sub{s}"], x, pos0, page_table,
                cfg, ctx, s, ro,
            )
            new_entries[f"sub{s}"] = ne
        return (x, g + 1), new_entries

    entries = {k: v for k, v in cache.items() if k.startswith("sub")}
    (x, _), new_entries = jax.lax.scan(body, (x, 0), (params["blocks"], entries))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, cfg, x)
    new_cache = dict(cache)
    new_cache.update(new_entries)
    new_cache["pos"] = pos0 + tokens.shape[1]
    return logits, new_cache
