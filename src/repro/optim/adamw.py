"""AdamW (Loshchilov & Hutter, 2019) over arbitrary pytrees — no optax here."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params, moment_dtype=jnp.float32) -> dict:
    """moment_dtype=bfloat16 halves optimizer HBM (§Perf iteration 5);
    update math still runs in f32."""
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=moment_dtype), p)
    return {"m": zeros(params), "v": zeros(params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(
    grads,
    params,
    state: dict,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float = 0.0,
) -> Tuple[Any, dict]:
    t = state["t"] + 1
    if grad_clip:
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    def upd(g, p, m, v):
        g32 = g.astype(jnp.float32)
        mdt = m.dtype
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mh = m32 / (1 - b1**t)
        vh = v32 / (1 - b2**t)
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * step).astype(p.dtype),
            m32.astype(mdt),
            v32.astype(mdt),
        )

    out = jax.tree.map(upd, grads, params, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "t": t}
