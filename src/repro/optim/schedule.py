"""LR schedules as plain callables step -> lr."""
from __future__ import annotations

import math


def constant(lr: float):
    return lambda step: lr


def linear_warmup_cosine(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def f(step):
        if step < warmup:
            return lr * (step + 1) / max(1, warmup)
        t = (step - warmup) / max(1, total - warmup)
        t = min(1.0, t)
        return lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + math.cos(math.pi * t)))

    return f
