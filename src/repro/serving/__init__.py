"""Request-level serving: continuous batching + SLA-aware scheduling over
the SiDA hash-ahead pipeline (request lifecycle, admission queue, lane
batcher, request server, telemetry), behind one consolidated config object
(`ServingConfig`) and a multi-tenant front door (`TenantConfig`, WFQ
scheduling, per-tenant shedding/quotas/telemetry).

This module IS the public serving API: everything in `__all__` is covered
by the snapshot check in tools/check_api.py, so additions and removals are
deliberate (update the snapshot with `python tools/check_api.py --update`).
"""
from repro.serving.config import (
    BatchingConfig,
    FaultToleranceConfig,
    ParallelServeConfig,
    PrefetchServeConfig,
    QuantServeConfig,
    ServingConfig,
    ServingConfigError,
    SpecServeConfig,
    TenantConfig,
    add_serving_args,
    parse_tenants,
)
from repro.serving.request import Request, RequestState, poisson_requests
from repro.serving.scheduler import (
    DEFAULT_BUCKETS,
    AdmissionController,
    LaneTable,
    Scheduler,
    TenantAdmission,
    WFQScheduler,
    bucket_len,
)
from repro.serving.server import RequestServer
from repro.serving.telemetry import Telemetry

__all__ = [
    # request lifecycle
    "Request",
    "RequestState",
    "poisson_requests",
    # scheduling
    "DEFAULT_BUCKETS",
    "AdmissionController",
    "LaneTable",
    "Scheduler",
    "TenantAdmission",
    "WFQScheduler",
    "bucket_len",
    # configuration
    "BatchingConfig",
    "FaultToleranceConfig",
    "ParallelServeConfig",
    "PrefetchServeConfig",
    "QuantServeConfig",
    "ServingConfig",
    "ServingConfigError",
    "SpecServeConfig",
    "TenantConfig",
    "add_serving_args",
    "parse_tenants",
    # server + telemetry
    "RequestServer",
    "Telemetry",
]
