"""Request-level serving: continuous batching + SLA-aware scheduling over
the SiDA hash-ahead pipeline (request lifecycle, admission queue, lane
batcher, request server, telemetry)."""
from repro.serving.request import Request, RequestState, poisson_requests
from repro.serving.scheduler import (
    DEFAULT_BUCKETS,
    AdmissionController,
    LaneTable,
    Scheduler,
    bucket_len,
)
from repro.serving.server import RequestServer
from repro.serving.telemetry import Telemetry

__all__ = [
    "Request",
    "RequestState",
    "poisson_requests",
    "DEFAULT_BUCKETS",
    "AdmissionController",
    "LaneTable",
    "Scheduler",
    "bucket_len",
    "RequestServer",
    "Telemetry",
]
