"""Consolidated serving configuration: one `ServingConfig` object for the
whole request-serving stack.

Nine PRs of serving features left `RequestServer.__init__` with ~30 flat
keyword arguments mirrored ad hoc by `launch/serve.py`'s flag plumbing.
This module is the single source of truth that replaces both:

* `ServingConfig` groups every server knob into coherent sub-configs
  (batching, prefetch, quant/tier, speculation, expert parallelism, paged
  K/V, fault tolerance, tenants) with `validate()` carrying the cross-field
  rules that used to live in `launch/serve.py::validate_serve_args`.
* `SERVE_FLAGS` + `add_serving_args()` register the CLI surface FROM this
  module, and `ServingConfig.from_args()` builds the config back out of the
  parsed namespace — flags and config cannot drift because both ends read
  the same table (`tools/gen_flags.py` regenerates the README flag table
  from the live parser, and tests/test_serving_config.py round-trips the
  full matrix).
* `TenantConfig` is the multi-tenant front door's registry entry: WFQ
  weight, token-rate budget, expert-pin quota, and SLO class — consumed by
  the scheduler's deficit-round-robin layer, the store's pin-quota
  enforcement, and the per-tenant telemetry partitions.

Back-compat: `RequestServer(**legacy_kwargs)` still works through
`ServingConfig.from_kwargs` (see the deprecation note there); the
degenerate single-tenant config is byte-identical to the kwargs path.
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import TierConfig
from repro.core.faults import KNOWN_SITES, FaultPlan
from repro.core.offload import ShardedStoreConfig
from repro.core.residency import PagedKVConfig

DEFAULT_BUCKETS = (8, 16, 32, 64, 128)
DEFAULT_TENANT = "default"


class ServingConfigError(ValueError):
    """An incoherent serving configuration (the structured equivalent of
    `validate_serve_args`'s SystemExit — launchers catch and exit, tests
    assert on the message)."""


# ----------------------------------------------------------------------
# tenants
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class TenantConfig:
    """One tenant's service contract in the multi-tenant front door.

    `weight` is the WFQ share: the deficit-round-robin scheduler grants
    each tenant `weight x quantum` prefill tokens per round, so long-run
    service is proportional to weight regardless of offered load.
    `token_rate` is an absolute budget (generated tokens/second, token
    bucket with `burst` capacity; 0 = unlimited): tokens debit the bucket
    as they are generated and an empty bucket defers the tenant's queued
    requests (never drops them). `pin_quota` caps the share of each MoE
    layer's device slots this tenant may hold pinned
    (`ExpertStore.pin_experts` attribution) so one tenant's hot experts
    cannot monopolize the slot pools every tenant's hit rate depends on.
    `slo_class` labels telemetry; `default_slo_s` supplies a deadline for
    this tenant's requests that arrive without one (admission control and
    shedding key off deadlines)."""

    name: str
    weight: float = 1.0
    token_rate: float = 0.0     # generated tokens/sec budget; 0 = unlimited
    burst: float = 0.0          # token-bucket capacity; 0 => 1s at token_rate
    pin_quota: float = 1.0      # max fraction of per-layer slots pinned
    slo_class: str = "standard"
    default_slo_s: Optional[float] = None

    def validate(self) -> None:
        if not self.name:
            raise ServingConfigError("tenant name must be non-empty")
        if self.weight <= 0:
            raise ServingConfigError(
                f"tenant {self.name!r}: weight must be > 0 (got {self.weight})"
            )
        if self.token_rate < 0 or self.burst < 0:
            raise ServingConfigError(
                f"tenant {self.name!r}: token_rate/burst must be >= 0"
            )
        if not (0.0 < self.pin_quota <= 1.0):
            raise ServingConfigError(
                f"tenant {self.name!r}: pin_quota must be in (0, 1] "
                f"(fraction of per-layer slots; got {self.pin_quota})"
            )


def parse_tenants(spec: str) -> Tuple[TenantConfig, ...]:
    """Parse the `--tenants` grammar: comma-separated
    `name[:weight=W][:rate=R][:burst=B][:pin=F][:slo=S][:class=C]`,
    e.g. ``paid:weight=4:pin=0.5,free:weight=1:rate=200``."""
    out: List[TenantConfig] = []
    keys = {
        "weight": ("weight", float),
        "rate": ("token_rate", float),
        "burst": ("burst", float),
        "pin": ("pin_quota", float),
        "slo": ("default_slo_s", float),
        "class": ("slo_class", str),
    }
    for part in filter(None, (p.strip() for p in spec.split(","))):
        fields = part.split(":")
        kw: Dict[str, Any] = {"name": fields[0].strip()}
        for f in fields[1:]:
            if "=" not in f:
                raise ServingConfigError(
                    f"tenant spec {part!r}: expected key=value, got {f!r}"
                )
            k, v = f.split("=", 1)
            if k not in keys:
                raise ServingConfigError(
                    f"tenant spec {part!r}: unknown key {k!r} "
                    f"(known: {', '.join(keys)})"
                )
            attr, typ = keys[k]
            try:
                kw[attr] = typ(v)
            except ValueError:
                raise ServingConfigError(
                    f"tenant spec {part!r}: bad value {v!r} for {k}"
                ) from None
        t = TenantConfig(**kw)
        t.validate()
        out.append(t)
    names = [t.name for t in out]
    if len(set(names)) != len(names):
        raise ServingConfigError(f"duplicate tenant names in {spec!r}")
    return tuple(out)


# ----------------------------------------------------------------------
# grouped sub-configs
# ----------------------------------------------------------------------
@dataclass
class BatchingConfig:
    """Continuous-batching geometry: decode lanes, prefill batch size, the
    length-bucket ladder, the (ring) K/V length, and expired-request
    dropping."""

    max_lanes: int = 4
    max_prefill_batch: int = 4
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    cache_len: int = 0          # 0 => 2 * buckets[-1] (ring path only)
    drop_expired: bool = False


@dataclass
class PrefetchServeConfig:
    """Async expert-prefetch pipeline + its supervision bounds. `depth`
    None defers to the model config's `cfg.prefetch`; 0 forces synchronous
    inline uploads."""

    depth: Optional[int] = None
    staging_buffers: Optional[int] = None
    fence_timeout_s: Optional[float] = None   # per-tick ticket.wait bound
    watchdog_interval_s: float = 0.25
    watchdog_max_job_age_s: Optional[float] = None


@dataclass
class QuantServeConfig:
    """Residency formats: host tier quantization, int8-native device slots,
    and the optional hot/warm (int8/int4) residency tiers."""

    host_quant: str = "none"                 # "none" | "int8"
    quantized_slots: Optional[bool] = None   # None => cfg.quant
    scale_granularity: Optional[str] = None  # "channel" | "tensor"
    tier: Optional[TierConfig] = None


@dataclass
class SpecServeConfig:
    """Speculative decode: draft mode + window. None defers to the model
    config's `cfg.spec`."""

    mode: Optional[str] = None   # "off" | "draft"
    k: Optional[int] = None


@dataclass
class ParallelServeConfig:
    """Expert parallelism: sharded slot pools (+ hot-expert replication via
    `sharded.replicate_hot`) and online home rebalancing."""

    sharded: Optional[ShardedStoreConfig] = None
    rebalance_interval: float = 0.0


@dataclass
class FaultToleranceConfig:
    """Seeded chaos plan + overload shedding. `shed` holds the admission
    controller template; with tenants configured the server splits it into
    per-tenant controllers (per-tenant depth/EMA) so one tenant's overload
    sheds only that tenant."""

    plan: Optional[FaultPlan] = None
    shed: Optional["AdmissionController"] = None  # noqa: F821 (scheduler)


@dataclass
class ServingConfig:
    """Every `RequestServer` knob, grouped. See module docstring."""

    slots_per_layer: int = 2
    serve_top_k: Optional[int] = None
    eviction: str = "lru"
    keep_prefill_logits: bool = False
    keep_decode_logits: bool = False
    batching: BatchingConfig = field(default_factory=BatchingConfig)
    prefetch: PrefetchServeConfig = field(default_factory=PrefetchServeConfig)
    quant: QuantServeConfig = field(default_factory=QuantServeConfig)
    spec: SpecServeConfig = field(default_factory=SpecServeConfig)
    parallel: ParallelServeConfig = field(default_factory=ParallelServeConfig)
    paged: Optional[PagedKVConfig] = None
    faults: FaultToleranceConfig = field(default_factory=FaultToleranceConfig)
    tenants: Tuple[TenantConfig, ...] = ()   # () = single-tenant (degenerate)
    wfq_quantum: float = 64.0   # DRR tokens granted per round per unit weight

    # ------------------------------------------------------------------
    @property
    def multitenant(self) -> bool:
        return len(self.tenants) > 0

    def tenant(self, name: str) -> Optional[TenantConfig]:
        for t in self.tenants:
            if t.name == name:
                return t
        return None

    # ------------------------------------------------------------------
    def validate(
        self,
        max_prompt_len: Optional[int] = None,
        max_new_tokens: Optional[int] = None,
        slo_s: Optional[float] = None,
    ) -> "ServingConfig":
        """Cross-field coherence rules (absorbed from the launcher's old
        `validate_serve_args`). The optional workload hints let launchers
        check the config against the stream they are about to serve; pure
        config rules run regardless. Raises `ServingConfigError`."""

        def die(msg: str) -> None:
            raise ServingConfigError(msg)

        if self.slots_per_layer < 1:
            die("slots_per_layer must be >= 1")
        b = self.batching
        if b.max_lanes < 1 or b.max_prefill_batch < 1:
            die("max_lanes and max_prefill_batch must be >= 1")
        if not b.buckets or list(b.buckets) != sorted(set(b.buckets)):
            die(f"buckets must be a strictly increasing ladder, got {b.buckets}")
        tier = self.quant.tier
        if tier is not None and tier.enabled:
            if not self.quant.quantized_slots:
                die("the int4 warm tier extends the quantized slot pool: "
                    "also set quantized_slots (hot tier stays int8)")
            sharded = self.parallel.sharded
            if sharded is not None and sharded.replicate_hot:
                die("int4 tiering and replicate_hot are mutually exclusive "
                    "(replicas assume a single uniform slot pool)")
            if not (0.0 < tier.tier_split <= 1.0):
                die(f"tier_split {tier.tier_split} must be in (0, 1]: the "
                    "fraction of the slot byte budget held as int8 hot slots")
            if tier.group_size <= 0:
                die("quant group_size must be >= 1 (int4 scale group size "
                    "along the contraction axis)")
        sh = self.parallel.sharded
        if sh is not None:
            if sh.ep_shards < 1 or sh.replicate_hot < 0:
                die("ep_shards must be >= 1 and replicate_hot >= 0")
            if sh.replicate_hot and sh.ep_shards <= 1:
                die("replicate_hot needs ep_shards > 1 (replication acts "
                    "across expert-parallel shards)")
        if self.parallel.rebalance_interval < 0:
            die("rebalance_interval must be >= 0")
        if (
            self.parallel.rebalance_interval
            and (sh is None or sh.ep_shards <= 1)
        ):
            die("rebalance_interval needs ep_shards > 1 (placement acts "
                "across expert-parallel shards)")
        p = self.paged
        if p is not None and p.enabled:
            if p.page_size <= 0 or p.kv_pages < 0 or p.prefill_chunk < 0:
                die("kv_pages/prefill_chunk must be >= 0 and page_size >= 1")
            resident = p.kv_pages * p.page_size
            if p.max_seq and p.max_seq < resident:
                die(f"max_seq {p.max_seq} is below the resident pool "
                    f"({p.kv_pages} x {p.page_size} = {resident}); drop "
                    "max_seq or shrink the pool")
            if b.buckets[-1] > p.seq_len:
                die(f"largest prefill bucket ({b.buckets[-1]}) exceeds the "
                    f"addressable range {p.seq_len}")
            need = -(-b.buckets[-1] // p.page_size)
            if p.kv_pages < need:
                die(f"kv_pages {p.kv_pages} cannot seed one full prefill "
                    f"bucket ({b.buckets[-1]} tokens = {need} pages of "
                    f"{p.page_size}); raise kv_pages to >= {need}")
            spec_k = self.spec.k
            if self.spec.mode == "draft" and spec_k and spec_k > resident:
                die(f"spec k {spec_k} exceeds the resident K/V pool "
                    f"({resident} positions); a verify block must fit in "
                    "device pages")
            if max_prompt_len is not None and max_new_tokens is not None:
                if max_prompt_len + max_new_tokens > p.seq_len:
                    die(f"prompt {max_prompt_len} + new tokens "
                        f"{max_new_tokens} exceeds the addressable range "
                        f"{p.seq_len}: such requests would be rejected at "
                        "admission — raise max_seq (spilled pages live on "
                        "host, so it may exceed the resident pool)")
        if max_prompt_len is not None and max_prompt_len > b.buckets[-1]:
            if p is None or not p.enabled or p.prefill_chunk <= 0:
                die(f"prompt length {max_prompt_len} exceeds the largest "
                    f"prefill bucket ({b.buckets[-1]}): such prompts would "
                    "be rejected at admission — enable chunked prefill "
                    "(paged K/V + prefill_chunk) or raise the buckets")
        pf = self.prefetch
        if pf.fence_timeout_s is not None and pf.fence_timeout_s < 0:
            die("fence_timeout_s must be >= 0")
        if self.faults.plan is not None:
            for spec in self.faults.plan.specs:
                if spec.site not in KNOWN_SITES:
                    die(f"fault plan: site {spec.site!r} is not instrumented "
                        f"(known sites: {', '.join(KNOWN_SITES)})")
        if self.faults.shed is not None and slo_s is None:
            # only checkable when the launcher tells us about the workload;
            # a shed gate with neither per-request SLOs nor a default would
            # never fire — that is a misconfiguration, not a feature
            if not any(t.default_slo_s is not None for t in self.tenants):
                die("overload shedding needs a deadline to protect: pass an "
                    "SLO (per request, per tenant default_slo_s, or the "
                    "launcher's --slo)")
        if self.wfq_quantum <= 0:
            die("wfq_quantum must be > 0")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            die(f"duplicate tenant names: {names}")
        for t in self.tenants:
            t.validate()
        return self

    # ------------------------------------------------------------------
    # legacy kwargs shim
    # ------------------------------------------------------------------
    @classmethod
    def from_kwargs(cls, **kwargs: Any) -> "ServingConfig":
        """Build a ServingConfig from `RequestServer`'s legacy flat kwargs.

        DEPRECATED surface: new call sites should construct a ServingConfig;
        the flat names are kept (via KWARG_PATHS) so nine PRs of tests and
        benchmarks keep working, and the equivalence differential
        (tests/test_serving_config.py) pins the two paths byte-identical.
        Unknown names raise TypeError exactly like the old signature did."""
        self = cls()
        for name, val in kwargs.items():
            path = KWARG_PATHS.get(name)
            if path is None:
                raise TypeError(
                    f"RequestServer got an unexpected keyword argument "
                    f"{name!r} (see ServingConfig for the config surface)"
                )
            obj: Any = self
            *parents, leaf = path.split(".")
            for p in parents:
                obj = getattr(obj, p)
            if name == "buckets":
                val = tuple(sorted(val))
            if name == "tenants":
                val = tuple(val)
            setattr(obj, leaf, val)
        return self

    # ------------------------------------------------------------------
    # flag surface
    # ------------------------------------------------------------------
    @staticmethod
    def add_args(parser: argparse.ArgumentParser) -> None:
        add_serving_args(parser)

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ServingConfig":
        """One builder from the parsed CLI namespace — replaces the old
        hand-written flag->kwarg plumbing in `launch/serve.py`. Validates
        (with workload hints when the namespace carries them) and raises
        `ServingConfigError` on incoherent flag combinations."""
        tier = None
        if args.int4_slots:
            if not (0.0 < args.tier_split <= 1.0):
                raise ServingConfigError(
                    f"--tier-split {args.tier_split} must be in (0, 1]: the "
                    "fraction of the slot byte budget held as int8 hot slots"
                )
            if args.quant_group <= 0:
                raise ServingConfigError(
                    "--quant-group must be >= 1 (int4 scale group size "
                    "along the contraction axis)"
                )
            tier = TierConfig(
                int4_slots=True, tier_split=args.tier_split,
                group_size=args.quant_group,
            )
        sharded = None
        if args.ep_shards > 1 or args.replicate_hot:
            sharded = ShardedStoreConfig(
                ep_shards=args.ep_shards, replicate_hot=args.replicate_hot,
            )
        paged = None
        if args.kv_pages or args.max_seq or args.prefill_chunk:
            if args.kv_pages < 0 or args.page_size <= 0 or args.prefill_chunk < 0:
                raise ServingConfigError(
                    "--kv-pages/--prefill-chunk must be >= 0 and "
                    "--page-size >= 1"
                )
            if args.prefill_chunk and not args.kv_pages:
                raise ServingConfigError(
                    "--prefill-chunk needs the paged K/V cache: also pass "
                    "--kv-pages"
                )
            if args.max_seq and not args.kv_pages:
                raise ServingConfigError(
                    "--max-seq needs the paged K/V cache: also pass "
                    "--kv-pages"
                )
            paged = PagedKVConfig(
                page_size=args.page_size, kv_pages=args.kv_pages,
                prefill_chunk=args.prefill_chunk, max_seq=args.max_seq,
            )
        plan = None
        if args.fault_plan:
            try:
                plan = FaultPlan.parse(args.fault_plan, seed=args.fault_seed)
            except ValueError as e:
                raise ServingConfigError(f"--fault-plan: {e}") from None
        shed = None
        if args.shed_margin:
            if args.shed_margin < 0:
                raise ServingConfigError("--shed-margin must be >= 0")
            from repro.serving.scheduler import AdmissionController

            shed = AdmissionController(margin=args.shed_margin)
        if args.fence_timeout < 0:
            raise ServingConfigError("--fence-timeout must be >= 0")
        tenants: Tuple[TenantConfig, ...] = ()
        if args.tenants:
            tenants = parse_tenants(args.tenants)
        seq = getattr(args, "seq", None)
        buckets = DEFAULT_BUCKETS
        if seq is not None:
            buckets = bucket_ladder(serve_bucket_limit(
                seq, args.kv_pages, args.page_size, args.prefill_chunk
            ))
        self = cls(
            slots_per_layer=args.slots,
            eviction=args.eviction,
            batching=BatchingConfig(
                max_lanes=args.lanes,
                max_prefill_batch=args.prefill_batch,
                buckets=buckets,
                drop_expired=args.drop_expired,
            ),
            prefetch=PrefetchServeConfig(
                depth=args.prefetch_depth,
                staging_buffers=args.staging_buffers,
                fence_timeout_s=args.fence_timeout or None,
            ),
            quant=QuantServeConfig(
                host_quant=args.host_quant,
                quantized_slots=args.quantized_slots,
                scale_granularity=args.scale_granularity,
                tier=tier,
            ),
            spec=SpecServeConfig(mode=args.spec_mode, k=args.spec_k),
            parallel=ParallelServeConfig(
                sharded=sharded,
                rebalance_interval=args.rebalance_interval,
            ),
            paged=paged,
            faults=FaultToleranceConfig(plan=plan, shed=shed),
            tenants=tenants,
            wfq_quantum=args.wfq_quantum,
        )
        return self.validate(
            max_prompt_len=seq,
            max_new_tokens=getattr(args, "new_tokens", None),
            slo_s=getattr(args, "slo", None),
        )


# RequestServer's historical flat keyword surface -> dotted config path
# (the back-compat shim's single lookup table; tests assert it covers the
# pre-redesign signature exactly).
KWARG_PATHS: Dict[str, str] = {
    "slots_per_layer": "slots_per_layer",
    "serve_top_k": "serve_top_k",
    "eviction": "eviction",
    "keep_prefill_logits": "keep_prefill_logits",
    "keep_decode_logits": "keep_decode_logits",
    "max_lanes": "batching.max_lanes",
    "max_prefill_batch": "batching.max_prefill_batch",
    "buckets": "batching.buckets",
    "cache_len": "batching.cache_len",
    "drop_expired": "batching.drop_expired",
    "prefetch_depth": "prefetch.depth",
    "staging_buffers": "prefetch.staging_buffers",
    "fence_timeout_s": "prefetch.fence_timeout_s",
    "watchdog_interval_s": "prefetch.watchdog_interval_s",
    "watchdog_max_job_age_s": "prefetch.watchdog_max_job_age_s",
    "host_quant": "quant.host_quant",
    "quantized_slots": "quant.quantized_slots",
    "scale_granularity": "quant.scale_granularity",
    "tier": "quant.tier",
    "spec_mode": "spec.mode",
    "spec_k": "spec.k",
    "sharded": "parallel.sharded",
    "rebalance_interval": "parallel.rebalance_interval",
    "paged": "paged",
    "faults": "faults.plan",
    "shed": "faults.shed",
    "tenants": "tenants",
    "wfq_quantum": "wfq_quantum",
}


# ----------------------------------------------------------------------
# bucket ladder (shared by from_args and the launcher's messages)
# ----------------------------------------------------------------------
def serve_bucket_limit(
    seq: int, kv_pages: int = 0, page_size: int = 16, prefill_chunk: int = 0,
) -> int:
    """Largest prefill bucket a launcher should build for prompts up to
    `seq`. Paged serving caps buckets at what the resident pool can seed in
    one shot (and, with chunked prefill on, at the default 128 — longer
    prompts stream chunk by chunk)."""
    limit = seq
    if kv_pages:
        limit = min(limit, kv_pages * page_size)
        if prefill_chunk:
            limit = min(limit, 128)
    bucket = 8
    while bucket < limit:
        bucket *= 2
    return bucket


def bucket_ladder(limit: int) -> Tuple[int, ...]:
    """The 8, 16, ... power-of-two ladder up to (and including) `limit`."""
    buckets = [8]
    while buckets[-1] < limit:
        buckets.append(2 * buckets[-1])
    return tuple(buckets)


# ----------------------------------------------------------------------
# CLI flag table — the argparse surface is REGISTERED from this table and
# READ BACK by from_args, so the flag set and the config cannot drift.
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class FlagSpec:
    flag: str                 # "--kv-pages"
    path: Optional[str]       # dotted ServingConfig path for 1:1 flags;
    #                           None = composite (consumed by from_args
    #                           into a sub-config object)
    kwargs: Dict[str, Any] = field(default_factory=dict)  # add_argument(**)

    @property
    def dest(self) -> str:
        return self.flag.lstrip("-").replace("-", "_")


SERVE_FLAGS: Tuple[FlagSpec, ...] = (
    FlagSpec("--slots", "slots_per_layer", dict(
        type=int, default=2,
        help="device expert slots per MoE layer (the memory budget)")),
    FlagSpec("--eviction", "eviction", dict(
        default="fifo", choices=["fifo", "lru", "alpha"],
        help="slot replacement: fifo | lru | alpha (α-mass)")),
    FlagSpec("--prefetch-depth", "prefetch.depth", dict(
        type=int, default=0,
        help="async prefetch lookahead (0 = synchronous uploads)")),
    FlagSpec("--staging-buffers", "prefetch.staging_buffers", dict(
        type=int, default=2,
        help="host staging slabs for the transfer thread")),
    FlagSpec("--host-quant", "quant.host_quant", dict(
        default="none", choices=["none", "int8"],
        help="host expert tier format (int8 halves H2D bytes; dequantised "
             "at slot write unless --quantized-slots)")),
    FlagSpec("--quantized-slots", "quant.quantized_slots", dict(
        action="store_true",
        help="int8 device-resident slots + fused-dequant expert FFN (2-4x "
             "resident experts per slot byte; implies --host-quant int8)")),
    FlagSpec("--scale-granularity", "quant.scale_granularity", dict(
        default="channel", choices=["channel", "tensor"],
        help="int8 scale granularity per expert tensor")),
    FlagSpec("--int4-slots", None, dict(
        action="store_true",
        help="hierarchical residency tiers: keep the hot tier int8 and add "
             "a warm tier of nibble-packed int4 slots with per-group scales "
             "(~2x experts per byte); requires --quantized-slots")),
    FlagSpec("--tier-split", None, dict(
        type=float, default=0.5,
        help="fraction of the slot byte budget held as int8 hot slots; the "
             "remainder becomes int4 warm slots (1.0 = all-hot, degenerate "
             "to --quantized-slots)")),
    FlagSpec("--quant-group", None, dict(
        type=int, default=64,
        help="int4 scale group size along the contraction axis (smaller = "
             "tighter error, more scale-plane bytes)")),
    FlagSpec("--spec-mode", "spec.mode", dict(
        default="off", choices=["off", "draft"],
        help="speculative decode: 'draft' unrolls the hash predictor's "
             "tied-embedding next-token head and verifies k tokens per "
             "step (request-server mode)")),
    FlagSpec("--spec-k", "spec.k", dict(
        type=int, default=4,
        help="draft tokens proposed per verify step; the union of all k "
             "positions' predicted experts ships as one superset prefetch "
             "ticket")),
    FlagSpec("--ep-shards", None, dict(
        type=int, default=1,
        help="expert-parallel serving shards: partition the slot pools "
             "(and prefetch transfer queues) over a 1-D 'model' mesh of "
             "this many devices; the expert FFN runs inside shard_map "
             "(fused dequant when --quantized-slots). 1 = single-device")),
    FlagSpec("--replicate-hot", None, dict(
        type=int, default=0,
        help="extra copies an α-mass-hot expert may hold on other shards "
             "(free slots only; translation round-robins tokens over the "
             "copies). Requires --ep-shards > 1; 0 = fixed placement")),
    FlagSpec("--rebalance-interval", "parallel.rebalance_interval", dict(
        type=float, default=0.0,
        help="seconds between online home-shard re-placements driven by "
             "the decayed α-mass EMA (request-server mode; requires "
             "--ep-shards > 1; 0 = off)")),
    FlagSpec("--kv-pages", None, dict(
        type=int, default=0,
        help="paged K/V cache: device page budget shared by all lanes "
             "(0 = ring cache). Spilled pages live on host and page back "
             "in over the prefetch queues")),
    FlagSpec("--page-size", None, dict(
        type=int, default=16,
        help="K/V page size in token positions")),
    FlagSpec("--prefill-chunk", None, dict(
        type=int, default=0,
        help="chunked prefill: stream prompts longer than the largest "
             "bucket through the paged cache in chunks of this many "
             "tokens, interleaved with decode ticks (0 = off; requires "
             "--kv-pages)")),
    FlagSpec("--max-seq", None, dict(
        type=int, default=0,
        help="addressable sequence length (page-table width); 0 = "
             "kv-pages * page-size (everything resident). May exceed the "
             "resident pool: the excess spills")),
    FlagSpec("--lanes", "batching.max_lanes", dict(
        type=int, default=4,
        help="(server) continuous-batching decode lanes")),
    FlagSpec("--prefill-batch", "batching.max_prefill_batch", dict(
        type=int, default=4,
        help="(server) max requests per bucketed prefill batch")),
    FlagSpec("--drop-expired", "batching.drop_expired", dict(
        action="store_true",
        help="(server) reject requests already past their SLO")),
    FlagSpec("--fault-plan", None, dict(
        default="",
        help="(server) seeded chaos schedule, e.g. "
             "'upload:fail,p=0.2;thread:crash@2' — grammar "
             "site:kind[=delay_s][@nth[xtimes]][,p=prob], ;-separated "
             "(see core/faults.py)")),
    FlagSpec("--fault-seed", None, dict(
        type=int, default=0,
        help="(server) RNG seed for probabilistic (p=) fault specs")),
    FlagSpec("--fence-timeout", None, dict(
        type=float, default=0.0,
        help="(server) bound (s) a serve tick waits on prefetch fences "
             "before falling back to a synchronous prepare (0 = wait "
             "indefinitely)")),
    FlagSpec("--shed-margin", None, dict(
        type=float, default=0.0,
        help="(server) overload shedding: reject at admission when "
             "estimated queue wait exceeds this fraction of a request's "
             "deadline slack (0 = no shedding; requires a deadline: --slo "
             "or a tenant default)")),
    FlagSpec("--tenants", None, dict(
        default="",
        help="(server) multi-tenant front door: comma-separated "
             "name[:weight=W][:rate=R][:pin=F][:slo=S][:class=C] specs, "
             "e.g. 'paid:weight=4:pin=0.5,free:rate=200'. Empty = "
             "single-tenant (byte-identical to the pre-tenant path)")),
    FlagSpec("--wfq-quantum", "wfq_quantum", dict(
        type=float, default=64.0,
        help="(server) deficit-round-robin quantum: prefill+decode tokens "
             "granted per scheduling round per unit tenant weight")),
)


def add_serving_args(parser: argparse.ArgumentParser) -> None:
    """Register every serving flag from SERVE_FLAGS (the launcher adds its
    workload/launcher-only flags — --arch, --engine, --requests, … —
    itself)."""
    for spec in SERVE_FLAGS:
        parser.add_argument(spec.flag, **spec.kwargs)


def resolve_path(cfg: ServingConfig, path: str) -> Any:
    """Read a dotted ServingConfig path ("batching.max_lanes")."""
    obj: Any = cfg
    for p in path.split("."):
        obj = getattr(obj, p)
    return obj
