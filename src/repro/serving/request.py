"""Request lifecycle for the serving subsystem.

A `Request` carries one user prompt through

    QUEUED -> PREFILL -> DECODE -> DONE   (or REJECTED)

with per-request timestamps at every transition, an optional latency SLO
(deadline = arrival + slo), the hash-ahead table built at admission, and
the generated-token stream. The batch engines operate on anonymous token
matrices; everything SLA-aware in the scheduler hangs off this object.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.core.hash_table import HashTable


class RequestState(enum.Enum):
    QUEUED = "queued"      # arrived, waiting for a prefill batch
    PREFILL = "prefill"    # in a running prefill forward
    DECODE = "decode"      # occupying a decode lane
    DONE = "done"          # finished (max_new_tokens generated)
    REJECTED = "rejected"  # dropped (deadline already blown before prefill)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [P] int32 token ids
    max_new_tokens: int
    arrival_s: float = 0.0             # offset from stream start
    slo_s: Optional[float] = None      # latency SLO; deadline = arrival + slo
    tenant: str = "default"            # multi-tenant front door: owner id,
    #                                    stamped at admission (WFQ queue,
    #                                    token budget, telemetry partition)

    state: RequestState = RequestState.QUEUED
    generated: List[int] = field(default_factory=list)
    on_token: Optional[Callable[[int], None]] = None  # token-stream callback

    # hash-ahead output, built at admission (before any model compute)
    table: Optional[HashTable] = None

    lane: int = -1                     # decode lane while state == DECODE
    prefill_logits: Optional[np.ndarray] = None  # kept only when asked
    decode_logits: Optional[List[np.ndarray]] = None  # per-step, when asked

    # why admission refused this request (state == REJECTED)
    reject_reason: Optional[str] = None
    # chunked-prefill progress: prompt tokens consumed so far (long prompts
    # served through the paged K/V path advance this chunk by chunk)
    chunk_pos: int = 0

    # lifecycle timestamps (server-clock seconds; -1 = not reached)
    t_queued: float = -1.0
    t_prefill: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def deadline_s(self) -> float:
        return self.arrival_s + self.slo_s if self.slo_s is not None else float("inf")

    def slack(self, now: float) -> float:
        return self.deadline_s - now

    @property
    def ttft_s(self) -> float:
        """Time to first token (arrival -> first generated token)."""
        return self.t_first_token - self.arrival_s if self.t_first_token >= 0 else -1.0

    @property
    def latency_s(self) -> float:
        """End-to-end latency (arrival -> last token)."""
        return self.t_done - self.arrival_s if self.t_done >= 0 else -1.0

    def emit(self, token: int) -> None:
        self.generated.append(int(token))
        if self.on_token is not None:
            self.on_token(int(token))

    def finished(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


def poisson_requests(
    rng: np.random.Generator,
    n: int,
    rate_rps: float,
    vocab_size: int,
    prompt_len_range=(8, 32),
    max_new_range=(4, 16),
    slo_s: Optional[float] = None,
    tenant: str = "default",
    rid_base: int = 0,
) -> List[Request]:
    """Synthetic open-loop arrival stream: exponential inter-arrival gaps
    (Poisson process at `rate_rps`), uniform prompt lengths and decode
    budgets. The canonical driver for `RequestServer.run` and the serving
    benchmark."""
    reqs: List[Request] = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(1.0 / rate_rps))
        p = int(rng.integers(prompt_len_range[0], prompt_len_range[1] + 1))
        m = int(rng.integers(max_new_range[0], max_new_range[1] + 1))
        prompt = rng.integers(0, vocab_size, (p,)).astype(np.int32)
        reqs.append(
            Request(
                rid=rid_base + i, prompt=prompt, max_new_tokens=m,
                arrival_s=t, slo_s=slo_s, tenant=tenant,
            )
        )
    return reqs
