"""Admission queue + continuous batcher.

Two scheduling decisions live here, both SLA-aware:

* **Prefill batch composition** — queued requests are grouped by length
  bucket (padding waste stays bounded by the bucket granularity) and
  ordered earliest-deadline-first; within the same urgency band, requests
  whose hash-ahead tables overlap the resident expert cache the most go
  first (the cache-affinity score generalized out of the batch engine's
  lookahead scheduling onto `ExpertStore.cache_affinity`; with the async
  pipeline the server passes the `PrefetchPipeline` instead, whose
  affinity also credits uploads still in flight — work the cache already
  paid for ranks as if it were resident).
* **Decode lane occupancy** — the `LaneTable` tracks which request holds
  which decode-batch row; requests join a free lane as soon as prefill
  completes and leave the moment they finish, so the running decode batch
  continuously re-fills instead of draining to the slowest member.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.offload import ExpertStore
from repro.serving.request import Request, RequestState

DEFAULT_BUCKETS = (8, 16, 32, 64, 128)

# requests within the same slack band are interchangeable deadline-wise;
# cache affinity orders inside a band
SLACK_BAND_S = 0.25


def bucket_len(length: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket that holds `length` (prompts are padded up to it)."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket {buckets[-1]}")


class LaneTable:
    """Decode-batch lane bookkeeping: which request occupies which row."""

    def __init__(self, n_lanes: int):
        self.n_lanes = n_lanes
        self.requests: List[Optional[Request]] = [None] * n_lanes
        self._free: List[int] = list(range(n_lanes - 1, -1, -1))

    def free_count(self) -> int:
        return len(self._free)

    def active(self) -> List[int]:
        return [i for i, r in enumerate(self.requests) if r is not None]

    def assign(self, req: Request) -> int:
        lane = self._free.pop()
        self.requests[lane] = req
        req.lane = lane
        return lane

    def release(self, lane: int) -> Request:
        req = self.requests[lane]
        assert req is not None, f"lane {lane} is already free"
        self.requests[lane] = None
        self._free.append(lane)
        req.lane = -1
        return req


class Scheduler:
    """Admission queue feeding the continuous batcher."""

    def __init__(
        self,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        use_affinity: bool = True,
        slack_band_s: float = SLACK_BAND_S,
    ):
        self.buckets = tuple(sorted(buckets))
        self.use_affinity = use_affinity
        self.slack_band_s = slack_band_s
        self._queue: List[Request] = []
        # rid -> (affinity epoch, score): cache_affinity is an O(L·E) scan
        # under the store lock, so a deep queue re-scoring every request
        # every tick would serialize the serve loop against the prefetch
        # thread — scores are reused until the store's residency epoch
        # moves (see ExpertStore.affinity_epoch)
        self._aff_cache: Dict[int, Tuple[object, float]] = {}

    # ------------------------------------------------------------------
    def enqueue(self, req: Request) -> None:
        req.state = RequestState.QUEUED
        self._queue.append(req)

    def pending(self) -> int:
        return len(self._queue)

    def pop_expired(self, now: float) -> List[Request]:
        """Remove and return queued requests whose deadline already passed —
        admission control: serving them would burn capacity on guaranteed
        SLO misses."""
        expired = [r for r in self._queue if r.slack(now) < 0]
        for r in expired:
            self._queue.remove(r)
            r.state = RequestState.REJECTED
            self._aff_cache.pop(r.rid, None)
        return expired

    # ------------------------------------------------------------------
    def _order(self, reqs: List[Request], now: float, store):
        """EDF first; inside a slack band, highest cache affinity first.
        `store` is any affinity provider with `cache_affinity(table)` —
        an ExpertStore (residency only) or a PrefetchPipeline (residency
        plus in-flight uploads). Affinity is memoized per request against
        the provider's `affinity_epoch`: within one tick (and across ticks
        while residency is unchanged) each table is scanned at most once."""
        epoch = getattr(store, "affinity_epoch", None)

        def affinity(r: Request) -> float:
            hit = self._aff_cache.get(r.rid)
            if hit is not None and epoch is not None and hit[0] == epoch:
                return hit[1]
            aff = store.cache_affinity(r.table)
            self._aff_cache[r.rid] = (epoch, aff)
            return aff

        def key(r: Request):
            band = (
                r.slack(now) // self.slack_band_s
                if r.slo_s is not None
                else float("inf")
            )
            aff = 0.0
            if self.use_affinity and store is not None and r.table is not None:
                aff = affinity(r)
            return (band, -aff, r.arrival_s, r.rid)

        return sorted(reqs, key=key)

    def chunk_urgent(
        self, req: Request, now: float, remaining_chunks: int, chunk_s: float,
    ) -> bool:
        """Deadline accounting for chunked prefill: run the next chunk
        BEFORE this tick's decode when the request's remaining slack no
        longer covers the remaining chunks at the observed per-chunk rate
        (plus one slack band of margin). SLO-less requests are never
        urgent — their chunks always yield to decode progress."""
        if req.slo_s is None:
            return False
        need = remaining_chunks * max(chunk_s, 1e-4) + self.slack_band_s
        return req.slack(now) < need

    def next_prefill_batch(
        self,
        now: float,
        max_batch: int,
        store: Optional[ExpertStore] = None,  # or PrefetchPipeline (duck-typed)
    ) -> Tuple[List[Request], int]:
        """Compose the next prefill batch: the most urgent request anchors
        it, its length bucket fixes the padded shape, and remaining slots
        fill from the same bucket in deadline/affinity order. Returns
        (requests, bucket) — ([], 0) when nothing is ready."""
        ready = [r for r in self._queue if r.table is not None]
        if not ready or max_batch <= 0:
            return [], 0
        ordered = self._order(ready, now, store)
        anchor = ordered[0]
        bucket = bucket_len(anchor.prompt_len, self.buckets)
        batch = [
            r for r in ordered if bucket_len(r.prompt_len, self.buckets) == bucket
        ][:max_batch]
        for r in batch:
            self._queue.remove(r)
            r.state = RequestState.PREFILL
            self._aff_cache.pop(r.rid, None)
        return batch, bucket


class AdmissionController:
    """Overload shedding at the admission gate.

    The estimate is classic back-of-queue wait: `queue depth × EMA of
    observed per-request service time`. A request is shed (rejected with
    reason `overloaded`) when that estimate exceeds `margin` of its
    remaining slack — i.e. when, at the observed service rate, the request
    would already have missed its deadline before reaching a lane. Shedding
    at admission is the whole point: reject BEFORE burning prefill/decode
    capacity on a guaranteed SLO miss, not after (`pop_expired` is the
    too-late backstop).

    Hysteresis: crossing the threshold latches the gate; it stays latched
    until the estimate falls below `exit_frac` of a request's threshold, so
    the admit/shed decision cannot chatter around the boundary while the
    queue hovers at critical depth.

    Degraded transfer shards (the prefetch pipeline's sync-fallback mode —
    see core/offload.py) shrink the threshold by the degraded fraction:
    when uploads have lost their overlap, true service times are about to
    rise, so faults translate into earlier rejections instead of letting
    admitted requests pile into SLO collapse.

    Requests without an SLO fall back to `default_slo_s` slack; with
    neither, they are never shed (there is no deadline to protect)."""

    def __init__(
        self,
        margin: float = 0.8,          # shed when est. wait > margin × slack
        exit_frac: float = 0.6,       # un-latch below exit_frac × threshold
        ema_decay: float = 0.8,       # service-time EMA (new obs weight 1-d)
        init_service_s: float = 0.0,  # prior before the first completion
        default_slo_s: Optional[float] = None,
        degraded_shrink: float = 0.5, # threshold ×= (1 - shrink × degraded)
    ):
        self.margin = margin
        self.exit_frac = exit_frac
        self.ema_decay = ema_decay
        self.service_s = init_service_s
        self.default_slo_s = default_slo_s
        self.degraded_shrink = degraded_shrink
        self.shedding = False         # the hysteresis latch

    def observe(self, service_s: float) -> None:
        """Feed one completed request's service time (prefill -> done)."""
        if self.service_s <= 0.0:
            self.service_s = service_s
        else:
            self.service_s = (
                self.ema_decay * self.service_s
                + (1.0 - self.ema_decay) * service_s
            )

    def est_wait_s(self, depth: int) -> float:
        return depth * self.service_s

    def should_shed(
        self, depth: int, slack_s: Optional[float], degraded_frac: float = 0.0
    ) -> bool:
        """Decide one admission. `slack_s` is the request's remaining
        deadline slack (None = no SLO). Updates the hysteresis latch."""
        if slack_s is None:
            slack_s = self.default_slo_s
        if slack_s is None or self.service_s <= 0.0:
            return False
        thr = self.margin * max(slack_s, 0.0)
        thr *= max(0.0, 1.0 - self.degraded_shrink * degraded_frac)
        est = self.est_wait_s(depth)
        shed = est > (self.exit_frac * thr if self.shedding else thr)
        self.shedding = shed
        return shed

    def clone(self) -> "AdmissionController":
        """A fresh controller with the same policy hyperparameters but its
        own EMA state and hysteresis latch (the per-tenant split)."""
        return AdmissionController(
            margin=self.margin,
            exit_frac=self.exit_frac,
            ema_decay=self.ema_decay,
            init_service_s=0.0,
            default_slo_s=self.default_slo_s,
            degraded_shrink=self.degraded_shrink,
        )


# ----------------------------------------------------------------------
# multi-tenant front door: weighted fair queueing above EDF + affinity
# ----------------------------------------------------------------------
class TenantState:
    """Runtime scheduling state for one tenant: the DRR deficit counter and
    the generated-token rate bucket."""

    def __init__(self, cfg: "TenantConfig"):  # noqa: F821 (serving.config)
        self.cfg = cfg
        self.deficit = 0.0
        # token bucket for the generated-token rate budget; starts full so
        # a tenant's first burst is not throttled by an empty ledger
        self.bucket_cap = cfg.burst if cfg.burst > 0 else cfg.token_rate
        self.tokens = self.bucket_cap
        self.last_refill: Optional[float] = None

    def refill(self, now: float) -> None:
        if self.cfg.token_rate <= 0:
            return
        if self.last_refill is None:
            self.last_refill = now
            return
        dt = max(0.0, now - self.last_refill)
        self.tokens = min(self.bucket_cap, self.tokens + dt * self.cfg.token_rate)
        self.last_refill = now

    def throttled(self, now: float) -> bool:
        """True when the tenant's generated-token budget is exhausted —
        its queued requests DEFER (never drop) until the bucket refills."""
        if self.cfg.token_rate <= 0:
            return False
        self.refill(now)
        return self.tokens <= 0.0

    def debit(self, n_tokens: int, now: float) -> None:
        """Charge generated tokens against the rate budget. The balance may
        go negative (a request in flight keeps decoding); the debt defers
        the tenant's NEXT prefill until refill pays it back."""
        if self.cfg.token_rate <= 0:
            return
        self.refill(now)
        self.tokens -= float(n_tokens)


class WFQScheduler(Scheduler):
    """Deficit-round-robin weighted fair queueing over per-tenant queues,
    sitting ABOVE the existing EDF + cache-affinity order.

    Two-level decision: DRR picks WHICH tenant the next prefill batch is
    drawn from (long-run service proportional to `TenantConfig.weight`,
    independent of offered load); within the chosen tenant the inherited
    `_order` ranks requests exactly as the single-tenant scheduler does
    (deadline bands, then cache affinity). A batch is therefore always
    single-tenant — bucket padding and attribution stay simple.

    Starvation-freedom: every scheduling round adds `quantum x weight` to
    each active tenant's deficit counter, so any head request's finite cost
    (padded prefill tokens + decode budget) is eventually covered no matter
    how much traffic heavier tenants offer; the round-robin pointer rotates
    so ties break fairly. A tenant's deficit resets when its queue drains
    (the DRR rule that prevents banking unused service into a future burst).

    Token-rate budgets: tenants whose generated-token bucket is empty are
    skipped (their requests defer, never drop) until `debit`-ed tokens are
    paid back by refill — the server debits per generated token."""

    def __init__(
        self,
        tenants: Sequence["TenantConfig"],  # noqa: F821
        quantum: float = 64.0,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        use_affinity: bool = True,
        slack_band_s: float = SLACK_BAND_S,
    ):
        super().__init__(
            buckets=buckets, use_affinity=use_affinity, slack_band_s=slack_band_s
        )
        self.quantum = quantum
        self.tenants: Dict[str, TenantState] = {
            t.name: TenantState(t) for t in tenants
        }
        self._queues: Dict[str, List[Request]] = {
            t.name: [] for t in tenants
        }
        self._rr: List[str] = [t.name for t in tenants]
        self._rr_pos = 0

    # ------------------------------------------------------------------
    def _ensure(self, name: str) -> TenantState:
        st = self.tenants.get(name)
        if st is None:
            # unknown tenants get a default contract (weight 1, unlimited)
            # rather than a crash at admission; the registry is advisory
            from repro.serving.config import TenantConfig

            st = TenantState(TenantConfig(name=name))
            self.tenants[name] = st
            self._queues[name] = []
            self._rr.append(name)
        return st

    def enqueue(self, req: Request) -> None:
        self._ensure(req.tenant)
        req.state = RequestState.QUEUED
        self._queues[req.tenant].append(req)

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def pending_tenant(self, name: str) -> int:
        return len(self._queues.get(name, ()))

    def pop_expired(self, now: float) -> List[Request]:
        expired: List[Request] = []
        for q in self._queues.values():
            dead = [r for r in q if r.slack(now) < 0]
            for r in dead:
                q.remove(r)
                r.state = RequestState.REJECTED
                self._aff_cache.pop(r.rid, None)
            expired.extend(dead)
        return expired

    # ------------------------------------------------------------------
    @staticmethod
    def _cost(req: Request, bucket: int) -> float:
        """DRR service cost of one request: padded prefill tokens plus the
        decode budget it is entitled to generate."""
        return float(bucket + req.max_new_tokens)

    def debit(self, tenant: str, n_tokens: int, now: float) -> None:
        """Charge generated tokens to the tenant's rate bucket (the server
        calls this once per decode/verify tick with that tick's count)."""
        self._ensure(tenant).debit(n_tokens, now)

    def next_prefill_batch(
        self,
        now: float,
        max_batch: int,
        store: Optional[ExpertStore] = None,
    ) -> Tuple[List[Request], int]:
        if max_batch <= 0:
            return [], 0
        ready: Dict[str, List[Request]] = {}
        for name, q in self._queues.items():
            rs = [r for r in q if r.table is not None]
            if rs:
                ready[name] = rs
        if not ready:
            return [], 0
        # rate-throttled tenants defer; drop their deficit growth too so an
        # exhausted budget cannot bank priority for the moment it refills
        active = [
            n for n in self._rr
            if n in ready and not self.tenants[n].throttled(now)
        ]
        for name, st in self.tenants.items():
            if name not in ready:
                st.deficit = 0.0  # DRR: empty queue forfeits its deficit
        if not active:
            return [], 0
        # rotate so each call gives a different tenant first claim
        start = self._rr_pos % len(self._rr)
        order = [n for n in self._rr[start:] + self._rr[:start] if n in active]
        # per-tenant EDF+affinity heads, computed once
        heads: Dict[str, Tuple[List[Request], int, float]] = {}
        for name in order:
            ranked = self._order(ready[name], now, store)
            bucket = bucket_len(ranked[0].prompt_len, self.buckets)
            heads[name] = (ranked, bucket, self._cost(ranked[0], bucket))
        # each full round adds quantum x weight to every active tenant, so
        # the cheapest head is reachable within bounded rounds
        min_gain = min(
            self.quantum * self.tenants[n].cfg.weight for n in order
        )
        max_cost = max(h[2] for h in heads.values())
        for _ in range(int(max_cost / max(min_gain, 1e-9)) + 2):
            for name in order:
                st = self.tenants[name]
                st.deficit += self.quantum * st.cfg.weight
                ranked, bucket, cost = heads[name]
                if st.deficit < cost:
                    continue
                batch: List[Request] = []
                for r in ranked:
                    if len(batch) >= max_batch:
                        break
                    if bucket_len(r.prompt_len, self.buckets) != bucket:
                        continue
                    c = self._cost(r, bucket)
                    if batch and st.deficit < c:
                        break
                    st.deficit -= c
                    batch.append(r)
                q = self._queues[name]
                for r in batch:
                    q.remove(r)
                    r.state = RequestState.PREFILL
                    self._aff_cache.pop(r.rid, None)
                if not q:
                    st.deficit = 0.0
                self._rr_pos = (self._rr.index(name) + 1) % len(self._rr)
                return batch, bucket
        return [], 0  # unreachable: the round bound covers max_cost


class TenantAdmission:
    """The tenant-aware split of the overload-shedding gate: one
    `AdmissionController` clone per tenant, so queue-depth estimates and
    service-time EMAs are tracked per tenant and one tenant's overload
    sheds ONLY that tenant's requests. Tenants with a `default_slo_s` in
    their contract shed against that deadline even when individual
    requests carry none."""

    def __init__(
        self,
        template: AdmissionController,
        tenants: Sequence["TenantConfig"] = (),  # noqa: F821
    ):
        self._template = template
        self._by_tenant: Dict[str, AdmissionController] = {}
        for t in tenants:
            ctl = template.clone()
            if t.default_slo_s is not None:
                ctl.default_slo_s = t.default_slo_s
            self._by_tenant[t.name] = ctl

    def controller(self, tenant: str) -> AdmissionController:
        ctl = self._by_tenant.get(tenant)
        if ctl is None:
            ctl = self._template.clone()
            self._by_tenant[tenant] = ctl
        return ctl

    def observe(self, tenant: str, service_s: float) -> None:
        self.controller(tenant).observe(service_s)

    def should_shed(
        self,
        tenant: str,
        depth: int,
        slack_s: Optional[float],
        degraded_frac: float = 0.0,
    ) -> bool:
        """One admission decision against the TENANT's own queue depth and
        service-time history."""
        return self.controller(tenant).should_shed(depth, slack_s, degraded_frac)

    @property
    def shedding(self) -> bool:
        return any(c.shedding for c in self._by_tenant.values())
