"""Admission queue + continuous batcher.

Two scheduling decisions live here, both SLA-aware:

* **Prefill batch composition** — queued requests are grouped by length
  bucket (padding waste stays bounded by the bucket granularity) and
  ordered earliest-deadline-first; within the same urgency band, requests
  whose hash-ahead tables overlap the resident expert cache the most go
  first (the cache-affinity score generalized out of the batch engine's
  lookahead scheduling onto `ExpertStore.cache_affinity`; with the async
  pipeline the server passes the `PrefetchPipeline` instead, whose
  affinity also credits uploads still in flight — work the cache already
  paid for ranks as if it were resident).
* **Decode lane occupancy** — the `LaneTable` tracks which request holds
  which decode-batch row; requests join a free lane as soon as prefill
  completes and leave the moment they finish, so the running decode batch
  continuously re-fills instead of draining to the slowest member.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.offload import ExpertStore
from repro.serving.request import Request, RequestState

DEFAULT_BUCKETS = (8, 16, 32, 64, 128)

# requests within the same slack band are interchangeable deadline-wise;
# cache affinity orders inside a band
SLACK_BAND_S = 0.25


def bucket_len(length: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket that holds `length` (prompts are padded up to it)."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket {buckets[-1]}")


class LaneTable:
    """Decode-batch lane bookkeeping: which request occupies which row."""

    def __init__(self, n_lanes: int):
        self.n_lanes = n_lanes
        self.requests: List[Optional[Request]] = [None] * n_lanes
        self._free: List[int] = list(range(n_lanes - 1, -1, -1))

    def free_count(self) -> int:
        return len(self._free)

    def active(self) -> List[int]:
        return [i for i, r in enumerate(self.requests) if r is not None]

    def assign(self, req: Request) -> int:
        lane = self._free.pop()
        self.requests[lane] = req
        req.lane = lane
        return lane

    def release(self, lane: int) -> Request:
        req = self.requests[lane]
        assert req is not None, f"lane {lane} is already free"
        self.requests[lane] = None
        self._free.append(lane)
        req.lane = -1
        return req


class Scheduler:
    """Admission queue feeding the continuous batcher."""

    def __init__(
        self,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        use_affinity: bool = True,
        slack_band_s: float = SLACK_BAND_S,
    ):
        self.buckets = tuple(sorted(buckets))
        self.use_affinity = use_affinity
        self.slack_band_s = slack_band_s
        self._queue: List[Request] = []
        # rid -> (affinity epoch, score): cache_affinity is an O(L·E) scan
        # under the store lock, so a deep queue re-scoring every request
        # every tick would serialize the serve loop against the prefetch
        # thread — scores are reused until the store's residency epoch
        # moves (see ExpertStore.affinity_epoch)
        self._aff_cache: Dict[int, Tuple[object, float]] = {}

    # ------------------------------------------------------------------
    def enqueue(self, req: Request) -> None:
        req.state = RequestState.QUEUED
        self._queue.append(req)

    def pending(self) -> int:
        return len(self._queue)

    def pop_expired(self, now: float) -> List[Request]:
        """Remove and return queued requests whose deadline already passed —
        admission control: serving them would burn capacity on guaranteed
        SLO misses."""
        expired = [r for r in self._queue if r.slack(now) < 0]
        for r in expired:
            self._queue.remove(r)
            r.state = RequestState.REJECTED
            self._aff_cache.pop(r.rid, None)
        return expired

    # ------------------------------------------------------------------
    def _order(self, reqs: List[Request], now: float, store):
        """EDF first; inside a slack band, highest cache affinity first.
        `store` is any affinity provider with `cache_affinity(table)` —
        an ExpertStore (residency only) or a PrefetchPipeline (residency
        plus in-flight uploads). Affinity is memoized per request against
        the provider's `affinity_epoch`: within one tick (and across ticks
        while residency is unchanged) each table is scanned at most once."""
        epoch = getattr(store, "affinity_epoch", None)

        def affinity(r: Request) -> float:
            hit = self._aff_cache.get(r.rid)
            if hit is not None and epoch is not None and hit[0] == epoch:
                return hit[1]
            aff = store.cache_affinity(r.table)
            self._aff_cache[r.rid] = (epoch, aff)
            return aff

        def key(r: Request):
            band = (
                r.slack(now) // self.slack_band_s
                if r.slo_s is not None
                else float("inf")
            )
            aff = 0.0
            if self.use_affinity and store is not None and r.table is not None:
                aff = affinity(r)
            return (band, -aff, r.arrival_s, r.rid)

        return sorted(reqs, key=key)

    def chunk_urgent(
        self, req: Request, now: float, remaining_chunks: int, chunk_s: float,
    ) -> bool:
        """Deadline accounting for chunked prefill: run the next chunk
        BEFORE this tick's decode when the request's remaining slack no
        longer covers the remaining chunks at the observed per-chunk rate
        (plus one slack band of margin). SLO-less requests are never
        urgent — their chunks always yield to decode progress."""
        if req.slo_s is None:
            return False
        need = remaining_chunks * max(chunk_s, 1e-4) + self.slack_band_s
        return req.slack(now) < need

    def next_prefill_batch(
        self,
        now: float,
        max_batch: int,
        store: Optional[ExpertStore] = None,  # or PrefetchPipeline (duck-typed)
    ) -> Tuple[List[Request], int]:
        """Compose the next prefill batch: the most urgent request anchors
        it, its length bucket fixes the padded shape, and remaining slots
        fill from the same bucket in deadline/affinity order. Returns
        (requests, bucket) — ([], 0) when nothing is ready."""
        ready = [r for r in self._queue if r.table is not None]
        if not ready or max_batch <= 0:
            return [], 0
        ordered = self._order(ready, now, store)
        anchor = ordered[0]
        bucket = bucket_len(anchor.prompt_len, self.buckets)
        batch = [
            r for r in ordered if bucket_len(r.prompt_len, self.buckets) == bucket
        ][:max_batch]
        for r in batch:
            self._queue.remove(r)
            r.state = RequestState.PREFILL
            self._aff_cache.pop(r.rid, None)
        return batch, bucket


class AdmissionController:
    """Overload shedding at the admission gate.

    The estimate is classic back-of-queue wait: `queue depth × EMA of
    observed per-request service time`. A request is shed (rejected with
    reason `overloaded`) when that estimate exceeds `margin` of its
    remaining slack — i.e. when, at the observed service rate, the request
    would already have missed its deadline before reaching a lane. Shedding
    at admission is the whole point: reject BEFORE burning prefill/decode
    capacity on a guaranteed SLO miss, not after (`pop_expired` is the
    too-late backstop).

    Hysteresis: crossing the threshold latches the gate; it stays latched
    until the estimate falls below `exit_frac` of a request's threshold, so
    the admit/shed decision cannot chatter around the boundary while the
    queue hovers at critical depth.

    Degraded transfer shards (the prefetch pipeline's sync-fallback mode —
    see core/offload.py) shrink the threshold by the degraded fraction:
    when uploads have lost their overlap, true service times are about to
    rise, so faults translate into earlier rejections instead of letting
    admitted requests pile into SLO collapse.

    Requests without an SLO fall back to `default_slo_s` slack; with
    neither, they are never shed (there is no deadline to protect)."""

    def __init__(
        self,
        margin: float = 0.8,          # shed when est. wait > margin × slack
        exit_frac: float = 0.6,       # un-latch below exit_frac × threshold
        ema_decay: float = 0.8,       # service-time EMA (new obs weight 1-d)
        init_service_s: float = 0.0,  # prior before the first completion
        default_slo_s: Optional[float] = None,
        degraded_shrink: float = 0.5, # threshold ×= (1 - shrink × degraded)
    ):
        self.margin = margin
        self.exit_frac = exit_frac
        self.ema_decay = ema_decay
        self.service_s = init_service_s
        self.default_slo_s = default_slo_s
        self.degraded_shrink = degraded_shrink
        self.shedding = False         # the hysteresis latch

    def observe(self, service_s: float) -> None:
        """Feed one completed request's service time (prefill -> done)."""
        if self.service_s <= 0.0:
            self.service_s = service_s
        else:
            self.service_s = (
                self.ema_decay * self.service_s
                + (1.0 - self.ema_decay) * service_s
            )

    def est_wait_s(self, depth: int) -> float:
        return depth * self.service_s

    def should_shed(
        self, depth: int, slack_s: Optional[float], degraded_frac: float = 0.0
    ) -> bool:
        """Decide one admission. `slack_s` is the request's remaining
        deadline slack (None = no SLO). Updates the hysteresis latch."""
        if slack_s is None:
            slack_s = self.default_slo_s
        if slack_s is None or self.service_s <= 0.0:
            return False
        thr = self.margin * max(slack_s, 0.0)
        thr *= max(0.0, 1.0 - self.degraded_shrink * degraded_frac)
        est = self.est_wait_s(depth)
        shed = est > (self.exit_frac * thr if self.shedding else thr)
        self.shedding = shed
        return shed
