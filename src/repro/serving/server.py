"""Request server: continuous batching over the SiDA hash-ahead pipeline.

Wiring (one shared `ExpertStore` under everything):

    arrival stream ──> hash-ahead thread ──> admission queue (Scheduler)
                        (build_table per           │ EDF + cache-affinity
                         request, off the          ▼
                         critical path)      prefill batches (length-bucketed)
                                                   │ SiDAEngine.prefill
                                                   │ (logits + rope'd K/V)
                                                   ▼
                                             decode lanes (continuous batch)
                                                   │ per-step hash predict,
                                                   │ ExpertStore prepare,
                                                   ▼ masked decode_step
                                             token streams -> Request.emit

Requests join a decode lane the moment their prefill finishes (the prefill
forward's K/V seeds the lane's cache directly — no replay) and leave the
moment they finish, so the decode batch re-fills continuously instead of
draining to its slowest member. The hash function's look-ahead property is
what makes admission-time expert prediction (and therefore cache-affinity
scheduling and prefetch) possible before any model compute runs.

With `prefetch_depth > 0` the server attaches an async `PrefetchPipeline`
to the shared store: the hash-ahead thread becomes the prefetch *producer*
(each admitted request's predicted experts start uploading immediately as a
fire-and-forget warming prefetch), prefill and decode ticks go through
tickets whose ready fences replace inline uploads, and the scheduler's
cache-affinity score credits uploads still in flight.

With `spec_mode="draft"` decode ticks run speculatively: the predictor's
tied-embedding draft head proposes `spec_k` tokens per lane, ONE superset
prefetch ticket covers every draft position's predicted experts, and a
single jitted k-position verify accepts a per-lane prefix — lanes at mixed
positions accept different amounts, which continuous batching already
handles (see docs/ARCHITECTURE.md, "Speculative decode").
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.decode_engine import (
    draft_unroll_fn,
    hash_fn_step,
    hash_state_init,
    select_accepted_state,
)
from repro.core.engine import SiDAEngine
from repro.core.hash_table import HashTable
from repro.core.offload import ExpertStore, PrefetchPipeline
from repro.core.residency import KVPagePool, ResidencyManager
from repro.models.attention import ShardingCtx
from repro.models.transformer import (
    decode_step,
    init_cache,
    n_moe_layers,
    prefill_chunk_step,
    verify_step,
)
from repro.serving.config import ServingConfig
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import (
    LaneTable,
    Scheduler,
    TenantAdmission,
    WFQScheduler,
)
from repro.serving.telemetry import Telemetry


def _mask_batch(active, new, old, batch_axis: int):
    """jnp.where over a pytree whose leaves carry batch at `batch_axis`."""

    def one(nw, od):
        shape = [1] * nw.ndim
        shape[batch_axis] = -1
        return jnp.where(active.reshape(shape), nw, od)

    return jax.tree.map(one, new, old)


class RequestServer:
    """Continuous-batching request server over the SiDA engines."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        hash_params: dict,
        config: Optional[ServingConfig] = None,
        **kwargs,
    ):
        """`config` is the consolidated `ServingConfig` (serving/config.py).

        Back-compat shim (DEPRECATED): the historical flat keyword surface
        (`slots_per_layer=…, max_lanes=…, prefetch_depth=…`, ~30 knobs) is
        still accepted and routed through `ServingConfig.from_kwargs`; an
        int in `config`'s position is the old positional `slots_per_layer`.
        `ctx` and `telemetry` stay runtime keywords in both styles (live
        mesh / shared registry objects are not configuration). Mixing a
        ServingConfig with legacy config kwargs is a TypeError."""
        ctx = kwargs.pop("ctx", None) or ShardingCtx()
        telemetry = kwargs.pop("telemetry", None)
        if isinstance(config, int):  # legacy positional slots_per_layer
            kwargs["slots_per_layer"] = config
            config = None
        if config is None:
            config = ServingConfig.from_kwargs(**kwargs)
        elif kwargs:
            raise TypeError(
                "RequestServer: pass either a ServingConfig or the legacy "
                f"flat kwargs, not both (got config= plus {sorted(kwargs)})"
            )
        self.config = config
        slots_per_layer = config.slots_per_layer
        max_lanes = config.batching.max_lanes
        max_prefill_batch = config.batching.max_prefill_batch
        buckets = config.batching.buckets
        cache_len = config.batching.cache_len
        drop_expired = config.batching.drop_expired
        serve_top_k = config.serve_top_k
        host_quant = config.quant.host_quant
        eviction = config.eviction
        keep_prefill_logits = config.keep_prefill_logits
        keep_decode_logits = config.keep_decode_logits
        prefetch_depth = config.prefetch.depth
        staging_buffers = config.prefetch.staging_buffers
        fence_timeout_s = config.prefetch.fence_timeout_s
        watchdog_interval_s = config.prefetch.watchdog_interval_s
        watchdog_max_job_age_s = config.prefetch.watchdog_max_job_age_s
        quantized_slots = config.quant.quantized_slots
        scale_granularity = config.quant.scale_granularity
        tier = config.quant.tier
        spec_mode = config.spec.mode
        spec_k = config.spec.k
        sharded = config.parallel.sharded
        rebalance_interval = config.parallel.rebalance_interval
        paged = config.paged
        faults = config.faults.plan
        shed = config.faults.shed

        assert cfg.moe.enabled, "RequestServer targets MoE architectures"
        assert not cfg.enc_dec and cfg.block_kind == "attn", (
            "decode lanes currently support attention-family decoder-only archs"
        )
        self.cfg = cfg
        self.ctx = ctx
        mode = spec_mode if spec_mode is not None else cfg.spec.mode
        assert mode in ("off", "draft"), mode
        self.spec_k = spec_k if spec_k is not None else cfg.spec.k
        self.spec = mode == "draft" and self.spec_k > 1
        if self.spec:
            assert "draft_proj" in hash_params, (
                "spec_mode='draft' needs a hash function with a draft head "
                "(init_hash_fn(draft=True) or init_draft_head)"
            )
        # `sharded` + a mesh in ctx: the one shared slot pool partitions
        # expert-parallel; prefill, decode ticks, and speculative verify
        # all route through the shard_map EP dispatch, and the prefetch
        # pipeline fans tickets out into per-shard transfer queues.
        self.store = ExpertStore(
            cfg, params, slots_per_layer, host_quant=host_quant, eviction=eviction,
            quantized_slots=quantized_slots, scale_granularity=scale_granularity,
            tier=tier, sharded=sharded, mesh=ctx.mesh,
        )
        self.faults = faults
        self.fence_timeout_s = fence_timeout_s
        self.shed = shed
        self.watchdog_interval_s = watchdog_interval_s
        self.watchdog_max_job_age_s = watchdog_max_job_age_s
        self._last_watchdog = 0.0
        self.prefetch: Optional[PrefetchPipeline] = PrefetchPipeline.maybe_create(
            self.store, cfg, prefetch_depth, staging_buffers, faults=faults
        )
        # prefetch_depth=0 keeps the engine from building a second pipeline
        # off cfg.prefetch when the server decided to run synchronously
        self.engine = SiDAEngine(
            cfg, params, hash_params, slots_per_layer,
            serve_top_k=serve_top_k, ctx=ctx, store=self.store,
            prefetcher=self.prefetch, prefetch_depth=0,
        )
        self.hash_params = hash_params
        self.embed_table = params["embed"]
        self.L = n_moe_layers(cfg)
        self.E = cfg.moe.num_experts
        self.k = serve_top_k or cfg.moe.top_k

        self.buckets = tuple(sorted(buckets))
        self.paged = paged if (paged is not None and paged.enabled) else None
        if self.paged is not None:
            # cache_len is the ADDRESSABLE range (page-table width), not the
            # resident budget: spilled pages live on host, which is how a
            # 32k prompt serves through a 4k-page HBM pool
            self.cache_len = self.paged.seq_len
            assert self.buckets[-1] <= self.cache_len, (
                "page table must address a full prefill bucket"
            )
            need = -(-self.buckets[-1] // self.paged.page_size)
            assert self.paged.kv_pages >= need, (
                f"kv_pages={self.paged.kv_pages} cannot hold one full "
                f"prefill bucket ({self.buckets[-1]} tokens = {need} pages)"
            )
        else:
            self.cache_len = cache_len or 2 * self.buckets[-1]
            assert self.buckets[-1] <= self.cache_len, (
                "cache must hold a full bucket"
            )
            # ring-only constraint: a wrapped window would evict positions
            # the prefill seed just wrote; the paged path has no wrap (its
            # windows bound the residency span instead — KVPagePool.span)
            windows = [
                w for s in range(cfg.n_layers) if (w := cfg.layer_window(s))
            ]
            assert not windows or min(windows) >= self.cache_len, (
                "windowed layers need window >= cache_len for prefill-seeded lanes"
            )

        self.max_lanes = max_lanes
        self.max_prefill_batch = max_prefill_batch
        self.drop_expired = drop_expired
        # online load-aware placement: every `rebalance_interval` seconds
        # the serve loop re-assigns expert home shards from the decayed
        # α-mass EMA (ExpertStore.rebalance_homes); moves ride the transfer
        # queues, so they never stall a tick
        self.rebalance_interval = (
            rebalance_interval if self.store.shards > 1 else 0.0
        )
        self._last_rebalance = 0.0
        self.keep_prefill_logits = keep_prefill_logits
        self.keep_decode_logits = keep_decode_logits

        # multi-tenant front door: WFQ (deficit round robin over per-tenant
        # queues) replaces the flat queue, the shed gate splits per tenant,
        # and each tenant's pin quota registers with the store. The
        # single-tenant path (no tenants configured) keeps the exact
        # pre-tenant objects so its behavior stays byte-identical.
        self.tenants = config.tenants
        self.multitenant = config.multitenant
        self._shed_mt: Optional[TenantAdmission] = None
        if self.multitenant:
            self.scheduler: Scheduler = WFQScheduler(
                self.tenants, quantum=config.wfq_quantum, buckets=self.buckets
            )
            if shed is not None:
                self._shed_mt = TenantAdmission(shed, self.tenants)
            for t in self.tenants:
                if t.pin_quota < 1.0:
                    self.store.set_pin_quota(t.name, t.pin_quota)
        else:
            self.scheduler = Scheduler(buckets=self.buckets)
        self.lanes = LaneTable(max_lanes)
        self.telemetry = telemetry or Telemetry()
        self._lock = threading.Lock()

        # --- mutable decode-batch state (one lane = one batch row)
        if self.paged is not None:
            # page-ins ride the prefetch pipeline's transfer queues when
            # async; one ResidencyManager fronts both HBM pools
            self.kv_pool: Optional[KVPagePool] = KVPagePool(
                cfg, self.paged, max_lanes, eviction="alpha",
                pipeline=self.prefetch,
            )
            self.residency: Optional[ResidencyManager] = ResidencyManager(
                self.store, self.kv_pool
            )
            self.cache = self.kv_pool.init_cache()
        else:
            self.kv_pool = None
            self.residency = None
            self.cache = init_cache(cfg, max_lanes, self.cache_len)
        self.hstate = hash_state_init(hash_params, max_lanes)
        self.lane_tokens = np.zeros((max_lanes,), np.int32)
        self._active = np.zeros((max_lanes,), bool)
        self._lane_pos = np.zeros((max_lanes,), np.int64)  # paged: write pos
        self._long_queue: List[Request] = []   # prompts beyond the buckets
        self._chunk_state: Optional[dict] = None  # in-flight chunked prefill
        self._pending_pred = None  # (ids, alpha, active, ticket) for next tick
        self._pending_spec = None  # pre-unrolled draft block for next spec tick
        self._step = 0
        self._t0 = time.perf_counter()  # rebased at run(); fallback for direct use
        self.completed: List[Request] = []
        self.rejected: List[Request] = []

        cfg_, ctx_, E, k = cfg, ctx, self.E, self.k

        @jax.jit
        def _hash_prefill(hp, embed_table, tokens, lengths, state0=None):
            """Advance the predictor LSTM through each (padded) prompt,
            freezing every sequence at its true length — yields the exact
            state the incremental decode predictor would have reached.
            `state0` continues from a prior call (chunked prefill threads
            the state chunk to chunk); None starts fresh."""
            emb = jnp.take(embed_table, tokens, axis=0)          # [n, Sb, d]
            if state0 is None:
                state0 = hash_state_init(hp, tokens.shape[0])

            def step(state, xs):
                emb_t, j = xs
                _, new = hash_fn_step(hp, emb_t, state, E)
                act = j < lengths
                return _mask_batch(act, new, state, 0), None

            xs = (jnp.moveaxis(emb, 1, 0), jnp.arange(tokens.shape[1]))
            state, _ = jax.lax.scan(step, state0, xs)
            return state

        @jax.jit
        def _predict_masked(hp, embed_table, tokens, hstate, active):
            emb = jnp.take(embed_table, tokens, axis=0)          # [B, d]
            logits, new = hash_fn_step(hp, emb, hstate, E)       # [B, L, E]
            merged = _mask_batch(active, new, hstate, 0)
            vals, ids = jax.lax.top_k(logits, k)                 # [B, L, k]
            alpha = jax.nn.softmax(vals, axis=-1) * active[:, None, None]
            return (
                jnp.moveaxis(ids, 1, 0).astype(jnp.int32),       # [L, B, k]
                jnp.moveaxis(alpha, 1, 0).astype(jnp.float32),
                merged,
            )

        if self.paged is not None:
            # paged pools are SHARED across lanes (no batch axis), so the
            # ring path's per-row `_mask_batch` merge cannot apply —
            # inactive lanes' writes are instead *routed* to the trash page
            # inside the step (decode_step's `active`); only `pos` merges
            @jax.jit
            def _decode_masked(serve_params, cache, tokens, slot_ids, w, active):
                logits, new_cache = decode_step(
                    serve_params, cache, tokens, cfg_, ctx_,
                    routing_override=(slot_ids, w), active=active,
                )
                merged = dict(new_cache)
                merged["pos"] = jnp.where(active, new_cache["pos"], cache["pos"])
                return jnp.argmax(logits, -1).astype(jnp.int32), logits, merged
        else:
            @jax.jit
            def _decode_masked(serve_params, cache, tokens, slot_ids, w, active):
                logits, new_cache = decode_step(
                    serve_params, cache, tokens, cfg_, ctx_,
                    routing_override=(slot_ids, w),
                )
                merged = dict(new_cache)
                merged["pos"] = jnp.where(active, new_cache["pos"], cache["pos"])
                for key in cache:
                    if key.startswith("sub"):
                        merged[key] = _mask_batch(active, new_cache[key], cache[key], 1)
                return jnp.argmax(logits, -1).astype(jnp.int32), logits, merged

        @jax.jit
        def _seed_lanes_paged(cache, hstate, hjoin, lanes, pos):
            """Paged lane join: only `pos` and the predictor state live on
            device per lane — the K/V itself is scattered into the page
            pool host-side by `KVPagePool.seed` before this runs."""
            new_cache = dict(cache)
            new_cache["pos"] = cache["pos"].at[lanes].set(pos)
            new_hstate = jax.tree.map(
                lambda full, j: full.at[lanes].set(j.astype(full.dtype)),
                hstate, hjoin,
            )
            return new_cache, new_hstate

        @jax.jit
        def _chunk_step(serve_params, cache, tokens, lane, slot_ids, w):
            """One [1, T] prefill chunk of lane `lane` against the shared
            paged cache: slice the lane's pos/page-table rows, run the
            chunk forward, and merge the advanced pos back."""
            sub = dict(cache)
            sub["pos"] = jax.lax.dynamic_slice(cache["pos"], (lane,), (1,))
            sub["page_table"] = jax.lax.dynamic_slice(
                cache["page_table"], (lane, 0),
                (1, cache["page_table"].shape[1]),
            )
            logits, new_sub = prefill_chunk_step(
                serve_params, sub, tokens, cfg_, ctx_,
                routing_override=(slot_ids, w),
            )
            merged = dict(new_sub)
            merged["pos"] = jax.lax.dynamic_update_slice(
                cache["pos"], new_sub["pos"].astype(cache["pos"].dtype), (lane,)
            )
            merged["page_table"] = cache["page_table"]
            return logits, merged

        @jax.jit
        def _seed_lanes(cache, hstate, kv, hjoin, lanes, pos):
            new_cache = dict(cache)
            for skey, (kk, vv) in kv.items():
                entry = dict(new_cache[skey])
                Sb = kk.shape[2]
                entry["k"] = entry["k"].at[:, lanes, :Sb].set(
                    kk.astype(entry["k"].dtype)
                )
                entry["v"] = entry["v"].at[:, lanes, :Sb].set(
                    vv.astype(entry["v"].dtype)
                )
                new_cache[skey] = entry
            new_cache["pos"] = cache["pos"].at[lanes].set(pos)
            new_hstate = jax.tree.map(
                lambda full, j: full.at[lanes].set(j.astype(full.dtype)),
                hstate, hjoin,
            )
            return new_cache, new_hstate

        @jax.jit
        def _verify_masked(
            serve_params, cache, tokens_blk, slot_ids, w, active,
            states, hstate_old,
        ):
            out, n_acc, logits, new_cache = verify_step(
                serve_params, cache, tokens_blk, cfg_, ctx_,
                routing_override=(slot_ids, w), active=active,
            )
            # per-lane predictor rollback: state after the last accepted
            # input; inactive lanes (n_acc == 0) keep their old state
            hstate = select_accepted_state(states, n_acc, hstate_old)
            return out, n_acc, logits, new_cache, hstate

        self._hash_prefill = _hash_prefill
        self._predict_masked = _predict_masked
        self._decode_masked = _decode_masked
        self._seed_lanes = _seed_lanes
        self._seed_lanes_paged = _seed_lanes_paged
        self._chunk_step = _chunk_step
        # one shared unroll definition with the decode engine (the lane
        # mask is the only delta) so the draft recurrence cannot drift
        # between the two greedy-equivalent consumers
        self._spec_unroll_masked = jax.jit(
            draft_unroll_fn(E, k, self.spec_k)
        )
        self._verify_masked = _verify_masked

    # ------------------------------------------------------------------
    # hash-ahead admission
    # ------------------------------------------------------------------
    def build_request_table(self, req: Request) -> None:
        """Hash-ahead: predict the request's per-token expert activations
        before any model compute (runs on the hash thread). With the async
        pipeline attached, the hash thread doubles as the prefetch producer:
        the predicted experts start uploading immediately as a
        fire-and-forget warming prefetch (`protect=False` — a warmed expert
        may still be evicted before the request is scheduled; later tickets
        fence on any of its uploads still in flight)."""
        if self.faults is not None:
            self.faults.inject("hash")
        req.table = self.engine.build_table(req.rid, req.prompt[None, :])
        if self.prefetch is not None:
            self.prefetch.submit(req.table, protect=False)
            self.telemetry.counter("prefetch_warm_submits").inc()

    def admit(self, req: Request, now: float) -> None:
        req.t_queued = now
        self.telemetry.counter("requests_arrived").inc()
        if self.multitenant:
            # stamp the tenant's contract onto the request at admission:
            # requests without their own SLO inherit the tenant default
            # (deadline-driven scheduling/shedding key off it)
            tcfg = self.config.tenant(req.tenant)
            if tcfg is not None and req.slo_s is None:
                req.slo_s = tcfg.default_slo_s
            self.telemetry.tenant(req.tenant).counter("requests_arrived").inc()
        P = req.prompt_len
        if self.paged is not None and P + req.max_new_tokens > self.cache_len:
            # the page table cannot address positions past cache_len, so the
            # request could not finish — refuse it up front, explicitly
            return self._reject(req, now, "exceeds_addressable_range")
        if P > self.buckets[-1]:
            if self.paged is None or self.paged.prefill_chunk <= 0:
                # no chunked-prefill path: the prefill batcher cannot pad
                # this prompt into any bucket (bucket_len would raise)
                return self._reject(req, now, "prompt_exceeds_max_bucket")
            self.telemetry.counter("requests_admitted_long").inc()
            with self._lock:
                self._long_queue.append(req)
            return
        if self._shed_mt is not None:
            # tenant-aware shedding: the decision reads only THIS tenant's
            # queue depth and service-time EMA, so one tenant's overload
            # closes one tenant's gate — the others keep admitting
            with self._lock:
                depth = self.scheduler.pending_tenant(req.tenant) + sum(
                    1 for r in self._long_queue if r.tenant == req.tenant
                )
            degraded = (
                self.prefetch.degraded_fraction()
                if self.prefetch is not None
                else 0.0
            )
            slack = req.slack(now) if req.slo_s is not None else None
            if self._shed_mt.should_shed(req.tenant, depth, slack, degraded):
                self.telemetry.tenant(req.tenant).gauge(
                    "est_queue_wait_s"
                ).set(self._shed_mt.controller(req.tenant).est_wait_s(depth))
                return self._reject(req, now, "overloaded")
        elif self.shed is not None:
            # overload shedding: estimated back-of-queue wait vs this
            # request's remaining deadline slack. Degraded transfer shards
            # shrink the threshold — uploads running synchronously mean
            # observed service times are about to rise, so the gate closes
            # early instead of admitting requests into an SLO collapse.
            with self._lock:
                depth = self.scheduler.pending() + len(self._long_queue)
            degraded = (
                self.prefetch.degraded_fraction()
                if self.prefetch is not None
                else 0.0
            )
            slack = req.slack(now) if req.slo_s is not None else None
            if self.shed.should_shed(depth, slack, degraded):
                self.telemetry.gauge("est_queue_wait_s").set(
                    self.shed.est_wait_s(depth)
                )
                return self._reject(req, now, "overloaded")
        with self._lock:
            self.scheduler.enqueue(req)

    def _reject(self, req: Request, now: float, reason: str) -> None:
        req.state = RequestState.REJECTED
        req.reject_reason = reason
        req.t_done = now
        self.rejected.append(req)
        self.telemetry.counter("requests_rejected").inc()
        self.telemetry.counter(f"requests_rejected_{reason}").inc()
        if self.multitenant:
            tt = self.telemetry.tenant(req.tenant)
            tt.counter("requests_rejected").inc()
            tt.counter(f"requests_rejected_{reason}").inc()

    # ------------------------------------------------------------------
    # prefill: length-bucketed batch -> lanes
    # ------------------------------------------------------------------
    def _combined_table(self, batch: List[Request], bucket: int) -> HashTable:
        """Concat per-request hash tables, edge-padding ids (no spurious
        expert loads) with zero α (pad tokens route nowhere)."""
        ids = np.zeros((self.L, len(batch), bucket, self.k), np.int32)
        w = np.zeros((self.L, len(batch), bucket, self.k), np.float32)
        for i, r in enumerate(batch):
            P = r.prompt_len
            ids[:, i, :P] = r.table.expert_ids[:, 0]
            ids[:, i, P:] = r.table.expert_ids[:, 0, P - 1 : P]
            w[:, i, :P] = r.table.weights[:, 0]
        return HashTable(self._step, ids, w)

    def _prefill_and_join(
        self, batch: List[Request], bucket: int, now: float,
        table: Optional[HashTable] = None, ticket=None,
    ):
        n = len(batch)
        tokens = np.zeros((n, bucket), np.int32)
        lengths = np.zeros((n,), np.int32)
        for i, r in enumerate(batch):
            tokens[i, : r.prompt_len] = r.prompt
            lengths[i] = r.prompt_len
            r.t_prefill = now
        if table is None:
            table = self._combined_table(batch, bucket)

        # dispatch the hash-prefill scan first: it is independent of the
        # routing translation, so its device time overlaps the prefill
        # ticket's remaining upload fence (async) or the inline prepare
        hjoin = self._hash_prefill(
            self.hash_params, self.embed_table, jnp.asarray(tokens),
            jnp.asarray(lengths),
        )
        logits, kv = self.engine.prefill(tokens, table, ticket=ticket)
        logits = np.asarray(logits)

        lanes = np.zeros((n,), np.int32)
        pos = np.zeros((n,), np.int32)
        t_first = time.perf_counter() - self._t0
        for i, r in enumerate(batch):
            first = int(np.argmax(logits[i, r.prompt_len - 1]))
            if self.keep_prefill_logits:
                r.prefill_logits = logits[i, : r.prompt_len].copy()
            lanes[i] = self.lanes.assign(r)
            pos[i] = r.prompt_len
            r.state = RequestState.DECODE
            r.t_first_token = t_first
            r.emit(first)
            self.lane_tokens[lanes[i]] = first
            self.telemetry.histogram("ttft_s").observe(r.ttft_s)
            if self.multitenant:
                self.scheduler.debit(r.tenant, 1, now)
        if self.kv_pool is not None:
            # scatter each request's rope'd K/V into its lane's pages
            # host-side (allocating/spilling as needed), then install pos
            # and predictor state on device
            for i, r in enumerate(batch):
                self.cache = self.kv_pool.seed(
                    self.cache, int(lanes[i]),
                    {k: (kk[:, i], vv[:, i]) for k, (kk, vv) in kv.items()},
                    r.prompt_len,
                )
            self.cache["page_table"] = self.kv_pool.device_table()
            self.cache, self.hstate = self._seed_lanes_paged(
                self.cache, self.hstate, hjoin,
                jnp.asarray(lanes), jnp.asarray(pos),
            )
        else:
            self.cache, self.hstate = self._seed_lanes(
                self.cache, self.hstate, kv, hjoin,
                jnp.asarray(lanes), jnp.asarray(pos),
            )
        self._lane_pos[lanes] = pos
        self._active[lanes] = True
        self.telemetry.counter("prefill_batches").inc()
        self.telemetry.histogram("prefill_batch_size").observe(n)
        self.telemetry.counter("prefill_pad_tokens").inc(
            float(n * bucket - lengths.sum())
        )
        # a request whose whole budget was the first token finishes here
        for i, r in enumerate(batch):
            if r.finished():
                self._finish(int(lanes[i]))

    def _await_fences(self, ticket, prep: HashTable):
        """Bounded wait on a prefetch ticket's ready fences. Returns the
        translation to decode with. On timeout (`fence_timeout_s` elapsed —
        the transfer threads are stalled, dead, or hopelessly backlogged)
        the tick falls back to a synchronous `store.prepare` of the same
        prediction: identical residency outcome, zero overlap, but the
        serve loop never blocks past its configured bound behind a hung
        fence. `fence_timeout_s=None` waits indefinitely (fences are still
        poisoned — never abandoned — on transfer failure, so indefinite
        means until retry/rollback resolves them, not forever)."""
        with self.telemetry.timer("prefetch_fence_s"):
            ok = ticket.wait(self.fence_timeout_s)
        if ok:
            return ticket.trans
        self.telemetry.counter("prefetch_fence_timeouts").inc()
        return self.store.prepare(prep)

    # ------------------------------------------------------------------
    # decode: one continuous-batch step
    # ------------------------------------------------------------------
    def _page_tick(self, upto: np.ndarray, extra_span: int = 0) -> None:
        """Pre-tick paging: make each lane's positions resident up to
        `upto[lane]` (0 = skip the lane), clear page-in fences, and refresh
        the device page table — the tick that follows can then read every
        in-span position through the table.

        Each lane's in-span pages are PINNED as they are ensured: without
        the pin, ensure() for lane N could evict an in-span page of an
        already-ensured lane M, and the tick would silently drop lane M's
        real keys through a -1 table entry. Over-pressure now raises the
        explicit pool-exhausted error instead; the tick unpins after its
        jitted step."""
        pool = self.kv_pool
        for lane in range(self.max_lanes):
            if upto[lane] > 0:
                self.cache = pool.ensure(
                    self.cache, lane, int(upto[lane]), pin=True,
                    extra_span=extra_span,
                )
        self.cache = pool.sync(self.cache)
        self.cache["page_table"] = pool.device_table()

    def _predict_tick(self, mask: np.ndarray):
        """Advance the hash predictor for `mask` lanes; returns np arrays."""
        ids, alpha, self.hstate = self._predict_masked(
            self.hash_params, self.embed_table,
            jnp.asarray(self.lane_tokens), self.hstate, jnp.asarray(mask),
        )
        return np.asarray(ids), np.asarray(alpha)

    def _spec_tick(self, now: float) -> None:
        """Speculative continuous-batch step: draft K tokens per lane, ship
        ONE superset prefetch ticket for all K positions' predicted experts,
        verify the block in a single jitted k-position decode, and emit each
        lane's accepted prefix — lanes at mixed positions accept different
        amounts, so the continuous batch stays continuous."""
        active = self._active.copy()
        if self.kv_pool is not None:
            # verify writes the whole K-block before acceptance is known;
            # _page_tick pins the ensured pages so nothing the verify reads
            # or writes can be evicted before the rollback restore. The
            # target is clamped to the addressable range: a lane finishing
            # at the edge drafts past it, but those overflow writes route
            # to the trash page and can never be accepted (admission caps
            # P + max_new at cache_len)
            # extra_span: the block's first query sits spec_k - 1 positions
            # before its last — widen the page-in floor so its window pages
            # come back too
            self._page_tick(
                np.where(
                    active,
                    np.minimum(self._lane_pos + self.spec_k, self.cache_len),
                    0,
                ),
                extra_span=self.spec_k - 1,
            )
        act_dev = jnp.asarray(active)
        unrolled = ticket = stale_ticket = None
        if self._pending_spec is not None:
            # the draft block (and its superset ticket) were pre-submitted at
            # the end of the previous tick — the transfers overlapped the
            # prefill forwards / scheduling that ran in between. A lane that
            # joined since then invalidates the pre-unroll (its token and
            # predictor state were reseeded), so redo it urgently — but keep
            # the stale ticket alive until after the verify: the surviving
            # lanes' predictions are unchanged, so its in-flight uploads are
            # exactly what the redone submit would re-request, and holding
            # the protection lets the new plan fence on them instead of
            # re-issuing the transfers.
            p_unrolled, pred_active, p_ticket = self._pending_spec
            self._pending_spec = None
            if (active & ~pred_active).any():
                stale_ticket = p_ticket
            else:
                unrolled, ticket = p_unrolled, p_ticket
        if unrolled is None:
            inputs, ids, alpha, states = self._spec_unroll_masked(
                self.hash_params, self.embed_table,
                jnp.asarray(self.lane_tokens), self.hstate, act_dev,
            )
            ids_np = np.asarray(ids)                       # [L, B, K, k]
            alpha_np = np.asarray(alpha)
        else:
            inputs, ids, alpha, states, ids_np, alpha_np = unrolled
        spec_prep = HashTable(self._step, ids_np[:, active], alpha_np[:, active])
        if self.prefetch is not None:
            if ticket is None:
                # one multi-token ticket: the union over all K draft
                # positions of every active lane — a strict superset of
                # each per-step ticket
                ticket = self.prefetch.submit(spec_prep)
            trans = self._await_fences(ticket, spec_prep)
        else:
            trans = self.store.prepare(spec_prep)
        slot_ids, w = self.store.translate_device(ids, alpha, trans)
        out_blk, n_acc, logits, self.cache, self.hstate = self._verify_masked(
            self.store.serve_params, self.cache, inputs,
            jnp.moveaxis(slot_ids, 2, 0), jnp.moveaxis(w, 2, 0), act_dev,
            states, self.hstate,
        )
        out_np = np.asarray(out_blk)    # forces the step; slots consumed
        n_np = np.asarray(n_acc)
        if self.kv_pool is not None:
            self.kv_pool.unpin_all()
            self._lane_pos[active] += n_np[active]
        if ticket is not None:
            ticket.release()
        if stale_ticket is not None:
            stale_ticket.release()
        logits_np = (
            np.asarray(logits) if self.keep_decode_logits else None
        )  # [K, B, V]
        self._step += 1
        n_active = int(active.sum())
        self.telemetry.counter("decode_steps").inc()
        self.telemetry.counter("spec_verify_steps").inc()
        self.telemetry.counter("spec_proposed_tokens").inc(self.spec_k * n_active)

        emitted = 0
        for lane in self.lanes.active():
            if not active[lane]:
                continue  # joined after this tick's snapshot
            req = self.lanes.requests[lane]
            for i in range(int(n_np[lane])):
                req.emit(int(out_np[lane, i]))
                emitted += 1
                if logits_np is not None:
                    if req.decode_logits is None:
                        req.decode_logits = []
                    req.decode_logits.append(logits_np[i, lane].copy())
                self.lane_tokens[lane] = out_np[lane, i]
                self.telemetry.counter("tokens_generated").inc()
                if self.multitenant:
                    self.telemetry.tenant(req.tenant).counter(
                        "tokens_generated"
                    ).inc()
                    self.scheduler.debit(req.tenant, 1, now)
                if req.finished():
                    self._finish(lane)
                    break
        # accepted counts what was actually DELIVERED: a lane whose request
        # finished mid-block drops the rest of its accepted prefix, and
        # counting those would over-report acceptance vs tokens_generated
        # (and vs the engine-side DecodeMetrics, which truncates the same way)
        self.telemetry.counter("spec_accepted_tokens").inc(float(emitted))
        if n_active:
            self.telemetry.histogram("accepted_per_step").observe(
                emitted / n_active
            )

        # pipeline the next block: the accepted tokens and rolled-back
        # predictor state are final, so the next draft unroll (and its
        # superset ticket's uploads) can overlap whatever runs between
        # ticks — mirrors the vanilla tick's pre-predict
        if self.prefetch is not None and self._active.any():
            nxt = self._active.copy()
            n_inp, n_ids, n_alpha, n_states = self._spec_unroll_masked(
                self.hash_params, self.embed_table,
                jnp.asarray(self.lane_tokens), self.hstate, jnp.asarray(nxt),
            )
            n_ids_np, n_alpha_np = np.asarray(n_ids), np.asarray(n_alpha)
            tkt = self.prefetch.submit(HashTable(
                self._step, n_ids_np[:, nxt], n_alpha_np[:, nxt]
            ))
            self._pending_spec = (
                (n_inp, n_ids, n_alpha, n_states, n_ids_np, n_alpha_np),
                nxt, tkt,
            )

    def _decode_tick(self, now: float) -> None:
        if self.spec:
            return self._spec_tick(now)
        active = self._active.copy()
        if self.kv_pool is not None:
            self._page_tick(np.where(active, self._lane_pos + 1, 0))
        ticket = None
        if self._pending_pred is not None:
            # predictions (and their uploads) were pre-submitted at the end
            # of the previous tick — the transfer overlapped whatever ran
            # in between (prefill forwards, scheduling, arrival waits)
            ids_np, alpha_np, pred_active, ticket = self._pending_pred
            self._pending_pred = None
            joined = active & ~pred_active
            if joined.any():
                # lanes that joined since the pre-predict: predict just them
                # and fold into the tick (their uploads go out urgently now)
                ids2, alpha2 = self._predict_tick(joined)
                ids_np = np.where(joined[None, :, None], ids2, ids_np)
                alpha_np = np.where(joined[None, :, None], alpha2, alpha_np)
                ticket.release()
                ticket = self.prefetch.submit(HashTable(
                    self._step,
                    ids_np[:, active, None, :], alpha_np[:, active, None, :],
                ))
        else:
            ids_np, alpha_np = self._predict_tick(active)

        # prefetch only what active lanes predict; translate for all lanes
        prep = HashTable(
            self._step, ids_np[:, active, None, :], alpha_np[:, active, None, :]
        )
        if self.prefetch is not None:
            if ticket is None:
                ticket = self.prefetch.submit(prep)
            trans = self._await_fences(ticket, prep)
        else:
            trans = self.store.prepare(prep)
        full = HashTable(self._step, ids_np[:, :, None, :], alpha_np[:, :, None, :])
        slot_ids, w = self.store.translate(full, trans)

        next_tok, logits, self.cache = self._decode_masked(
            self.store.serve_params, self.cache, jnp.asarray(self.lane_tokens),
            jnp.asarray(slot_ids[:, :, 0, :]), jnp.asarray(w[:, :, 0, :]),
            jnp.asarray(active),
        )
        next_tok = np.asarray(next_tok)  # forces the step; slots consumed
        if ticket is not None:
            ticket.release()
        if self.kv_pool is not None:
            self.kv_pool.unpin_all()     # pinned by _page_tick
            self._lane_pos[active] += 1
        logits_np = np.asarray(logits) if self.keep_decode_logits else None
        self._step += 1
        self.telemetry.counter("decode_steps").inc()

        for lane in self.lanes.active():
            if not active[lane]:
                continue  # joined after this tick's snapshot
            req = self.lanes.requests[lane]
            req.emit(int(next_tok[lane]))
            if logits_np is not None:
                if req.decode_logits is None:
                    req.decode_logits = []
                req.decode_logits.append(logits_np[lane].copy())
            self.lane_tokens[lane] = next_tok[lane]
            self.telemetry.counter("tokens_generated").inc()
            if self.multitenant:
                # per-tenant accounting: the generated token both marks the
                # tenant's partition and debits its rate budget (WFQ defers
                # the tenant's next prefill once the bucket runs dry)
                self.telemetry.tenant(req.tenant).counter(
                    "tokens_generated"
                ).inc()
                self.scheduler.debit(req.tenant, 1, now)
            if req.finished():
                self._finish(lane)

        # pipeline the next tick: predict it now (tokens are final) and
        # submit its uploads so they transfer while prefill forwards and
        # scheduling run between ticks — the next fence finds them landed
        if self.prefetch is not None and self._active.any():
            nxt = self._active.copy()
            n_ids, n_alpha = self._predict_tick(nxt)
            tkt = self.prefetch.submit(HashTable(
                self._step, n_ids[:, nxt, None, :], n_alpha[:, nxt, None, :]
            ))
            self._pending_pred = (n_ids, n_alpha, nxt, tkt)

    def _finish(self, lane: int) -> None:
        req = self.lanes.release(lane)
        self._active[lane] = False
        if self.kv_pool is not None:
            self.kv_pool.release_lane(lane)
            self._lane_pos[lane] = 0
        now = time.perf_counter() - self._t0
        req.state = RequestState.DONE
        req.t_done = now
        self.completed.append(req)
        self.telemetry.counter("requests_completed").inc()
        self.telemetry.histogram("latency_s").observe(req.latency_s)
        self.telemetry.histogram("decode_tokens").observe(len(req.generated))
        missed = req.slo_s is not None and req.latency_s > req.slo_s
        if missed:
            self.telemetry.counter("deadline_miss").inc()
        if self.multitenant:
            tt = self.telemetry.tenant(req.tenant)
            tt.counter("requests_completed").inc()
            tt.histogram("latency_s").observe(req.latency_s)
            tt.histogram("ttft_s").observe(req.ttft_s)
            tt.histogram("decode_tokens").observe(len(req.generated))
            if missed:
                tt.counter("deadline_miss").inc()
        service = now - req.t_prefill
        if req.t_prefill >= 0:
            # prefill-to-done is the service time the back-of-queue wait
            # estimate multiplies by (queueing delay is what it predicts,
            # so it must not be part of the sample)
            if self._shed_mt is not None:
                self._shed_mt.observe(req.tenant, service)
            elif self.shed is not None:
                self.shed.observe(service)

    # ------------------------------------------------------------------
    # chunked prefill: long prompts stream through the paged cache
    # ------------------------------------------------------------------
    def _start_long(self, req: Request, now: float) -> None:
        """Claim a lane for a long prompt; it joins the decode batch only
        after its last chunk (the lane stays masked out meanwhile)."""
        lane = self.lanes.assign(req)
        req.state = RequestState.PREFILL
        req.t_prefill = now
        self._active[lane] = False
        self._chunk_state = {
            "req": req, "lane": lane, "done": 0,
            "hstate": None,   # predictor state threaded chunk to chunk
            "ema_s": 0.0,     # observed per-chunk seconds (EMA) for the
                              # scheduler's chunk-deadline accounting
            "logits": [] if self.keep_prefill_logits else None,
        }
        self.telemetry.counter("long_prefills_started").inc()

    def _chunk_tick(self, now: float) -> None:
        """Run ONE prefill chunk of the in-flight long request. Bounding
        the work per call is the point: decode ticks interleave between
        chunks, so a 32k prefill never stalls the continuous batch (the
        short-request decode-progress criterion in bench_serving's
        `server_longctx` probe)."""
        st = self._chunk_state
        req, lane, done = st["req"], st["lane"], st["done"]
        T = self.paged.prefill_chunk
        P = req.prompt_len
        n = min(T, P - done)
        t0 = time.perf_counter()
        tokens = np.zeros((1, T), np.int32)
        tokens[0, :n] = req.prompt[done : done + n]
        # per-chunk routing sliced from the admission-time hash table;
        # edge-pad ids (no spurious loads), zero-α pads route nowhere
        ids = np.zeros((self.L, 1, T, self.k), np.int32)
        w = np.zeros((self.L, 1, T, self.k), np.float32)
        ids[:, :, :n] = req.table.expert_ids[:, :, done : done + n]
        ids[:, :, n:] = ids[:, :, n - 1 : n]
        w[:, :, :n] = req.table.weights[:, :, done : done + n]
        tbl = HashTable(self._step, ids, w)
        ticket = None
        if self.prefetch is not None:
            ticket = self.prefetch.submit(tbl)
            trans = self._await_fences(ticket, tbl)
        else:
            trans = self.store.prepare(tbl)
        slot_ids, w_t = self.store.translate(tbl, trans)
        # residency for the chunk's writes plus its attention span, pinned
        # so a later page's alloc can't evict an earlier in-span page
        # mid-ensure. Clamped to the addressable range: when cache_len is
        # not a chunk multiple the last chunk's pad tail reaches past it,
        # but those positions route to the trash page inside the step
        # extra_span: the chunk's first query is T - 1 positions before its
        # last, so its attention window reaches that much further back
        self.cache = self.kv_pool.ensure(
            self.cache, lane, min(done + T, self.cache_len), pin=True,
            extra_span=T - 1,
        )
        self.cache = self.kv_pool.sync(self.cache)
        self.cache["page_table"] = self.kv_pool.device_table()
        logits, self.cache = self._chunk_step(
            self.store.serve_params, self.cache, jnp.asarray(tokens),
            jnp.asarray(lane, jnp.int32),
            jnp.asarray(slot_ids), jnp.asarray(w_t),
        )
        self.kv_pool.unpin_lane(lane)
        lengths = jnp.asarray([n], jnp.int32)
        if st["hstate"] is None:
            st["hstate"] = self._hash_prefill(
                self.hash_params, self.embed_table, jnp.asarray(tokens), lengths
            )
        else:
            st["hstate"] = self._hash_prefill(
                self.hash_params, self.embed_table, jnp.asarray(tokens),
                lengths, st["hstate"],
            )
        if st["logits"] is not None:
            st["logits"].append(np.asarray(logits)[0, :n])
        if ticket is not None:
            ticket.release()
        st["done"] = done + n
        req.chunk_pos = st["done"]
        dt = time.perf_counter() - t0
        st["ema_s"] = dt if st["ema_s"] == 0.0 else 0.5 * st["ema_s"] + 0.5 * dt
        self._step += 1
        self.telemetry.counter("prefill_chunks").inc()
        self.telemetry.counter("prefill_pad_tokens").inc(float(T - n))
        if st["done"] < P:
            return
        # final chunk: the lane joins the decode batch
        first = int(np.argmax(np.asarray(logits)[0, n - 1]))
        if st["logits"] is not None:
            req.prefill_logits = np.concatenate(st["logits"], axis=0)
        # the padded tail advanced pos past the prompt; decode resumes at P
        # (each garbage position is rewritten by decode before any query
        # can attend it — decode at position p writes p, then reads <= p)
        self.cache = dict(self.cache)
        self.cache["pos"] = self.cache["pos"].at[lane].set(P)
        self.hstate = jax.tree.map(
            lambda full, j: full.at[lane].set(j[0].astype(full.dtype)),
            self.hstate, st["hstate"],
        )
        self._lane_pos[lane] = P
        self.lane_tokens[lane] = first
        self._active[lane] = True
        req.state = RequestState.DECODE
        req.t_first_token = time.perf_counter() - self._t0
        req.emit(first)
        self.telemetry.histogram("ttft_s").observe(req.ttft_s)
        if self.multitenant:
            self.scheduler.debit(req.tenant, 1, now)
        self.telemetry.counter("long_prefills_completed").inc()
        self._chunk_state = None
        if req.finished():
            self._finish(lane)

    # ------------------------------------------------------------------
    # serving loop
    # ------------------------------------------------------------------
    def run(self, requests: List[Request], realtime: bool = True) -> Telemetry:
        """Serve an arrival stream to completion.

        realtime=True honors inter-arrival gaps with wall-clock waits (the
        open-loop Poisson benchmark); realtime=False releases requests in
        arrival order as fast as the hash thread can admit them (tests)."""
        self._t0 = time.perf_counter()
        stream = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        hash_done = threading.Event()
        hash_exc: List[BaseException] = []

        def hash_thread():
            # Supervised: a per-request failure (a corrupt prompt, an
            # injected `hash` fault) rejects THAT request and moves on; an
            # unexpected escape is captured and re-raised on the main loop
            # after join. Either way `hash_done` is GUARANTEED set — the
            # main loop's exit test is `hash_done and queues empty`, so a
            # silently dead hash thread would otherwise spin run() forever.
            try:
                for req in stream:
                    if realtime:
                        wait = req.arrival_s - (time.perf_counter() - self._t0)
                        if wait > 0:
                            time.sleep(wait)
                    try:
                        self.build_request_table(req)
                    except Exception:
                        self.telemetry.counter("hash_thread_errors").inc()
                        self._reject(
                            req, time.perf_counter() - self._t0, "hash_error"
                        )
                        continue
                    self.admit(req, time.perf_counter() - self._t0)
            except BaseException as e:  # noqa: BLE001 — re-raised after join
                hash_exc.append(e)
            finally:
                hash_done.set()

        ht = threading.Thread(target=hash_thread)
        ht.start()
        try:
            while True:
                now = time.perf_counter() - self._t0
                long_req = None
                with self._lock:
                    if self.drop_expired:
                        for r in self.scheduler.pop_expired(now):
                            # through _reject so reject_reason and the
                            # per-reason counter stay consistent with every
                            # other rejection path
                            self._reject(r, now, "deadline_expired")
                    free = self.lanes.free_count()
                    batch, bucket = ([], 0)
                    if free:
                        # affinity provider: the pipeline (residency + in-
                        # flight uploads) when async, the bare store when not
                        batch, bucket = self.scheduler.next_prefill_batch(
                            now, min(free, self.max_prefill_batch),
                            self.prefetch or self.store,
                        )
                    # one chunked long prefill at a time; it needs a lane
                    # beyond what this round's bucket batch will take
                    if (
                        self._chunk_state is None and self._long_queue
                        and self.lanes.free_count() > len(batch)
                    ):
                        long_req = self._long_queue.pop(0)
                    depth = self.scheduler.pending() + len(self._long_queue)
                self.telemetry.gauge("queue_depth").set(depth)
                self.telemetry.gauge("active_lanes").set(len(self.lanes.active()))

                if (
                    self.prefetch is not None
                    and self.watchdog_interval_s > 0
                    and now - self._last_watchdog >= self.watchdog_interval_s
                ):
                    self._last_watchdog = now
                    revived, stalled = self.prefetch.watchdog(
                        self.watchdog_max_job_age_s
                    )
                    if revived:
                        self.telemetry.counter("watchdog_revives").inc(revived)
                    if stalled:
                        self.telemetry.counter("prefetch_stalled_jobs").inc(
                            stalled
                        )

                if (
                    self.rebalance_interval > 0
                    and now - self._last_rebalance >= self.rebalance_interval
                ):
                    self._last_rebalance = now
                    moved = self.store.rebalance_homes()
                    if moved:
                        self.telemetry.counter("rebalance_moves").inc(moved)
                        self.telemetry.counter("rebalance_rounds").inc()

                if long_req is not None:
                    self._start_long(long_req, now)

                progressed = False
                pf_table, pf_ticket = None, None
                if batch:
                    pf_table = self._combined_table(batch, bucket)
                    if self.prefetch is not None:
                        # submit prefill uploads before the decode tick so
                        # the tick's compute covers the transfer; priority 1
                        # keeps them behind the tick's own urgent uploads
                        pf_ticket = self.prefetch.submit(pf_table, priority=1)
                # chunk ordering: a chunk runs before this round's decode
                # tick only when the long request's deadline demands it —
                # otherwise decode progress (the short requests) goes first
                chunk_first = False
                if self._chunk_state is not None:
                    st = self._chunk_state
                    remaining = -(
                        -(st["req"].prompt_len - st["done"])
                        // self.paged.prefill_chunk
                    )
                    chunk_first = self.scheduler.chunk_urgent(
                        st["req"], now, remaining, st["ema_s"]
                    )
                    if chunk_first:
                        self._chunk_tick(now)
                        progressed = True
                if self._active.any():
                    # timed so summaries can report decode-phase throughput
                    # (tokens per second spent inside decode ticks) — the
                    # quantity speculative decode optimizes, separated from
                    # admission/prefill/scheduling wall time
                    with self.telemetry.timer("decode_tick_s"):
                        self._decode_tick(now)
                    progressed = True
                if batch:
                    self._prefill_and_join(
                        batch, bucket, now, table=pf_table, ticket=pf_ticket
                    )
                    progressed = True
                if self._chunk_state is not None and not chunk_first:
                    self._chunk_tick(now)
                    progressed = True
                if not progressed:
                    # hash_done is set only after the last admit, so a
                    # pending() re-read under the lock cannot miss a request
                    # admitted after the depth snapshot above
                    if hash_done.is_set():
                        with self._lock:
                            if (
                                self.scheduler.pending() == 0
                                and not self._long_queue
                                and self._chunk_state is None
                            ):
                                break
                    time.sleep(2e-4)
        finally:
            ht.join()
        if hash_exc:
            # an unexpected hash-thread death must fail the run loudly on
            # the caller's thread, not leave a half-served stream behind
            raise hash_exc[0]
        st = self.store.stats
        self.telemetry.counter("h2d_bytes").inc(st.bytes_h2d)
        self.telemetry.counter("expert_loads").inc(st.loads)
        self.telemetry.counter("expert_hits").inc(st.hits)
        self.telemetry.counter("expert_evictions").inc(st.evictions)
        self.telemetry.counter("expert_replica_loads").inc(st.replica_loads)
        if self.prefetch is not None:
            for k, v in self.prefetch.stats.summary().items():
                c = self.telemetry.counter(k)
                c.value = 0  # stats are cumulative; snapshot, don't double-count
                c.inc(v)
        if self.kv_pool is not None:
            for k, v in self.kv_pool.stats.summary().items():
                c = self.telemetry.counter(k)
                c.value = 0
                c.inc(v)
        if self.faults is not None:
            for k, v in self.faults.summary().items():
                c = self.telemetry.counter(k)
                c.value = 0
                c.inc(v)
        return self.telemetry

    def close(self) -> None:
        """Join the async prefetch transfer thread (no-op when sync)."""
        if self.prefetch is not None:
            self.prefetch.close()

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Flat metric dict: the serving analogue of ServeMetrics.summary."""
        t = self.telemetry
        lat, ttft = t.histogram("latency_s"), t.histogram("ttft_s")
        st = self.store.stats
        refs = st.hits + st.loads
        toks = t.counter("tokens_generated").value + t.counter(
            "requests_completed"
        ).value  # first tokens are emitted at prefill
        wall = t.wall_s()
        # upload-stall: sync path pays for every upload inline
        # (stats.prepare_time); async pays only for ready fences that had
        # not landed yet (pipeline stall_s) plus any residual sync preps
        stall = st.prepare_time
        overlap = 0.0
        if self.prefetch is not None:
            stall += self.prefetch.stats.stall_s
            overlap = self.prefetch.stats.overlap_s
        acc_hist = t.histogram("accepted_per_step")
        tick_s = t.counter("decode_tick_s_total").value
        out = {
            "completed": t.counter("requests_completed").value,
            "rejected": t.counter("requests_rejected").value,
            "deadline_miss": t.counter("deadline_miss").value,
            "throughput_tok_s": toks / wall if wall else 0.0,
            # decode-phase throughput: generated tokens per second of decode
            # ticks — excludes admission/prefill/scheduling wall time, so it
            # isolates the hot loop (and is far less noisy on shared hosts)
            "decode_tok_s": (
                t.counter("tokens_generated").value / tick_s if tick_s else 0.0
            ),
            "spec_k": float(self.spec_k if self.spec else 0),
            # 0.0 when spec is off: no positions were ever proposed
            "spec_acceptance_rate": t.ratio(
                "spec_accepted_tokens", "spec_proposed_tokens"
            ),
            "spec_accepted_per_step": (
                sum(acc_hist.samples) / acc_hist.count if acc_hist.count else 0.0
            ),
            "p50_latency_s": lat.percentile(50),
            "p95_latency_s": lat.percentile(95),
            "p99_latency_s": lat.percentile(99),
            "p50_ttft_s": ttft.percentile(50),
            "p95_ttft_s": ttft.percentile(95),
            "cache_hit_rate": st.hits / refs if refs else 0.0,
            "h2d_mb": st.bytes_h2d / 1e6,
            "max_queue_depth": t.gauge("queue_depth").max,
            "upload_stall_s": stall,
            "upload_overlap_s": overlap,
            "async_prefetch": 1.0 if self.prefetch is not None else 0.0,
            # fault tolerance: the supervision counters every chaos run
            # (tests/test_faults.py, bench_serving server_chaos) asserts on
            "rejected_overloaded": t.counter("requests_rejected_overloaded").value,
            "rejected_hash_error": t.counter("requests_rejected_hash_error").value,
            "upload_retries": t.counter("prefetch_upload_retries").value,
            "upload_failures": t.counter("prefetch_upload_failures").value,
            "poisoned_fences": t.counter("prefetch_poisoned_fences").value,
            "thread_crashes": t.counter("prefetch_thread_crashes").value,
            "thread_restarts": t.counter("prefetch_thread_restarts").value,
            "sync_fallbacks": t.counter("prefetch_sync_fallbacks").value,
            "fence_timeouts": t.counter("prefetch_fence_timeouts").value,
            "watchdog_revives": t.counter("watchdog_revives").value,
            "degraded_shards": t.counter("prefetch_degraded_shards").value,
        }
        if self.store.shards > 1:
            out["replicate_hot"] = float(self.store.sharded.replicate_hot)
            out["replica_loads"] = float(st.replica_loads)
            out["rebalance_moves"] = float(st.rebalance_moves)
            if self.prefetch is not None:
                # shard load balance: max/mean per-shard upload traffic —
                # 1.0 is a perfectly even fleet, the fixed-home skew this
                # PR removes shows up as a large ratio (bench_serving's
                # shard-load-balance row reads exactly this)
                ups = [
                    float(self.prefetch.stats.uploads_by_shard.get(m, 0))
                    for m in range(self.store.shards)
                ]
                mean = sum(ups) / len(ups)
                out["shard_upload_max_over_mean"] = (
                    max(ups) / mean if mean > 0 else 1.0
                )
        if self.residency is not None:
            out.update(self.residency.summary())
            out["paged_kv"] = 1.0
            out["long_prefills_completed"] = t.counter(
                "long_prefills_completed"
            ).value
            out["prefill_chunks"] = t.counter("prefill_chunks").value
            out["requests_rejected_too_long"] = t.counter(
                "requests_rejected_prompt_exceeds_max_bucket"
            ).value
        else:
            out["paged_kv"] = 0.0
        return out

    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant summary block (multi-tenant runs; {} otherwise):
        arrivals/completions/rejections, token counts, latency percentiles,
        and SLO attainment — the fraction of the tenant's ARRIVED requests
        that completed within their deadline (sheds and misses both count
        against it, which is what a tenant's contract actually measures).
        Tenants without SLOs report attainment over completions alone."""
        out: Dict[str, Dict[str, float]] = {}
        for name in self.telemetry.tenant_names():
            tt = self.telemetry.tenant(name)
            lat = tt.histogram("latency_s")
            arrived = tt.counter("requests_arrived").value
            completed = tt.counter("requests_completed").value
            missed = tt.counter("deadline_miss").value
            in_slo = completed - missed
            out[name] = {
                "arrived": arrived,
                "completed": completed,
                "rejected": tt.counter("requests_rejected").value,
                "rejected_overloaded": tt.counter(
                    "requests_rejected_overloaded"
                ).value,
                "deadline_miss": missed,
                "tokens_generated": tt.counter("tokens_generated").value,
                "p50_latency_s": lat.percentile(50),
                "p95_latency_s": lat.percentile(95),
                "slo_attainment": in_slo / arrived if arrived else 0.0,
                "pinned_share": self.store.pinned_share(name),
            }
        return out
