"""Serving telemetry: counters, gauges, histograms with JSON export.

Replaces the ad-hoc print-a-few-floats reporting of the batch engines with
a structured registry the server, the launcher, and the benchmarks all
share: `Telemetry.snapshot()` is a plain dict (JSON-serializable) carrying
p50/p95/p99 latency, TTFT, queue depth, H2D bytes, cache hit rate, …
"""
from __future__ import annotations

import contextlib
import json
import math
import threading
import time
from typing import Dict, List, Optional

# metrics are written from several threads at once (the hash-ahead thread
# rejects/admits while the serve loop ticks and the transfer threads flush
# stats); a float `+=` is read-modify-write, so unguarded concurrent incs
# can drop counts. One shared lock is plenty — these are not hot-loop ops.
_metrics_lock = threading.Lock()


class Counter:
    """Monotonic event count (requests completed, tokens generated, …).
    Thread-safe: admission runs on the hash thread, ticks on the main one."""

    def __init__(self) -> None:
        self.value: float = 0

    def inc(self, v: float = 1) -> None:
        with _metrics_lock:
            self.value += v


class Gauge:
    """Point-in-time value (queue depth, active lanes, …). Keeps the max
    ever seen so a snapshot exposes peak pressure, not just the final state."""

    def __init__(self) -> None:
        self.value: float = 0
        self.max: float = 0

    def set(self, v: float) -> None:
        with _metrics_lock:
            self.value = v
            self.max = max(self.max, v)


class Histogram:
    """Exact-sample histogram (serving runs are bounded, so no sketching):
    percentiles are computed from the raw observations at snapshot time."""

    def __init__(self) -> None:
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        with _metrics_lock:
            self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> float:
        """Ceil-based nearest rank: at least a q-fraction of the samples
        lie at or below the returned value. (Banker's rounding would pick
        the LOWER of two samples for p50 and understate small-count tail
        percentiles — an SLO report must err high, not low.)"""
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        idx = min(len(xs) - 1, max(0, math.ceil(q / 100.0 * (len(xs) - 1))))
        return xs[idx]

    def summary(self) -> Dict[str, float]:
        if not self.samples:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": sum(self.samples) / self.count,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "max": max(self.samples),
        }


class Telemetry:
    """Named-metric registry with get-or-create accessors and JSON export."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        # per-tenant partitions (multi-tenant serving): child registries
        # keyed by tenant name, surfaced as a "tenants" block in snapshots.
        # Created lazily so single-tenant snapshots stay byte-identical to
        # the pre-tenant schema (no empty "tenants" key).
        self._tenants: Dict[str, "Telemetry"] = {}
        self._t0 = time.perf_counter()

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self._histograms.setdefault(name, Histogram())

    def tenant(self, name: str) -> "Telemetry":
        """Get-or-create the per-tenant child registry. The server writes
        each request's metrics to the global registry AND to its tenant's
        partition, so per-tenant SLO attainment / throughput / shed counts
        are first-class in every snapshot."""
        return self._tenants.setdefault(name, Telemetry())

    def tenant_names(self) -> List[str]:
        return sorted(self._tenants)

    def wall_s(self) -> float:
        return time.perf_counter() - self._t0

    def ratio(self, num: str, den: str) -> float:
        """Counter ratio with a zero-denominator guard — acceptance rate
        (spec_accepted_tokens / spec_proposed_tokens), hit rates, and any
        other derived fraction the summaries report."""
        d = self.counter(den).value
        return self.counter(num).value / d if d else 0.0

    @contextlib.contextmanager
    def timer(self, name: str):
        """Time a block into histogram `name` and accumulate the total into
        counter `name + "_total"` — the serving loop wraps prefetch-fence
        waits with this so stall time shows up in every snapshot."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.histogram(name).observe(dt)
            self.counter(name + "_total").inc(dt)

    def snapshot(self) -> dict:
        snap = {
            "wall_s": self.wall_s(),
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {
                k: {"last": g.value, "max": g.max} for k, g in self._gauges.items()
            },
            "histograms": {k: h.summary() for k, h in self._histograms.items()},
        }
        if self._tenants:
            snap["tenants"] = {
                name: t.snapshot() for name, t in sorted(self._tenants.items())
            }
        return snap

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
