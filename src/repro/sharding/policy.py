"""Sharding policy: best-effort PartitionSpecs for params, optimizer state,
inputs, and decode caches, per (arch × input-shape × mesh).

Rules (DESIGN.md §5) — every rule checks divisibility and falls back to
replication, so every assigned architecture lowers on every mesh:

* weights (2D+): last dim ("output features", incl. the MoE expert dim for
  routers / vocab for embeddings) -> `model`; second-to-last -> `data`
  (FSDP/ZeRO-style full sharding — required for the 235B-scale configs to
  fit 16 GB/chip).
* MoE expert stacks [G, E, d, f]: E -> `model` (expert parallelism),
  f -> `data`.
* batch dims of inputs -> ("pod", "data") when divisible.
* decode KV caches: seq dim -> `model` (flash-decode partial-softmax merge
  happens in shard_map, see models/attention.py), batch -> ("pod","data").
* `pod` axis: pure data parallelism across pods (params replicated over pod).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.attention import ShardingCtx
from repro.models.transformer import init_cache, init_params

Array = jax.Array


def _div(n: int, mesh: Mesh, axis: Optional[str]) -> bool:
    return axis is not None and n % int(np.prod([mesh.shape[a] for a in _tup(axis)])) == 0


def _tup(axis) -> Tuple[str, ...]:
    return axis if isinstance(axis, tuple) else (axis,)


def make_ctx(mesh: Optional[Mesh]) -> ShardingCtx:
    if mesh is None:
        return ShardingCtx()
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    return ShardingCtx(
        mesh=mesh,
        batch_axes=batch_axes or None,
        model_axis="model" if "model" in names else None,
        decode_seq_axis=None,  # enabled for decode shapes in serve specs
    )


def serve_ctx(mesh: Optional[Mesh], axis: str = "model") -> ShardingCtx:
    """Expert-parallel SERVING context: only the MoE slot pools (and the
    expert FFN inside shard_map) shard over `axis`; attention, the residual
    stream, and every non-expert weight stay replicated. That restriction is
    deliberate — it keeps the sharded serving path byte-identical to the
    single-device path (the only cross-device reduction is the expert
    combine psum, whose partials are exact), which the EP-serving
    differentials pin down."""
    if mesh is None:
        return ShardingCtx()
    return ShardingCtx(
        mesh=mesh,
        batch_axes=None,
        model_axis=None,
        expert_axis=axis if axis in mesh.axis_names else None,
    )


def slot_pool_spec(axis: str = "model") -> P:
    """PartitionSpec of one serving slot pool [G, S, ...]: the slot dim
    (dim 1) shards over the expert-parallel axis — shard m owns the
    contiguous global-slot range [m*S_loc, (m+1)*S_loc). Scale planes
    [G, S, 1, f] share the same spec, so int8-resident pools shard
    identically (ExpertStore builds its pool NamedShardings from this)."""
    return P(None, axis, None, None)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_EXPERT_KEYS = ("w_in", "w_gate", "w_out")


def _param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Greedy best-effort spec for one parameter."""
    model = "model" if "model" in mesh.axis_names else None
    data = "data" if "data" in mesh.axis_names else None

    ndim = len(shape)
    if ndim <= 1:
        return P()
    is_block = path.startswith("blocks") or path.startswith("enc_blocks")
    is_expert = is_block and any(f"moe/{k}" in path for k in _EXPERT_KEYS)

    entries: list = [None] * ndim
    if path == "embed":
        # [V, d]: vocab -> model so the (un)embedding logits land V-sharded
        # (a replicated [B,S,V] activation is the single biggest temp killer)
        if _div(shape[0], mesh, model):
            entries[0] = model
        if _div(shape[1], mesh, data):
            entries[1] = data
        return P(*entries)
    if path == "head":
        # [d, V]: vocab -> model (same reason), d -> data
        if _div(shape[1], mesh, model):
            entries[1] = model
        if _div(shape[0], mesh, data):
            entries[0] = data
        return P(*entries)
    if is_expert:
        # [G, E, d_in, d_out]: experts -> model, d_out -> data
        if _div(shape[1], mesh, model):
            entries[1] = model
        if _div(shape[3], mesh, data):
            entries[3] = data
        return P(*entries)

    if path.endswith("moe/router"):
        # router stays E-replicated: sharding E over `model` forces a
        # full-logits all-gather before every top_k (§Perf iteration 3a);
        # the matrix is tiny (d x E), so shard only the d dim over data.
        if _div(shape[ndim - 2], mesh, data):
            entries[ndim - 2] = data
        return P(*entries)

    # Megatron-style 1D pairing (§Perf iteration: down-projection pairing):
    #   up/column weights:   out -> model, in -> data
    #   down/row weights:    in  -> model, out -> data
    # so the intermediate activation stays model-sharded between the pair
    # and only one collective (psum/reduce-scatter) closes each block,
    # instead of an all-gather around every matmul.
    leaf = path.rsplit("/", 1)[-1]
    is_down = leaf in ("wo", "w_out", "down", "ffn_out", "out_proj", "dt_proj")
    out_dim, in_dim = ndim - 1, ndim - 2
    lead_ok = in_dim >= (1 if is_block and ndim >= 3 else 0)
    if is_down:
        if lead_ok and _div(shape[in_dim], mesh, model):
            entries[in_dim] = model
        if _div(shape[out_dim], mesh, data):
            entries[out_dim] = data
        return P(*entries)
    if _div(shape[out_dim], mesh, model):
        entries[out_dim] = model
    if lead_ok and _div(shape[in_dim], mesh, data):
        entries[in_dim] = data
    return P(*entries)


def _paths_and_specs(tree, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        specs.append(_param_spec(key, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_specs(cfg: ModelConfig, mesh: Mesh, key=None):
    """PartitionSpec pytree for init_params(cfg) — via eval_shape (no alloc)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    shapes = jax.eval_shape(partial(init_params, cfg=cfg), key)
    return _paths_and_specs(shapes, mesh)


def opt_specs(cfg: ModelConfig, mesh: Mesh, pspecs):
    """AdamW state: m/v shadow the param specs; t replicated."""
    return {"m": pspecs, "v": pspecs, "t": P()}


# ---------------------------------------------------------------------------
# input / cache specs
# ---------------------------------------------------------------------------


def batch_axes_for(mesh: Mesh, batch: int) -> Optional[Tuple[str, ...]]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return None
    ext = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % ext == 0:
        return axes
    # try data-only / pod-only
    for sub in (("data",), ("pod",)):
        if all(a in mesh.axis_names for a in sub):
            if batch % int(np.prod([mesh.shape[a] for a in sub])) == 0:
                return sub
    return None


def token_specs(mesh: Mesh, batch: int) -> P:
    return P(batch_axes_for(mesh, batch), None)


def decode_plan(
    mesh: Mesh, batch: int
) -> Tuple[Optional[Tuple[str, ...]], Optional[Tuple[str, ...]]]:
    """(batch axes, KV-seq axes) for decode.

    The cache seq dim always shards over `model` (heads are replicated in
    decode — the model axis is free); when the batch can't use the data axes
    (e.g. long_500k batch=1) the seq dim takes them too, maximising how much
    cache each chip must hold.
    """
    b_ax = batch_axes_for(mesh, batch)
    seq_axes = tuple(
        a for a in ("model",) + (("pod", "data") if b_ax is None else ())
        if a in mesh.axis_names
    )
    return b_ax, (seq_axes or None)


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq_budget: int, enc_len: int = 0):
    """Spec pytree matching init_cache(cfg, batch, seq_budget)."""
    b_ax, seq_axes = decode_plan(mesh, batch)
    model = "model" if "model" in mesh.axis_names else None
    shapes = jax.eval_shape(
        partial(init_cache, cfg, batch, seq_budget, enc_len)
    )

    def spec_for(path_key: str, shape) -> P:
        nd = len(shape.shape)
        if path_key in ("pos", "cross_len"):
            return P(b_ax)
        if any(t in path_key for t in ("/k", "/v", "cross_k", "cross_v")) and nd == 5:
            # [G, B, Sc, K, D] — seq -> flash-decode shard axes
            seq_ax = seq_axes if seq_axes and _div(shape.shape[2], mesh, seq_axes) else None
            return P(None, b_ax, seq_ax, None, None)
        # recurrent states [G, B, ...]: shard the widest trailing dim on model
        entries = [None, b_ax] + [None] * (nd - 2)
        for i in range(nd - 1, 1, -1):
            if _div(shape.shape[i], mesh, model):
                entries[i] = model
                break
        return P(*entries)

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        specs.append(spec_for(key, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
