import os
import sys

# tests must see the real (1-)device CPU backend — never the dry-run's 512.
# Exception: the CI multi-device job opts in with REPRO_MULTI_DEVICE_TESTS=1
# and forces a small simulated mesh for the EP-serving differentials
# (tests/test_ep_serving.py); everything else self-skips or is unaffected.
if "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""):
    assert os.environ.get("REPRO_MULTI_DEVICE_TESTS") == "1", (
        "tests must run without the dry-run XLA_FLAGS "
        "(set REPRO_MULTI_DEVICE_TESTS=1 for the multi-device CI job)"
    )

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.attention import ShardingCtx
from repro.models.transformer import init_params


# Per-test hang ceiling. CI installs pytest-timeout and sets PYTEST_TIMEOUT
# (a hung fence/join there kills the one test with a traceback instead of
# eating the job's timeout-minutes). Locally the plugin may be absent, so
# fall back to faulthandler: dump all thread stacks and hard-exit if a
# single test exceeds REPRO_TEST_TIMEOUT_S (0 disables). The fault-injection
# suite (tests/test_faults.py) is exactly where a supervision bug shows up
# as a silent deadlock — a stack dump at timeout is the difference between
# a diagnosable CI failure and a 30-minute mystery.
_FALLBACK_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT_S", "600"))
try:
    import pytest_timeout  # noqa: F401  (plugin handles timeouts itself)

    _HAVE_TIMEOUT_PLUGIN = True
except ImportError:
    _HAVE_TIMEOUT_PLUGIN = False

if not _HAVE_TIMEOUT_PLUGIN and _FALLBACK_TIMEOUT_S > 0:
    import faulthandler

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_protocol(item):
        faulthandler.dump_traceback_later(_FALLBACK_TIMEOUT_S, exit=True)
        try:
            yield
        finally:
            faulthandler.cancel_dump_traceback_later()


@pytest.fixture(scope="session")
def ctx():
    return ShardingCtx()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


_PARAM_CACHE = {}


def reduced_params(name: str, seed: int = 0):
    """Session-cached reduced-config params (init is the slow part)."""
    key = (name, seed)
    if key not in _PARAM_CACHE:
        cfg = get_config(name).reduced()
        _PARAM_CACHE[key] = (cfg, init_params(jax.random.PRNGKey(seed), cfg))
    return _PARAM_CACHE[key]
