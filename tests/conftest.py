import os
import sys

# tests must see the real (1-)device CPU backend — never the dry-run's 512.
# Exception: the CI multi-device job opts in with REPRO_MULTI_DEVICE_TESTS=1
# and forces a small simulated mesh for the EP-serving differentials
# (tests/test_ep_serving.py); everything else self-skips or is unaffected.
if "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""):
    assert os.environ.get("REPRO_MULTI_DEVICE_TESTS") == "1", (
        "tests must run without the dry-run XLA_FLAGS "
        "(set REPRO_MULTI_DEVICE_TESTS=1 for the multi-device CI job)"
    )

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.attention import ShardingCtx
from repro.models.transformer import init_params


@pytest.fixture(scope="session")
def ctx():
    return ShardingCtx()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


_PARAM_CACHE = {}


def reduced_params(name: str, seed: int = 0):
    """Session-cached reduced-config params (init is the slow part)."""
    key = (name, seed)
    if key not in _PARAM_CACHE:
        cfg = get_config(name).reduced()
        _PARAM_CACHE[key] = (cfg, init_params(jax.random.PRNGKey(seed), cfg))
    return _PARAM_CACHE[key]
