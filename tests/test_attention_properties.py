"""Attention invariants (hypothesis property tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.attention import ShardingCtx, attend_full, init_attention
from repro.models.transformer import forward, init_params

CTX = ShardingCtx()


def _cfg(window=0, softcap=0.0, qk_norm=False):
    base = get_config("smollm-135m").reduced()
    return dataclasses.replace(
        base,
        head_dim=16,
        attn=dataclasses.replace(
            base.attn, window=window, logit_softcap=softcap, qk_norm=qk_norm
        ),
    )


@given(seed=st.integers(0, 50), t=st.integers(1, 10))
@settings(max_examples=10, deadline=None)
def test_causality(seed, t):
    """Changing tokens at positions > t must not change logits at <= t."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(seed)
    S = 12
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    toks2 = toks.at[0, t + 1 :].set(
        (toks[0, t + 1 :] + 7) % cfg.vocab_size
    )
    a = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"]
    b = forward(params, cfg, CTX, toks2, scan_mode="scan")["logits"]
    np.testing.assert_allclose(
        np.asarray(a[0, : t + 1], np.float32),
        np.asarray(b[0, : t + 1], np.float32),
        atol=1e-5,
    )


@given(seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_batch_permutation_equivariance(seed):
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (4, 8), 0, cfg.vocab_size)
    perm = np.array([2, 0, 3, 1])
    a = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"]
    b = forward(params, cfg, CTX, toks[perm], scan_mode="scan")["logits"]
    np.testing.assert_allclose(
        np.asarray(a, np.float32)[perm], np.asarray(b, np.float32), atol=1e-5
    )


@given(window=st.integers(1, 3))
@settings(max_examples=6, deadline=None)
def test_window_limits_receptive_field(window):
    """With window w and L stacked local layers, logits at position t depend
    only on tokens in (t - L·w, t] — perturbing older tokens changes nothing."""
    cfg = _cfg(window=window)
    cfg = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, layer_pattern=("local",))
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    S, t = 14, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    cutoff = t - cfg.n_layers * window  # strictly outside the stacked field
    if cutoff <= 0:
        return
    toks2 = toks.at[0, :cutoff].set((toks[0, :cutoff] + 3) % cfg.vocab_size)
    a = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"]
    b = forward(params, cfg, CTX, toks2, scan_mode="scan")["logits"]
    np.testing.assert_allclose(
        np.asarray(a[0, t], np.float32), np.asarray(b[0, t], np.float32), atol=1e-5
    )


def test_softcap_bounds_attention_logits():
    """gemma2 softcap: outputs finite & bounded even with huge activations."""
    cfg = _cfg(softcap=50.0)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = 100.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y = attend_full(p, x.astype(cfg.dtype), cfg, 0, CTX)
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


@given(scale=st.floats(0.5, 4.0))
@settings(max_examples=6, deadline=None)
def test_qk_norm_scale_invariance(scale):
    """With qk-norm, scaling the attention input barely moves the attention
    pattern (per-head RMS normalisation) — outputs stay finite and close in
    direction."""
    cfg = _cfg(qk_norm=True)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 6, cfg.d_model))
    y1 = attend_full(p, x.astype(cfg.dtype), cfg, 0, CTX)
    y2 = attend_full(p, (x * scale).astype(cfg.dtype), cfg, 0, CTX)
    assert bool(jnp.isfinite(y2.astype(jnp.float32)).all())
    # v path scales linearly; direction of outputs preserved
    c = jnp.sum(y1 * y2) / (jnp.linalg.norm(y1) * jnp.linalg.norm(y2) + 1e-9)
    assert float(c) > 0.95
