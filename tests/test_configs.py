"""Config registry: the 10 assigned architectures (+ the paper's Switch family)."""
import pytest

from repro.configs.base import INPUT_SHAPES, get_config, list_configs, shape_supported

ASSIGNED = {
    # name: (family, n_layers, d_model, n_heads, n_kv, d_ff, vocab)
    "gemma2-9b": ("dense", 42, 3584, 16, 8, 14336, 256000),
    "qwen3-moe-235b-a22b": ("moe", 94, 4096, 64, 4, 0, 151936),
    "stablelm-12b": ("dense", 40, 5120, 32, 8, 13824, 100352),
    "hymba-1.5b": ("hybrid", 32, 1600, 25, 5, 5504, 32001),
    "qwen2-1.5b": ("dense", 28, 1536, 12, 2, 8960, 151936),
    "chameleon-34b": ("vlm", 48, 8192, 64, 8, 22016, 65536),
    "seamless-m4t-medium": ("audio", 12, 1024, 16, 16, 4096, 256206),
    "xlstm-125m": ("ssm", 12, 768, 4, 4, 0, 50304),
    "deepseek-moe-16b": ("moe", 28, 2048, 16, 16, 0, 102400),
    "smollm-135m": ("dense", 30, 576, 9, 3, 1536, 49152),
}


def test_all_assigned_registered():
    names = set(list_configs())
    for a in ASSIGNED:
        assert a in names
    for e in (8, 64, 128, 256):
        assert f"switch-base-{e}" in names


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_exact_spec(name):
    fam, L, d, H, K, ff, V = ASSIGNED[name]
    cfg = get_config(name)
    assert cfg.family == fam
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == K
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    assert cfg.citation


def test_moe_details():
    q = get_config("qwen3-moe-235b-a22b")
    assert (q.moe.num_experts, q.moe.top_k, q.moe.d_expert) == (128, 8, 1536)
    ds = get_config("deepseek-moe-16b")
    assert (ds.moe.num_experts, ds.moe.top_k) == (64, 6)
    assert ds.moe.num_shared_experts == 2
    hy = get_config("hymba-1.5b")
    assert hy.ssm.state_dim == 16 and hy.block_kind == "hymba"
    xl = get_config("xlstm-125m")
    assert xl.block_kind == "xlstm" and set(xl.ssm.xlstm_pattern) == {"m", "s"}
    g = get_config("gemma2-9b")
    assert g.attn.logit_softcap == 50.0 and g.final_logit_softcap == 30.0
    assert g.attn.layer_pattern == ("local", "global") and g.attn.window == 4096
    assert get_config("qwen2-1.5b").attn.qkv_bias
    sm = get_config("seamless-m4t-medium")
    assert sm.enc_dec and sm.n_enc_layers == 12


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_reduced_is_small(name):
    r = get_config(name).reduced()
    assert r.n_layers == 2
    assert r.d_model <= 512
    assert r.moe.num_experts <= 4
    assert r.vocab_size <= 512
    # family-defining features survive reduction
    assert r.family == get_config(name).family
    assert r.block_kind == get_config(name).block_kind


def test_param_counts_switch_table2():
    """Table 2: MoE params dominate, growing with expert count."""
    prev_frac = 0.0
    for e in (8, 64, 128, 256):
        cfg = get_config(f"switch-base-{e}")
        c = cfg.param_counts()
        frac = c["moe"] / c["total"]
        assert frac > prev_frac
        prev_frac = frac
    assert prev_frac > 0.9  # switch-base-256: >90% of params are experts


def test_shape_support_matrix():
    n = 0
    for name in ASSIGNED:
        cfg = get_config(name)
        for s in INPUT_SHAPES.values():
            ok, why = shape_supported(cfg, s)
            n += ok
            if not ok:
                assert s.name == "long_500k" and why
    # exactly 3 archs run long_500k (xlstm, hymba, gemma2)
    assert n == 10 * 3 + 3


def test_param_count_magnitudes():
    """Config param totals should land near the advertised model sizes."""
    expect = {
        "gemma2-9b": (8e9, 12e9),
        "qwen3-moe-235b-a22b": (180e9, 280e9),
        "stablelm-12b": (10e9, 14e9),
        "chameleon-34b": (28e9, 40e9),
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "smollm-135m": (0.1e9, 0.2e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
    }
    for name, (lo, hi) in expect.items():
        total = get_config(name).param_counts()["total"]
        assert lo < total < hi, (name, total)
