"""Decode-vs-full-forward parity: the serving path must agree with training
forward for every architecture family, including ring-buffer sliding-window
caches and recurrent states. Also scan-vs-associative parity for SSMs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.attention import ShardingCtx
from repro.models.transformer import decode_step, forward, init_cache, init_params

CTX = ShardingCtx()

FAMS = ["smollm-135m", "gemma2-9b", "qwen2-1.5b", "xlstm-125m", "hymba-1.5b",
        "switch-base-8", "deepseek-moe-16b", "chameleon-34b"]


def _setup(name, high_capacity=True):
    cfg = get_config(name).reduced()
    if cfg.moe.enabled and high_capacity:
        # decode never drops tokens; match it in the full forward
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0)
        )
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_forward(name):
    cfg, params = _setup(name)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    ref = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"][:, -1]
    cache = init_cache(cfg, B, 16)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, CTX))
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t])
    err = float(jnp.abs(logits - ref).max() / jnp.abs(ref).max())
    assert err < 5e-3, err


def test_ring_buffer_window_decode():
    """Cache smaller than the sequence: sliding-window ring must still match
    a windowed full forward."""
    cfg = get_config("hymba-1.5b").reduced()  # window 64 after reduction
    cfg = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, window=8)
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    ref = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"][:, -1]
    cache = init_cache(cfg, B, 8)  # ring cache = window size < S
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, CTX))
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t])
    err = float(jnp.abs(logits - ref).max() / jnp.abs(ref).max())
    assert err < 5e-3, err


def test_banded_window_attention_matches_full():
    """Sliding-window KV banding (§Perf) == full-keys masked attention."""
    import repro.models.attention as A
    from repro.models.attention import attend_full, init_attention

    cfg = get_config("hymba-1.5b").reduced()
    cfg = dataclasses.replace(
        cfg, head_dim=32, attn=dataclasses.replace(cfg.attn, window=300)
    )
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2100, cfg.d_model)).astype(cfg.dtype)
    y_banded = attend_full(p, x, cfg, 0, CTX)  # S > window + Q_CHUNK => banded
    orig = A.Q_CHUNK
    try:
        A.Q_CHUNK = 4096  # force the single-chunk (unbanded) path
        y_full = attend_full(p, x, cfg, 0, CTX)
    finally:
        A.Q_CHUNK = orig
    err = float(jnp.abs(
        y_banded.astype(jnp.float32) - y_full.astype(jnp.float32)
    ).max())
    assert err < 5e-3, err


@pytest.mark.parametrize("name", ["xlstm-125m", "hymba-1.5b"])
def test_scan_vs_assoc(name):
    cfg = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 150), 0, cfg.vocab_size)
    a = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"]
    b = forward(params, cfg, CTX, toks, scan_mode="assoc")["logits"]
    err = float(jnp.abs(a - b).max() / jnp.abs(a).max())
    assert err < 1e-4, err


def test_encdec_decode_with_cross_cache():
    """seamless: decoder decode with precomputed cross-attention caches."""
    from repro.models.attention import _project_kv
    from repro.models.layers import rmsnorm
    from repro.models.transformer import _run_stack

    cfg = get_config("seamless-m4t-medium").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, E = 2, 10, 8
    enc = jax.random.normal(jax.random.PRNGKey(3), (B, E, cfg.d_model)).astype(cfg.dtype)
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size)
    ref = forward(params, cfg, CTX, toks, enc_input=enc, scan_mode="scan")["logits"][:, -1]

    e, _ = _run_stack(params["enc_blocks"], enc, cfg, CTX, False, None, None, False, "scan")
    enc_out = rmsnorm(params["enc_norm"], e, cfg.norm_eps)
    cache = init_cache(cfg, B, 16, enc_len=E)
    n_groups = jax.tree.leaves(params["blocks"])[0].shape[0]
    ck, cv = [], []
    for g in range(n_groups):
        bp = jax.tree.map(lambda x: x[g], params["blocks"])["sub0"]
        k, v = _project_kv(bp["xattn"], enc_out, cfg)
        ck.append(k); cv.append(v)
    cache["sub0"]["cross_k"] = jnp.stack(ck)
    cache["sub0"]["cross_v"] = jnp.stack(cv)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, CTX))
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t])
    err = float(jnp.abs(logits - ref).max() / jnp.abs(ref).max())
    assert err < 5e-3, err
