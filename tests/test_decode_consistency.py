"""Decode-vs-full-forward parity: the serving path must agree with training
forward for every architecture family, including ring-buffer sliding-window
caches and recurrent states. Also scan-vs-associative parity for SSMs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.attention import ShardingCtx
from repro.models.transformer import decode_step, forward, init_cache, init_params

CTX = ShardingCtx()

FAMS = ["smollm-135m", "gemma2-9b", "qwen2-1.5b", "xlstm-125m", "hymba-1.5b",
        "switch-base-8", "deepseek-moe-16b", "chameleon-34b"]


def _setup(name, high_capacity=True):
    cfg = get_config(name).reduced()
    if cfg.moe.enabled and high_capacity:
        # decode never drops tokens; match it in the full forward
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0)
        )
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_forward(name):
    cfg, params = _setup(name)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    ref = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"][:, -1]
    cache = init_cache(cfg, B, 16)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, CTX))
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t])
    err = float(jnp.abs(logits - ref).max() / jnp.abs(ref).max())
    assert err < 5e-3, err


def test_ring_buffer_window_decode():
    """Cache smaller than the sequence: sliding-window ring must still match
    a windowed full forward."""
    cfg = get_config("hymba-1.5b").reduced()  # window 64 after reduction
    cfg = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, window=8)
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    ref = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"][:, -1]
    cache = init_cache(cfg, B, 8)  # ring cache = window size < S
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, CTX))
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t])
    err = float(jnp.abs(logits - ref).max() / jnp.abs(ref).max())
    assert err < 5e-3, err


def test_banded_window_attention_matches_full():
    """Sliding-window KV banding (§Perf) == full-keys masked attention."""
    import repro.models.attention as A
    from repro.models.attention import attend_full, init_attention

    cfg = get_config("hymba-1.5b").reduced()
    cfg = dataclasses.replace(
        cfg, head_dim=32, attn=dataclasses.replace(cfg.attn, window=300)
    )
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2100, cfg.d_model)).astype(cfg.dtype)
    y_banded = attend_full(p, x, cfg, 0, CTX)  # S > window + Q_CHUNK => banded
    orig = A.Q_CHUNK
    try:
        A.Q_CHUNK = 4096  # force the single-chunk (unbanded) path
        y_full = attend_full(p, x, cfg, 0, CTX)
    finally:
        A.Q_CHUNK = orig
    err = float(jnp.abs(
        y_banded.astype(jnp.float32) - y_full.astype(jnp.float32)
    ).max())
    assert err < 5e-3, err


@pytest.mark.parametrize("name", ["xlstm-125m", "hymba-1.5b"])
def test_scan_vs_assoc(name):
    cfg = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 150), 0, cfg.vocab_size)
    a = forward(params, cfg, CTX, toks, scan_mode="scan")["logits"]
    b = forward(params, cfg, CTX, toks, scan_mode="assoc")["logits"]
    err = float(jnp.abs(a - b).max() / jnp.abs(a).max())
    assert err < 1e-4, err


def test_server_lanes_match_one_shot_prefill_at_staggered_positions():
    """Differential: decode via continuous-batch lanes == one-shot prefill
    of the same token stream (tolerance-bounded logits), with lanes at
    DIFFERENT sequence positions — prompts of different lengths join and
    leave mid-flight, so the masked decode batch mixes positions."""
    import dataclasses as dc

    from repro.core.decode_engine import hash_fn_step
    from repro.core.hash_fn import init_hash_fn
    from repro.core.offload import ExpertStore
    from repro.models.transformer import n_moe_layers
    from repro.serving import Request, RequestServer

    cfg = get_config("switch-base-8").reduced()
    cfg = dc.replace(
        cfg, n_layers=2,
        moe=dc.replace(cfg.moe, capacity_factor=100.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    E, L = cfg.moe.num_experts, n_moe_layers(cfg)

    rng = np.random.default_rng(5)
    plens = [5, 9, 13]          # different buckets => staggered joins
    gens = [7, 5, 4]
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (p,)).astype(np.int32),
            max_new_tokens=g,
        )
        for i, (p, g) in enumerate(zip(plens, gens))
    ]
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=E, max_lanes=3,
        max_prefill_batch=2, buckets=(8, 16), cache_len=32,
        keep_decode_logits=True,
    )
    srv.run(reqs, realtime=False)
    assert len(srv.completed) == 3
    # the point of the test: decode actually interleaved lanes that sit at
    # different sequence positions (different prompt lengths + join times)
    assert srv.telemetry.gauge("active_lanes").max > 1

    k = srv.k
    for req in srv.completed:
        P, gen = req.prompt_len, req.generated
        seq = np.concatenate([req.prompt, np.asarray(gen[:-1], np.int32)])
        # replay the routing the server used: bidirectional table over the
        # prompt + incremental causal predictions per generated position
        table = srv.engine.build_table(req.rid, req.prompt[None, :])
        ids = np.zeros((L, 1, len(seq), k), np.int32)
        w = np.zeros((L, 1, len(seq), k), np.float32)
        ids[:, :, :P] = table.expert_ids
        w[:, :, :P] = table.weights
        state = srv._hash_prefill(
            hp, params["embed"], jnp.asarray(req.prompt[None, :]),
            jnp.asarray(np.array([P], np.int32)),
        )
        for j, tok in enumerate(gen[:-1]):
            emb = jnp.take(params["embed"], jnp.asarray([tok]), axis=0)
            logits_h, state = hash_fn_step(hp, emb, state, E)
            vals, top = jax.lax.top_k(logits_h, k)
            ids[:, 0, P + j] = np.asarray(top)[0]
            w[:, 0, P + j] = np.asarray(jax.nn.softmax(vals, axis=-1))[0]

        from repro.core.hash_table import HashTable

        store = ExpertStore(cfg, params, slots_per_layer=E)
        full = HashTable(0, ids, w)
        slot_ids, ww = store.translate(full, store.prepare(full))
        out = forward(
            store.serve_params, cfg, CTX, jnp.asarray(seq[None, :]),
            routing_override=(jnp.asarray(slot_ids), jnp.asarray(ww)),
        )["logits"]
        ref = np.asarray(out, np.float32)[0]

        # tokens must match exactly; decode-lane logits within tolerance
        pred = np.argmax(ref[P - 1:], axis=-1)
        np.testing.assert_array_equal(pred, np.asarray(gen))
        assert req.decode_logits is not None
        assert len(req.decode_logits) == len(gen) - 1
        for j, lane_logits in enumerate(req.decode_logits):
            one_shot = ref[P + j]
            err = np.abs(lane_logits.astype(np.float32) - one_shot).max()
            denom = max(np.abs(one_shot).max(), 1e-9)
            assert err / denom < 5e-3, (req.rid, j, err / denom)


def test_encdec_decode_with_cross_cache():
    """seamless: decoder decode with precomputed cross-attention caches."""
    from repro.models.attention import _project_kv
    from repro.models.layers import rmsnorm
    from repro.models.transformer import _run_stack

    cfg = get_config("seamless-m4t-medium").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, E = 2, 10, 8
    enc = jax.random.normal(jax.random.PRNGKey(3), (B, E, cfg.d_model)).astype(cfg.dtype)
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size)
    ref = forward(params, cfg, CTX, toks, enc_input=enc, scan_mode="scan")["logits"][:, -1]

    e, _ = _run_stack(params["enc_blocks"], enc, cfg, CTX, False, None, None, False, "scan")
    enc_out = rmsnorm(params["enc_norm"], e, cfg.norm_eps)
    cache = init_cache(cfg, B, 16, enc_len=E)
    n_groups = jax.tree.leaves(params["blocks"])[0].shape[0]
    ck, cv = [], []
    for g in range(n_groups):
        bp = jax.tree.map(lambda x: x[g], params["blocks"])["sub0"]
        k, v = _project_kv(bp["xattn"], enc_out, cfg)
        ck.append(k); cv.append(v)
    cache["sub0"]["cross_k"] = jnp.stack(ck)
    cache["sub0"]["cross_v"] = jnp.stack(cv)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, CTX))
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t])
    err = float(jnp.abs(logits - ref).max() / jnp.abs(ref).max())
    assert err < 5e-3, err
