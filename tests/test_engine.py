"""SiDA engine + serving baselines: parity, threading, memory accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.baselines import OnDemandServer, PrefetchAllServer, StandardServer
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.hash_table import HashTable
from repro.models.attention import ShardingCtx
from repro.models.moe import router_topk
from repro.models.transformer import forward, init_params, n_moe_layers

CTX = ShardingCtx()


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=4,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg), cfg.moe.num_experts, d_h=16
    )
    batches = [np.random.default_rng(i).integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)
               for i in range(4)]
    return cfg, params, hp, batches


class OracleEngine(SiDAEngine):
    """Hash function replaced by the true router (100% hit rate)."""

    def __init__(self, *a, true_params=None, **kw):
        super().__init__(*a, **kw)
        self._true_params = true_params

    def build_table(self, j, tokens):
        cfg = self.cfg
        out = forward(
            self._true_params, cfg, CTX, jnp.asarray(tokens), collect_router_logits=True
        )
        rl = out["router_logits"]
        E = cfg.moe.num_experts
        ids, w = router_topk(rl.reshape(-1, E), cfg.moe.top_k)
        L = rl.shape[0]
        return HashTable(
            j,
            np.asarray(ids).reshape(L, *tokens.shape, -1),
            np.asarray(w).reshape(L, *tokens.shape, -1),
        )


def test_oracle_engine_matches_standard(setup):
    cfg, params, hp, batches = setup
    std = StandardServer(cfg, params)
    eng = OracleEngine(cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
                       true_params=params)
    eng.serve(batches, threaded=True)
    for i, toks in enumerate(batches):
        ref = np.asarray(std._fwd(params, jnp.asarray(toks)))
        got = eng.results[i]
        assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-4


def test_threaded_equals_sequential(setup):
    cfg, params, hp, batches = setup
    e1 = OracleEngine(cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
                      true_params=params)
    e1.serve(batches, threaded=True)
    r_threaded = [r.copy() for r in e1.results]
    e2 = OracleEngine(cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
                      true_params=params)
    e2.serve(batches, threaded=False)
    for a, b in zip(r_threaded, e2.results):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_real_hash_engine_runs(setup):
    """Untrained hash fn: engine must still serve (degraded quality is fine)."""
    cfg, params, hp, batches = setup
    eng = SiDAEngine(cfg, params, hp, slots_per_layer=2)
    m = eng.serve(batches)
    assert len(m.latency_s) == len(batches)
    assert m.tokens == sum(int(np.prod(b.shape)) for b in batches)
    assert all(np.isfinite(r).all() for r in eng.results)


def test_memory_saving_metric(setup):
    cfg, params, hp, batches = setup
    eng = SiDAEngine(cfg, params, hp, slots_per_layer=2)
    ms = eng.memory_saving()
    # 2 slots of 4 experts resident => 50% expert-memory reduction
    assert abs(ms["reduction"] - 0.5) < 1e-6
    std = StandardServer(cfg, params)
    assert eng.device_memory_bytes() < std.device_memory_bytes()


def test_ondemand_prefetchall_parity(setup):
    cfg, params, hp, batches = setup
    std = StandardServer(cfg, params)
    ref = np.asarray(std._fwd(params, jnp.asarray(batches[0])))
    od = OnDemandServer(cfg, params, slots_per_layer=cfg.moe.num_experts)
    pf = PrefetchAllServer(cfg, params, slots_per_layer=2)
    got_od = np.asarray(od._forward_batch(batches[0]))
    got_pf = np.asarray(pf._forward_batch(batches[0]))
    assert np.abs(got_od - ref).max() / np.abs(ref).max() < 1e-4
    assert np.abs(got_pf - ref).max() / np.abs(ref).max() < 1e-4


def test_serve_metrics_fields(setup):
    cfg, params, hp, batches = setup
    std = StandardServer(cfg, params)
    m = std.serve(batches)
    s = m.summary()
    assert s["throughput_tok_s"] > 0
    assert s["mean_latency_s"] > 0
