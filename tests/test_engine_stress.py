"""Engine concurrency/robustness + Pallas-in-model integration tests."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.hash_table import HashTable, HashTableQueue
from repro.models.attention import ShardingCtx
from repro.models.moe import apply_expert_stack_blocked, init_moe
from repro.models.transformer import init_params, n_moe_layers

CTX = ShardingCtx()

# concurrency stress sweeps: long-running — out of tier-1
pytestmark = pytest.mark.slow


def test_hash_table_queue_fifo_and_close():
    q = HashTableQueue(maxsize=4)
    tables = [
        HashTable(i, np.zeros((1, 1, 2, 1), np.int32), np.ones((1, 1, 2, 1), np.float32))
        for i in range(3)
    ]
    for t in tables:
        q.put(t)
    q.close()
    got = [q.get() for _ in range(4)]
    assert [t.batch_index for t in got[:3]] == [0, 1, 2]
    assert got[3] is None


def test_hash_table_stats_and_mass():
    ids = np.array([[[[0], [0], [1], [2]]]], np.int32)  # [1,1,4,1]
    w = np.array([[[[0.5], [0.3], [0.9], [0.1]]]], np.float32)
    t = HashTable(0, ids, w)
    act = t.active_experts(0)
    assert act[0] == 0  # most used first
    mass = t.activation_mass(0, 4)
    np.testing.assert_allclose(mass, [0.8, 0.9, 0.1, 0.0], atol=1e-6)
    st = t.activation_stats(4)
    assert st["idle_ratio"] == pytest.approx(0.25)


def test_engine_many_batches_threaded_stress():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg), cfg.moe.num_experts, d_h=16
    )
    eng = SiDAEngine(cfg, params, hp, slots_per_layer=2)
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32) for _ in range(12)]
    m = eng.serve(batches, threaded=True)
    assert len(m.latency_s) == 12
    assert all(r is not None and np.isfinite(r).all() for r in eng.results)
    # determinism under threading: same batches, fresh engine, same results
    eng2 = SiDAEngine(cfg, params, hp, slots_per_layer=2)
    eng2.serve(batches, threaded=True)
    for a, b in zip(eng.results, eng2.results):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_pallas_expert_stack_in_model_path():
    """apply_expert_stack_blocked(use_pallas=True) == jnp path (interpret)."""
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, d_expert=128)
    )
    p = init_moe(jax.random.PRNGKey(0), cfg)
    xe = jax.random.normal(
        jax.random.PRNGKey(1), (2, cfg.moe.num_experts, 64, cfg.d_model)
    ).astype(jnp.float32)
    p32 = jax.tree.map(lambda x: x.astype(jnp.float32), p)
    a = apply_expert_stack_blocked(p32, xe, cfg, use_pallas=False)
    b = apply_expert_stack_blocked(p32, xe, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)
