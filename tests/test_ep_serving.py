"""Expert-parallel sharded serving.

Two layers of guarantees:

  * bookkeeping contracts (single-device, always run) — a sharded
    ExpertStore constrains every expert to its home shard's slot
    partition, eviction/pinning never cross a shard boundary, and the
    PrefetchPipeline fans tickets out into per-shard transfer queues whose
    fences still deliver exact host rows;

  * EP-serving differentials (need a forced multi-device host mesh — the
    CI job sets XLA_FLAGS=--xla_force_host_platform_device_count=4 with
    REPRO_MULTI_DEVICE_TESTS=1) — the sharded RequestServer / decode
    engine produce greedy outputs BYTE-IDENTICAL to the single-device
    path, for fp and int8-resident slots, sync and async prefetch, vanilla
    and speculative decode, with the (fused-dequant) expert FFN running
    inside shard_map when REPRO_MOE_PALLAS=1.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.configs.base import get_config
from repro.core.decode_engine import SiDADecodeEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.hash_table import HashTable
from repro.core.offload import (
    EXPERT_TENSORS,
    ExpertStore,
    PrefetchPipeline,
    ShardedStoreConfig,
)
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, init_params, n_moe_layers
from repro.serving import RequestServer, poisson_requests
from repro.sharding.policy import serve_ctx, slot_pool_spec

CTX = ShardingCtx()


def needs_devices(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} simulated devices "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count=4 "
               f"+ REPRO_MULTI_DEVICE_TESTS=1)",
    )


def _e8_system(draft: bool = False):
    """Miniature E8 Switch (reduced() caps experts at 4, so rebuild) with
    top_k=1 — the regime where the EP combine psum is exact bit-for-bit."""
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg,
        moe=dataclasses.replace(
            cfg.moe, num_experts=8, d_expert=64, capacity_factor=4.0
        ),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16, draft=draft,
    )
    return cfg, params, hp


def _ep(ep_shards: int, replicate_hot: int = 0):
    """(ctx, sharded) for an EP run on the first `ep_shards` host devices."""
    from repro.launch.mesh import make_ep_mesh

    return (
        serve_ctx(make_ep_mesh(ep_shards)),
        ShardedStoreConfig(ep_shards=ep_shards, replicate_hot=replicate_hot),
    )


def _table(L, E, B=1, S=4, k=1, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, E, (L, B, S, k)).astype(np.int32)
    w = rng.random((L, B, S, k)).astype(np.float32)
    return HashTable(0, ids, w)


# ---------------------------------------------------------------------------
# sharded-store bookkeeping (single-device: shard bookkeeping is host-side)
# ---------------------------------------------------------------------------


def test_home_shard_placements():
    mod = ShardedStoreConfig(ep_shards=4, placement="mod")
    np.testing.assert_array_equal(mod.home_shards(8), [0, 1, 2, 3, 0, 1, 2, 3])
    blk = ShardedStoreConfig(ep_shards=4, placement="block")
    np.testing.assert_array_equal(blk.home_shards(8), [0, 0, 1, 1, 2, 2, 3, 3])
    assert not ShardedStoreConfig().enabled
    assert ShardedStoreConfig(ep_shards=2).enabled


def test_slot_pool_spec_shards_slot_dim():
    spec = slot_pool_spec("model")
    assert tuple(spec) == (None, "model", None, None)


@pytest.mark.parametrize("placement", ["mod", "block"])
def test_sharded_store_plans_within_home_partition(placement):
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(
        cfg, params, slots_per_layer=4,
        sharded=ShardedStoreConfig(ep_shards=2, placement=placement),
    )
    assert st.shards == 2 and st.S_loc == 2
    trans = st.prepare(_table(st.L, st.E, S=8, seed=3))
    local = st.local_trans(trans)
    for (g, s), res in st.resident.items():
        for e, slot in res.items():
            assert slot in st.shard_slots(st.shard_of(e)), (e, slot)
    # local translation = global - home shard's base, misses stay -1
    for l in range(st.L):
        for e in range(st.E):
            if trans[l, e] >= 0:
                assert local[l, e] == trans[l, e] - st.shard_of(e) * st.S_loc
                assert 0 <= local[l, e] < st.S_loc
            else:
                assert local[l, e] == -1


def test_sharded_eviction_never_crosses_shards():
    """Overflowing one shard's partition evicts only that shard's
    residents; the other shard's experts are untouched."""
    cfg, params = reduced_params("switch-base-8")
    # 4 experts, 2 shards ("mod": shard0={0,2}, shard1={1,3}), 1 slot each
    st = ExpertStore(
        cfg, params, slots_per_layer=2, sharded=ShardedStoreConfig(ep_shards=2),
    )
    st.prepare_layer(0, np.array([0, 1]))      # shard0 <- e0, shard1 <- e1
    g, s = st.layer_to_gs(0)
    slot1 = st.resident[(g, s)][1]
    st.prepare_layer(0, np.array([2]))         # shard0 overflows: evicts e0
    res = st.resident[(g, s)]
    assert 2 in res and 0 not in res
    assert res[1] == slot1, "shard 1's resident was disturbed"
    assert st.stats.evictions == 1


def test_sharded_pinning_protects_per_shard():
    """A pinned expert filling its home shard drops later same-shard loads
    (stats.dropped) while the other shard keeps loading normally."""
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(
        cfg, params, slots_per_layer=2, sharded=ShardedStoreConfig(ep_shards=2),
    )
    for l in range(st.L):
        st.pin_experts(l, [0])
    st.prepare_layer(0, np.array([0]))
    st.prepare_layer(0, np.array([2, 1]))      # e2: shard0 full+pinned; e1: shard1
    g, s = st.layer_to_gs(0)
    res = st.resident[(g, s)]
    assert 0 in res and 1 in res and 2 not in res
    assert st.stats.dropped == 1


def test_sharded_translate_renormalizes_dropped_experts():
    """Per-shard budgets drop differently than a global pool would, but the
    miss renormalization contract is unchanged: surviving weights are
    rescaled to the predicted α mass, all-miss tokens keep weight 0."""
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(
        cfg, params, slots_per_layer=2, sharded=ShardedStoreConfig(ep_shards=2),
    )
    L, E = st.L, st.E
    ids = np.zeros((L, 1, 2, 2), np.int32)
    ids[..., 0, :] = [0, 2]                    # both shard 0: one must drop
    ids[..., 1, :] = [0, 2]
    w = np.full((L, 1, 2, 2), 0.5, np.float32)
    table = HashTable(0, ids, w)
    slot_ids, ww = st.translate(table, st.prepare(table))
    assert st.stats.dropped > 0
    # each token keeps its full 1.0 α mass on the surviving expert
    np.testing.assert_allclose(ww.sum(-1), np.ones((L, 1, 2)), rtol=1e-6)
    assert (ww == 0).any(), "the dropped expert must carry zero weight"


def test_sharded_prefetch_fans_out_per_shard_queues():
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(
        cfg, params, slots_per_layer=4, sharded=ShardedStoreConfig(ep_shards=2),
    )
    assert len(st.free[(0, st.moe_subs[0])]) == 2  # per-shard free lists
    pipe = PrefetchPipeline(st, depth=2)
    try:
        assert len(pipe._jobs) == 2 and len(pipe._threads) == 2
        for it in range(4):
            t = _table(st.L, st.E, S=4, seed=it)
            tk = pipe.submit(t)
            assert tk.wait(timeout=30)
            _, wts = st.translate(t, tk.trans)
            assert (wts > 0).all()
            tk.release()
        # both shards actually moved bytes through their own queue
        assert set(pipe.stats.uploads_by_shard) == {0, 1}
        assert sum(pipe.stats.uploads_by_shard.values()) == pipe.stats.uploads
        # fenced consumers see exact host rows
        for l in range(st.L):
            g, s = st.layer_to_gs(l)
            moe_p = st.serve_params["blocks"][f"sub{s}"]["moe"]
            for e, slot in st.resident[(g, s)].items():
                for t in EXPERT_TENSORS:
                    np.testing.assert_array_equal(
                        np.asarray(moe_p[t][g, slot]),
                        st.host[f"sub{s}"][t][g, e],
                    )
    finally:
        pipe.close()
    assert not any(t.is_alive() for t in pipe._threads)


def _expert_table(L, experts):
    """Table routing one token per listed expert at every MoE layer."""
    n = len(experts)
    ids = np.tile(
        np.asarray(experts, np.int32).reshape(1, 1, n, 1), (L, 1, 1, 1)
    )
    return HashTable(0, ids, np.ones((L, 1, n, 1), np.float32))


def test_warm_backpressure_is_per_destination_shard():
    """A backlogged shard's warm queue suppresses warming submits only for
    tables whose experts live on that shard — idle shards keep warming."""
    cfg, params = reduced_params("switch-base-8")  # 4 experts; mod: {0,2}|{1,3}
    st = ExpertStore(
        cfg, params, slots_per_layer=4, sharded=ShardedStoreConfig(ep_shards=2),
    )
    pipe = PrefetchPipeline(st, depth=1)
    try:
        # fake a backlog on shard 0's warm queue (no notify => not drained)
        with pipe._jobs_cv:
            pipe._jobs[0][2].append({})
        assert pipe.submit(_expert_table(st.L, [0]), protect=False) is None
        assert pipe.stats.warm_skipped == 1
        tk = pipe.submit(_expert_table(st.L, [1]), protect=False)
        assert tk is not None, "idle shard's warming was suppressed"
        assert tk.wait(timeout=30)
    finally:
        pipe.close()


def test_sharded_store_rejects_bad_geometry():
    cfg, params = reduced_params("switch-base-8")  # 4 experts
    with pytest.raises(AssertionError):
        ExpertStore(cfg, params, slots_per_layer=4,
                    sharded=ShardedStoreConfig(ep_shards=3))  # 4 % 3 != 0
    with pytest.raises(AssertionError):
        ExpertStore(cfg, params, slots_per_layer=1,
                    sharded=ShardedStoreConfig(ep_shards=2))  # < 1 slot/shard


# ---------------------------------------------------------------------------
# hot-expert replication + load-aware rebalancing (single-device: the
# replica tables, promotion/reclaim protocol, and home re-assignment are
# host-side bookkeeping — the device differentials below cover dispatch)
# ---------------------------------------------------------------------------


def _mass_table(L, spec):
    """One token per (expert, count) entry — `count` tokens routed to
    `expert` at every MoE layer, unit α, so the hot-expert EMA sees a
    controlled mass profile."""
    ids = np.concatenate(
        [np.full((c,), e, np.int32) for e, c in spec]
    )
    n = ids.shape[0]
    return HashTable(
        0, np.tile(ids.reshape(1, 1, n, 1), (L, 1, 1, 1)),
        np.ones((L, 1, n, 1), np.float32),
    )


def _repl_store(cfg, params, shards=2, slots=4, replicate_hot=1):
    """Block-placed sharded store (E8/2: shard0={0..3}, shard1={4..7})
    with 2 slots per shard by default — room for exactly one replica."""
    return ExpertStore(
        cfg, params, slots_per_layer=slots, eviction="lru",
        sharded=ShardedStoreConfig(
            ep_shards=shards, placement="block", replicate_hot=replicate_hot,
        ),
    )


def test_replicas_fill_free_slots_with_global_ids(e8):
    """An α-hot expert gains an off-home copy in a FREE slot of the least
    loaded shard; slot ids stay global, cold experts stay single-copy."""
    cfg, params, _ = e8
    st = _repl_store(cfg, params)
    assert st.R == 2
    trans = st.prepare(_mass_table(st.L, [(0, 7), (4, 1)]))
    for (g, s), res in st.resident.items():
        reps = st.replicas[(g, s)]
        assert set(reps) == {0}, "only the hot expert replicates"
        (sh, slot), = reps[0].items()
        assert sh == 1 and slot // st.S_loc == 1      # off-home, global id
        assert slot != res[4], "replica must land in a free slot"
        assert res[0] // st.S_loc == 0 and res[4] // st.S_loc == 1
    assert st.stats.replica_loads > 0
    cand = st.replica_cand(trans)
    assert cand.shape == (st.L, st.E, 2)
    g, s = st.layer_to_gs(0)
    res, reps = st.resident[(g, s)], st.replicas[(g, s)]
    assert set(cand[0, 0]) == {res[0]} | set(reps[0].values())
    assert set(cand[0, 4]) == {res[4]}                # tiled primary


def test_replicated_translate_round_robins_and_matches_device(e8):
    """Tokens of a replicated expert alternate over its live copies, with
    no weight change (every copy is resident), and the device-side
    translation agrees with the host path bit for bit."""
    cfg, params, _ = e8
    st = _repl_store(cfg, params)
    st.prepare(_mass_table(st.L, [(0, 7), (4, 1)]))   # rep(e0) -> shard 1
    t = _mass_table(st.L, [(0, 8)])
    trans = st.prepare(t)
    slots, w = st.translate(t, trans)
    g, s = st.layer_to_gs(0)
    copies = {st.resident[(g, s)][0], *st.replicas[(g, s)][0].values()}
    assert set(slots[0, 0, :, 0].tolist()) == copies
    np.testing.assert_array_equal(w, t.weights)       # resident: no rescale
    ds, dw = st.translate_device(
        jnp.asarray(t.expert_ids), jnp.asarray(t.weights), trans
    )
    np.testing.assert_array_equal(np.asarray(ds), slots)
    np.testing.assert_array_equal(np.asarray(dw), w)


def test_replica_reclaimed_before_primary_eviction(e8):
    """Under slot pressure a shard gives up replica copies first: loading
    a new home expert reclaims the replica's slot and evicts nothing."""
    cfg, params, _ = e8
    st = _repl_store(cfg, params)
    st.prepare(_mass_table(st.L, [(0, 7), (4, 1)]))   # rep(e0) -> shard 1
    st.prepare(_mass_table(st.L, [(1, 1)]))           # shard 0 now full
    st.prepare(_mass_table(st.L, [(5, 1)]))           # shard 1 full: reclaim
    for (g, s), res in st.resident.items():
        assert not st.replicas[(g, s)], "replica slot was not reclaimed"
        assert {0, 1, 4, 5} <= set(res)
    assert st.stats.evictions == 0


def test_primary_eviction_promotes_surviving_replica(e8):
    """Evicting a primary whose replica survives promotes the replica —
    the expert stays resident (on the replica's shard) and the eviction
    counter does not move."""
    cfg, params, _ = e8
    st = _repl_store(cfg, params)
    st.prepare(_mass_table(st.L, [(0, 7), (4, 1)]))   # rep(e0) -> shard 1
    st.prepare(_mass_table(st.L, [(1, 1)]))           # shard 0 full {0, 1}
    st.prepare(_mass_table(st.L, [(1, 1), (2, 1)]))   # e2 wants shard 0
    for (g, s), res in st.resident.items():
        assert res[0] // st.S_loc == 1, "promotion kept e0 resident"
        assert 0 not in st.replicas[(g, s)]
        assert res[2] // st.S_loc == 0
    assert st.stats.evictions == 0, "promotion is not an eviction"


def test_rebalance_homes_migrates_primaries(e8):
    """Two α-heavy experts sharing a home shard get split apart by the
    greedy-LPT rebalance; moved primaries demote their old slot to a
    replica (never a dangling reader) and the store keeps serving."""
    cfg, params, _ = e8
    st = ExpertStore(
        cfg, params, slots_per_layer=8, eviction="lru",
        sharded=ShardedStoreConfig(
            ep_shards=4, placement="block", replicate_hot=1,
        ),
    )                                 # home: shard0={0,1}, S_loc=2
    for _ in range(3):
        st.prepare(_mass_table(st.L, [(0, 6), (1, 6), (2, 1)]))
    old_home = st.home.copy()
    epoch = st.affinity_epoch
    moved = st.rebalance_homes()
    assert moved > 0
    assert st.stats.rebalance_moves == moved
    assert not np.array_equal(st.home, old_home)
    assert st.affinity_epoch != epoch, "scheduler memo must invalidate"
    assert st.home[0] != st.home[1], "heavy experts split across shards"
    for (g, s), res in st.resident.items():
        slots = list(res.values())
        for d in st.replicas[(g, s)].values():
            slots += list(d.values())
        assert len(slots) == len(set(slots)), "primary/replica collision"
        assert all(0 <= sl < st.S for sl in slots)
    t = _mass_table(st.L, [(0, 2), (1, 2), (2, 1)])
    _, w = st.translate(t, st.prepare(t))
    assert (w > 0).all(), "post-move translation dropped a resident expert"


# ---------------------------------------------------------------------------
# EP-serving differentials (forced multi-device host mesh)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def e8():
    return _e8_system()


@pytest.fixture(scope="module")
def e8_draft():
    return _e8_system(draft=True)


def _request_stream(cfg, n=5, seed=7):
    rng = np.random.default_rng(seed)
    return poisson_requests(
        rng, n, rate_rps=1e6, vocab_size=cfg.vocab_size,
        prompt_len_range=(4, 14), max_new_range=(4, 8),
    )


def _serve(cfg, params, hp, ep_shards, prefetch_depth=0, quantized=False,
           spec_mode="off", spec_k=2, n=5, replicate_hot=0,
           rebalance_interval=0.0, slots=None):
    ctx, sharded = (
        _ep(ep_shards, replicate_hot) if ep_shards > 1
        else (ShardingCtx(), None)
    )
    srv = RequestServer(
        cfg, params, hp,
        slots_per_layer=slots or cfg.moe.num_experts,
        max_lanes=3, max_prefill_batch=3, buckets=(8, 16), cache_len=32,
        prefetch_depth=prefetch_depth, quantized_slots=quantized,
        spec_mode=spec_mode, spec_k=spec_k, ctx=ctx, sharded=sharded,
        rebalance_interval=rebalance_interval,
    )
    srv.run(_request_stream(cfg, n=n), realtime=False)
    out = {r.rid: list(r.generated) for r in srv.completed}
    srv.close()
    return out, srv


@needs_devices(2)
@pytest.mark.parametrize("quantized", [False, True])
def test_ep_forward_logits_byte_identical(e8, quantized):
    """One prefill forward through the shard_map EP dispatch == the
    single-device forward, bit for bit (fp and int8-resident slots)."""
    cfg, params, _ = e8
    st1 = ExpertStore(cfg, params, slots_per_layer=8,
                      quantized_slots=quantized)
    ctx2, sharded = _ep(2)
    st2 = ExpertStore(cfg, params, slots_per_layer=8,
                      quantized_slots=quantized, sharded=sharded,
                      mesh=ctx2.mesh)
    table = _table(st1.L, st1.E, B=2, S=8, seed=4)
    t2 = HashTable(0, table.expert_ids.copy(), table.weights.copy())
    s1, w1 = st1.translate(table, st1.prepare(table))
    s2, w2 = st2.translate(t2, st2.prepare(t2))
    np.testing.assert_array_equal(w1, w2)  # full residency on both stores
    toks = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    out1 = forward(st1.serve_params, cfg, CTX, jnp.asarray(toks),
                   routing_override=(jnp.asarray(s1), jnp.asarray(w1)))["logits"]
    out2 = forward(st2.serve_params, cfg, ctx2, jnp.asarray(toks),
                   routing_override=(jnp.asarray(s2), jnp.asarray(w2)))["logits"]
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


@needs_devices(2)
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_ep2_server_greedy_byte_identical(e8, quantized, prefetch_depth):
    """EP=2 sharded RequestServer == single-device server, token for token,
    fp and int8-resident slots, sync and async prefetch."""
    cfg, params, hp = e8
    ref, _ = _serve(cfg, params, hp, 1, prefetch_depth, quantized)
    got, srv = _serve(cfg, params, hp, 2, prefetch_depth, quantized)
    assert got == ref
    if prefetch_depth:
        # the async pipeline really ran per-shard transfer queues
        assert len(srv.prefetch._threads) == 2
        assert sum(srv.prefetch.stats.uploads_by_shard.values()) > 0


@needs_devices(4)
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_ep4_server_greedy_byte_identical(e8, quantized, prefetch_depth):
    """Same differential on the full 4-device mesh (CI's simulated EP=4)."""
    cfg, params, hp = e8
    ref, _ = _serve(cfg, params, hp, 1, prefetch_depth, quantized)
    got, _ = _serve(cfg, params, hp, 4, prefetch_depth, quantized)
    assert got == ref


@needs_devices(2)
def test_ep_server_speculative_byte_identical(e8_draft):
    """Speculative decode under EP: the superset draft/verify tickets fan
    out per shard and greedy outputs still match the single-device
    speculative server byte for byte."""
    cfg, params, hp = e8_draft
    ref, _ = _serve(cfg, params, hp, 1, 2, spec_mode="draft", spec_k=2, n=4)
    got, _ = _serve(cfg, params, hp, 2, 2, spec_mode="draft", spec_k=2, n=4)
    assert got == ref


@needs_devices(2)
@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_ep2_replicated_server_byte_identical(e8, prefetch_depth):
    """Hot-expert replication + online rebalancing must not change one
    token: with spare per-shard slots (2E total) the hot experts really
    do gain extra copies and dispatch round-robins tokens over shards,
    yet greedy decode stays byte-identical to the single-device server —
    every copy holds bit-identical weights and each token still hits
    exactly one copy inside the psum."""
    cfg, params, hp = e8
    ref, _ = _serve(cfg, params, hp, 1, prefetch_depth)
    got, srv = _serve(
        cfg, params, hp, 2, prefetch_depth, replicate_hot=1,
        rebalance_interval=0.005, slots=2 * cfg.moe.num_experts,
    )
    assert got == ref
    assert srv.store.R == 2


@needs_devices(4)
def test_ep4_replicated_server_byte_identical(e8):
    """Same differential on the full 4-device mesh with async prefetch."""
    cfg, params, hp = e8
    ref, _ = _serve(cfg, params, hp, 1, 2)
    got, _ = _serve(
        cfg, params, hp, 4, 2, replicate_hot=1,
        rebalance_interval=0.005, slots=2 * cfg.moe.num_experts,
    )
    assert got == ref


@needs_devices(2)
@pytest.mark.parametrize("quantized", [False, True])
def test_ep_decode_engine_byte_identical(e8, quantized):
    """SiDADecodeEngine.generate over a sharded store == single device."""
    cfg, params, hp = e8
    B, steps = 2, 6
    start = np.array([3, 5], np.int32)

    def gen(ep):
        ctx, sharded = _ep(ep) if ep > 1 else (ShardingCtx(), None)
        eng = SiDADecodeEngine(
            cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
            quantized_slots=quantized, ctx=ctx, sharded=sharded,
        )
        out, m = eng.generate(start, steps, cache_len=16)
        eng.close()
        return out, m

    ref, _ = gen(1)
    got, m = gen(2)
    np.testing.assert_array_equal(ref, got)
    assert m.tokens == B * steps
