"""Beyond-paper extensions: int8 host store, SSD spill tier, incremental
decode hash prediction, autoregressive decode engine, cache-aware scheduling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.configs.base import get_config
from repro.core.decode_engine import (
    SiDADecodeEngine,
    hash_fn_step,
    hash_state_init,
)
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import hash_fn_apply, init_hash_fn
from repro.core.hash_table import HashTable
from repro.core.offload import ExpertStore, quantize_expert
from repro.models.transformer import n_moe_layers


# ---------------------------------------------------------------------------
# int8 host store
# ---------------------------------------------------------------------------


def test_quantize_expert_roundtrip():
    w = np.random.default_rng(0).normal(size=(3, 64, 32)).astype(np.float32)
    q, scale = quantize_expert(w)
    assert q.dtype == np.int8
    deq = q.astype(np.float32) * scale
    rel = np.abs(deq - w).max() / np.abs(w).max()
    assert rel < 0.02, rel
    assert q.nbytes == w.nbytes // 4


def _table(L, E, seed=0):
    rng = np.random.default_rng(seed)
    return HashTable(
        0,
        rng.integers(0, E, (L, 2, 8, 1)).astype(np.int32),
        rng.random((L, 2, 8, 1)).astype(np.float32),
    )


def test_int8_store_loads_dequantized_slots():
    cfg, params = reduced_params("switch-base-8")
    fp = ExpertStore(cfg, params, slots_per_layer=4)
    q8 = ExpertStore(cfg, params, slots_per_layer=4, host_quant="int8")
    table = _table(fp.L, fp.E)
    t_fp = fp.prepare(table)
    t_q8 = q8.prepare(table)
    np.testing.assert_array_equal(t_fp, t_q8)
    # dequantised slot contents close to fp
    s = fp.moe_subs[0]
    a = np.asarray(fp.serve_params["blocks"][f"sub{s}"]["moe"]["w_in"], np.float32)
    b = np.asarray(q8.serve_params["blocks"][f"sub{s}"]["moe"]["w_in"], np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 0.02, rel
    # and moved ~4x fewer bytes (int8 vs f32 reduced-config weights)
    assert q8.stats.bytes_h2d < fp.stats.bytes_h2d / 2


def test_spill_dir_memmap(tmp_path):
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(cfg, params, slots_per_layer=4, spill_dir=str(tmp_path))
    assert any(f.suffix == ".npy" for f in tmp_path.iterdir())
    table = _table(st.L, st.E)
    trans = st.prepare(table)  # loads straight from the memmap tier
    assert (trans >= 0).any()


# ---------------------------------------------------------------------------
# incremental hash prediction
# ---------------------------------------------------------------------------


def test_hash_fn_step_matches_full_sequence():
    """Incremental (ring-buffer) prediction == the causal full-sequence
    predictor for sequences within the ring."""
    d_model, L, E, dh = 32, 2, 8, 16
    hp = init_hash_fn(jax.random.PRNGKey(0), d_model, L, E, d_h=dh)
    B, S = 2, 12
    emb = jax.random.normal(jax.random.PRNGKey(1), (B, S, d_model))
    full = hash_fn_apply(hp, emb, num_experts=E, causal=True)  # [B, S, L, E]
    state = hash_state_init(hp, B)
    outs = []
    for t in range(S):
        logits, state = hash_fn_step(hp, emb[:, t], state, E)
        outs.append(logits)
    stepped = jnp.stack(outs, axis=1)                     # [B, S, L, E]
    err = float(jnp.abs(stepped - full).max())
    assert err < 1e-4, err


def test_decode_engine_generates():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2)
    from repro.models.transformer import init_params

    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    eng = SiDADecodeEngine(cfg, params, hp, slots_per_layer=2, serve_top_k=1)
    start = np.array([1, 2], np.int32)
    out, m = eng.generate(start, steps=10, cache_len=16)
    assert out.shape == (2, 10)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    assert m.steps == 10
    # steady state: later steps hit the expert cache more than the first
    assert m.loads_per_step[-1] <= m.loads_per_step[0]


def test_decode_engine_int8_close_to_fp():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2)
    from repro.models.transformer import init_params

    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    e1 = SiDADecodeEngine(cfg, params, hp, slots_per_layer=4, serve_top_k=1)
    e2 = SiDADecodeEngine(cfg, params, hp, slots_per_layer=4, serve_top_k=1,
                          host_quant="int8")
    start = np.array([3, 4], np.int32)
    o1, _ = e1.generate(start, steps=8, cache_len=16)
    o2, _ = e2.generate(start, steps=8, cache_len=16)
    # greedy decode is discrete: require strong (not perfect) agreement
    assert (o1 == o2).mean() > 0.7


# ---------------------------------------------------------------------------
# cache-aware scheduling
# ---------------------------------------------------------------------------


def test_lookahead_scheduling_reduces_loads():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2)
    from repro.models.transformer import init_params

    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    rng = np.random.default_rng(0)
    # two "domains" of batches with disjoint token ranges -> distinct experts
    batches = []
    for i in range(8):
        lo, hi = (0, cfg.vocab_size // 2) if i % 2 == 0 else (cfg.vocab_size // 2, cfg.vocab_size)
        batches.append(rng.integers(lo, hi, (2, 12)).astype(np.int32))

    e1 = SiDAEngine(cfg, params, hp, slots_per_layer=2)
    e1.serve(batches, threaded=True, lookahead=1)
    loads_fifo = e1.store.stats.loads
    e2 = SiDAEngine(cfg, params, hp, slots_per_layer=2)
    e2.serve(batches, threaded=True, lookahead=4)
    loads_sched = e2.store.stats.loads
    assert loads_sched <= loads_fifo
    # results identical regardless of serving order
    for a, b in zip(e1.results, e2.results):
        np.testing.assert_allclose(a, b, atol=1e-5)
