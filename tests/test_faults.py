"""Fault tolerance: injection harness, supervised transfer threads, fence
poisoning, degraded sync fallback, watchdog revival, and overload shedding.

The load-bearing property throughout: faults may cost throughput, never
correctness. Every recovery path (retry, poison+replan, degraded sync
commit, dead-thread inline commit, fence-timeout sync fallback) must leave
residency byte-identical to what the synchronous path would have loaded —
the differential tests at the bottom assert exactly that on the full
request server.
"""
import threading
import time

import numpy as np
import pytest

import repro.core.offload as offload
from conftest import reduced_params
from repro.core.faults import FaultPlan, FaultSpec, InjectedFault
from repro.core.hash_table import HashTable
from repro.core.offload import EXPERT_TENSORS, ExpertStore, PrefetchPipeline
from repro.serving import AdmissionController, Request


def _store(slots, **kw):
    cfg, params = reduced_params("switch-base-8")
    return cfg, ExpertStore(cfg, params, slots_per_layer=slots, **kw)


def _table(L, experts, idx=0):
    n = len(experts)
    ids = np.zeros((L, 1, n, 1), np.int32)
    for j, e in enumerate(experts):
        ids[:, 0, j, 0] = e
    return HashTable(idx, ids, np.ones((L, 1, n, 1), np.float32))


def _assert_resident_matches_host(store):
    for l in range(store.L):
        g, s = store.layer_to_gs(l)
        moe_p = store.serve_params["blocks"][f"sub{s}"]["moe"]
        for e, slot in store.resident[(g, s)].items():
            for t in EXPERT_TENSORS:
                np.testing.assert_array_equal(
                    np.asarray(moe_p[t][g, slot]),
                    store.host[f"sub{s}"][t][g, e],
                    err_msg=f"layer {l} expert {e} tensor {t}",
                )


def _assert_slot_accounting(store):
    """No slot may be leaked or double-booked: every hot/warm slot is
    either on a free list or backing exactly one residency mapping."""
    for (g, s), res in store.resident.items():
        used = sorted(res.values())
        assert len(used) == len(set(used)), f"({g},{s}): slot double-booked"
        free = {x for m in range(store.shards) for x in store.free[(g, s)][m]}
        if store.S4:
            free |= {
                x for m in range(store.shards) for x in store.free4[(g, s)][m]
            }
        assert not (free & set(used)), f"({g},{s}): slot both free and used"
        assert len(free) + len(used) == store.S, (
            f"({g},{s}): {len(free)} free + {len(used)} used != {store.S}"
        )


def _wait_for(pred, timeout=20.0, msg="condition"):
    t0 = time.perf_counter()
    while not pred():
        if time.perf_counter() - t0 > timeout:
            pytest.fail(f"timed out waiting for {msg}")
        time.sleep(0.002)


@pytest.fixture
def slow_link(monkeypatch):
    """Model a saturated H2D link: every staged put sleeps first."""

    def patch(delay):
        real = offload._staged_put

        def slow(x):
            time.sleep(delay)
            return real(x)

        monkeypatch.setattr(offload, "_staged_put", slow)

    return patch


# ---------------------------------------------------------------------------
# FaultPlan: grammar + scheduling
# ---------------------------------------------------------------------------


def test_fault_spec_parse_grammar():
    s = FaultSpec.parse("upload:fail@3")
    assert (s.site, s.kind, s.nth, s.times, s.p) == ("upload", "fail", 3, 1, 0.0)
    s = FaultSpec.parse("upload:fail@3x2")
    assert (s.nth, s.times) == (3, 2)
    s = FaultSpec.parse("upload:stall=0.05,p=.1")
    assert (s.kind, s.delay_s, s.p) == ("stall", 0.05, 0.1)
    s = FaultSpec.parse(" thread:crash@2 ")
    assert (s.site, s.kind, s.nth) == ("thread", "crash", 2)
    plan = FaultPlan.parse("upload:fail@1;hash:fail,p=0.5", seed=3)
    assert len(plan.specs) == 2 and plan.seed == 3


@pytest.mark.parametrize("bad", [
    "upload",                 # no kind
    "upload:explode@1",       # unknown kind
    "upload:fail@0",          # nth must be >= 1
    "upload:fail@2x0",        # times must be >= 1
    "upload:fail",            # neither @nth nor p=
    "upload:stall@1",         # stall needs =delay_s
    "upload:fail,p=1.5",      # p out of range
    "upload:fail,q=0.5",      # unknown modifier
])
def test_fault_spec_parse_rejects(bad):
    with pytest.raises(ValueError):
        FaultSpec.parse(bad)


def test_fault_plan_nth_window():
    plan = FaultPlan.parse("upload:fail@3x2")
    fired = []
    for i in range(1, 7):
        try:
            plan.inject("upload")
        except InjectedFault as e:
            assert e.site == "upload" and e.n == i
            fired.append(i)
    assert fired == [3, 4]
    assert plan.ops("upload") == 6 and plan.fired("upload") == 2


def test_fault_plan_probabilistic_is_seed_deterministic():
    def pattern(seed):
        plan = FaultPlan.parse("upload:fail,p=0.3", seed=seed)
        out = []
        for _ in range(64):
            try:
                plan.inject("upload")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b = pattern(7), pattern(7)
    assert a == b, "same seed must give the identical schedule"
    assert 0 < sum(a) < 64, "p=0.3 over 64 ops should fire sometimes"
    assert pattern(8) != a, "a different seed should (a.s.) differ"


def test_fault_plan_sites_are_independent():
    """Ops at one site must not perturb another site's p-schedule: each
    site draws from its own (seed, site)-keyed RNG."""
    lone = FaultPlan.parse("upload:fail,p=0.3", seed=5)
    mixed = FaultPlan.parse("upload:fail,p=0.3;hash:fail,p=0.9", seed=5)

    def upload_pattern(plan, interleave):
        out = []
        for _ in range(32):
            if interleave:
                try:
                    plan.inject("hash")
                except InjectedFault:
                    pass
            try:
                plan.inject("upload")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    assert upload_pattern(lone, False) == upload_pattern(mixed, True)


def test_fault_plan_stall_sleeps_not_raises():
    plan = FaultPlan.parse("upload:stall=0.05@1")
    t0 = time.perf_counter()
    plan.inject("upload")      # stall: sleeps, returns
    assert time.perf_counter() - t0 >= 0.04
    plan.inject("upload")      # past the window: no-op
    assert plan.fired("upload") == 1


def test_unmatched_site_is_free():
    plan = FaultPlan.parse("upload:fail@1")
    plan.inject("host_read")   # no spec for this site: never raises
    assert plan.ops("host_read") == 1 and plan.fired("host_read") == 0


# ---------------------------------------------------------------------------
# supervised uploads: retry, poisoning, degradation, death, revival
# ---------------------------------------------------------------------------


def test_transient_upload_fault_is_retried():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(
        store, depth=2, faults=FaultPlan.parse("upload:fail@1"),
        max_retries=3, backoff_s=0.001,
    )
    try:
        tk = pipe.submit(_table(store.L, [0, 1]))
        # let the transfer thread own the job (wait() would steal it and
        # commit inline, bypassing the faulted _upload path entirely)
        _wait_for(lambda: pipe.stats.upload_retries >= 1, msg="a retry")
        assert tk.wait(timeout=20)
        assert not tk.failed
        slot_ids, w = store.translate(_table(store.L, [0, 1]), tk.trans)
        assert (w > 0).all()
        _assert_resident_matches_host(store)
        tk.release()
    finally:
        pipe.close()
    assert pipe.stats.upload_retries >= 1
    assert pipe.stats.upload_failures == 0
    assert pipe.stats.poisoned_fences == 0


def test_exhausted_retries_poison_rollback_and_replan():
    """A persistently failing upload batch is abandoned: slots roll back to
    the free list, fences fire poisoned, and the waiting ticket's replan
    reloads the experts through the sync commit — the consumer still gets
    a fully resident, byte-correct translation."""
    cfg, store = _store(2)
    plan = FaultPlan.parse("upload:fail@1x10")   # the staged path only
    pipe = PrefetchPipeline(
        store, depth=2, faults=plan, max_retries=2, backoff_s=0.001,
        degrade_after=99,                        # isolate poisoning
    )
    try:
        t = _table(store.L, [0, 1])
        tk = pipe.submit(t)
        _wait_for(lambda: pipe.stats.upload_failures >= 1, msg="abandonment")
        assert tk.wait(timeout=20), "poisoned fences must not hang waiters"
        assert tk.failed, "ticket must record that a fence was poisoned"
        slot_ids, w = store.translate(t, tk.trans)
        assert (w > 0).all(), "replan must heal the translation"
        _assert_resident_matches_host(store)
        _assert_slot_accounting(store)
        tk.release()
    finally:
        pipe.close()
    assert pipe.stats.poisoned_fences >= 1
    _assert_slot_accounting(store)


def test_consecutive_failures_degrade_shard_to_sync():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(
        store, depth=4, faults=FaultPlan.parse("upload:fail,p=1.0"),
        max_retries=0, backoff_s=0.0, degrade_after=1,
    )
    try:
        tk0 = pipe.submit(_table(store.L, [0]))
        _wait_for(lambda: pipe.degraded_fraction() == 1.0, msg="degradation")
        assert tk0.wait(timeout=20)
        tk0.release()
        # degraded shard: uploads commit through the sync path, which the
        # fault plan does not instrument — byte-identical, just inline
        tk = pipe.submit(_table(store.L, [2, 3]))
        # let the degraded thread take the job (wait() would steal it)
        _wait_for(lambda: pipe.stats.sync_fallbacks > 0, msg="sync fallback")
        assert tk.wait(timeout=20)
        assert not tk.failed
        _assert_resident_matches_host(store)
        tk.release()
        assert pipe.stats.sync_fallbacks > 0
        assert pipe.stats.degraded == 1
    finally:
        pipe.close()


def test_thread_crash_is_supervised_and_restarted():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(
        store, depth=2, faults=FaultPlan.parse("thread:crash@1"),
        max_thread_restarts=3,
    )
    try:
        t = _table(store.L, [0, 1])
        tk = pipe.submit(t)
        _wait_for(lambda: pipe.stats.thread_crashes >= 1, msg="the crash")
        # the crashed job's fences were poisoned; the waiter replans
        assert tk.wait(timeout=20)
        slot_ids, w = store.translate(t, tk.trans)
        assert (w > 0).all()
        tk.release()
        _wait_for(lambda: pipe._threads[0].is_alive(), msg="restart")
        assert pipe.stats.thread_restarts >= 1
        assert not pipe._dead[0]
        # the restarted thread serves later submits asynchronously
        tk2 = pipe.submit(_table(store.L, [2, 3], idx=1))
        assert tk2.wait(timeout=20)
        _assert_resident_matches_host(store)
        tk2.release()
    finally:
        pipe.close()


def test_dead_thread_inline_commit_and_watchdog_revival():
    """Crashes past max_thread_restarts declare the shard dead: producers
    commit its uploads inline (no deadlock against a ghost thread), and the
    watchdog's supervised restart brings the async path back."""
    cfg, store = _store(2)
    pipe = PrefetchPipeline(
        store, depth=1, faults=FaultPlan.parse("thread:crash@1"),
        max_thread_restarts=0,
    )
    try:
        tk0 = pipe.submit(_table(store.L, [0]))
        _wait_for(lambda: pipe._dead[0], msg="shard death")
        assert tk0.wait(timeout=20)
        tk0.release()
        # dead shard: submit must neither block in backpressure nor hang a
        # fence — the producer commits synchronously
        tk = pipe.submit(_table(store.L, [2, 3], idx=1))
        assert tk.wait(timeout=20)
        _assert_resident_matches_host(store)
        tk.release()
        assert pipe.stats.sync_fallbacks > 0

        revived, _ = pipe.watchdog()
        assert revived == 1
        assert not pipe._dead[0] and pipe.degraded_fraction() == 0.0
        _wait_for(lambda: pipe._threads[0].is_alive(), msg="revived thread")
        ups = pipe.stats.uploads
        tk2 = pipe.submit(_table(store.L, [0, 1], idx=2))
        _wait_for(lambda: pipe.stats.uploads > ups, msg="async upload")
        assert tk2.wait(timeout=20)
        tk2.release()
        _assert_resident_matches_host(store)
    finally:
        pipe.close()


def test_watchdog_flags_stalled_job():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(
        store, depth=2, faults=FaultPlan.parse("upload:stall=0.4@1"),
    )
    try:
        tk = pipe.submit(_table(store.L, [0, 1]))
        _wait_for(lambda: pipe._current_job[0] is not None, msg="job pickup")
        time.sleep(0.1)
        stalled = 0
        t0 = time.perf_counter()
        while stalled == 0 and time.perf_counter() - t0 < 2.0:
            _, stalled = pipe.watchdog(max_job_age_s=0.05)
            time.sleep(0.01)
        assert stalled >= 1, "watchdog should flag the stalled upload"
        assert tk.wait(timeout=20)   # the stall ends; the upload lands
        tk.release()
    finally:
        pipe.close()


def test_host_read_fault_is_supervised_too():
    """host_read faults fire inside _stage — same retry machinery."""
    cfg, store = _store(2)
    pipe = PrefetchPipeline(
        store, depth=2, faults=FaultPlan.parse("host_read:fail@1"),
        max_retries=3, backoff_s=0.001,
    )
    try:
        tk = pipe.submit(_table(store.L, [0, 1]))
        _wait_for(lambda: pipe.stats.upload_retries >= 1, msg="a retry")
        assert tk.wait(timeout=20)
        _assert_resident_matches_host(store)
        tk.release()
    finally:
        pipe.close()
    assert pipe.stats.upload_failures == 0


# ---------------------------------------------------------------------------
# ticket.wait(timeout) contract + shutdown hygiene
# ---------------------------------------------------------------------------


def test_ticket_wait_timeout_contract(slow_link):
    """wait(timeout)->False leaves trans unconsumable by contract; the
    caller falls back to store.prepare and gets a correct translation.
    A later untimed wait() still converges."""
    slow_link(0.3)
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=2)
    try:
        t = _table(store.L, [0, 1])
        tk = pipe.submit(t)
        # the transfer thread must own the job, else wait() steals it and
        # commits inline before the timeout can trigger
        _wait_for(lambda: pipe._current_job[0] is not None, msg="job pickup")
        assert tk.wait(timeout=0.01) is False
        # fallback: the sync path blocks until the in-flight upload lands
        # and returns a translation safe to forward with
        trans = store.prepare(t)
        slot_ids, w = store.translate(t, trans)
        assert (w > 0).all()
        _assert_resident_matches_host(store)
        assert tk.wait(timeout=20)   # the ticket itself also recovers
        tk.release()
    finally:
        pipe.close()


def test_close_is_idempotent_with_inflight_uploads(slow_link):
    slow_link(0.1)
    cfg, store = _store(4)
    pipe = PrefetchPipeline(store, depth=4, staging_buffers=2)
    tickets = [
        pipe.submit(_table(store.L, [2 * i % 4, (2 * i + 1) % 4], idx=i))
        for i in range(3)
    ]
    pipe.close()
    pipe.close()   # idempotent
    for t in pipe._threads:
        assert not t.is_alive()
    # every fence the pipeline ever handed out fired (possibly poisoned)
    for tk in tickets:
        for _, ev in tk._fences:
            assert ev.is_set()
    assert all(not pend for pend in pipe._pending.values())
    assert pipe._staging == [[] for _ in range(pipe.shards)]
    _assert_slot_accounting(store)


def test_close_after_thread_death_drains_and_fires_fences():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(
        store, depth=4, faults=FaultPlan.parse("thread:crash@1"),
        max_thread_restarts=0,
    )
    tk0 = pipe.submit(_table(store.L, [0]))
    _wait_for(lambda: pipe._dead[0], msg="shard death")
    tk1 = pipe.submit(_table(store.L, [2, 3], idx=1))
    pipe.close()
    for tk in (tk0, tk1):
        for _, ev in tk._fences:
            assert ev.is_set()
    assert all(not pend for pend in pipe._pending.values())
    _assert_slot_accounting(store)


# ---------------------------------------------------------------------------
# admission controller units
# ---------------------------------------------------------------------------


def test_admission_controller_threshold_and_hysteresis():
    a = AdmissionController(margin=0.8, exit_frac=0.6, init_service_s=0.1)
    assert not a.should_shed(2, 1.0)     # est 0.2 <= thr 0.8
    assert a.should_shed(10, 1.0)        # est 1.0 > 0.8: latch
    assert a.shedding
    assert a.should_shed(5, 1.0)         # est 0.5 > 0.48 (latched)
    assert not a.should_shed(4, 1.0)     # est 0.4 <= 0.48: unlatch
    assert not a.shedding


def test_admission_controller_no_slo_and_default():
    a = AdmissionController(init_service_s=0.1)
    assert not a.should_shed(10 ** 6, None)   # nothing to protect
    b = AdmissionController(init_service_s=0.1, default_slo_s=1.0)
    assert b.should_shed(10 ** 6, None)
    c = AdmissionController()                 # no prior, no observations
    assert not c.should_shed(10 ** 6, 0.001)


def test_admission_controller_degradation_shrinks_threshold():
    a = AdmissionController(margin=0.8, init_service_s=0.1)
    assert not a.should_shed(6, 1.0, degraded_frac=0.0)   # 0.6 <= 0.8
    a.shedding = False
    assert a.should_shed(6, 1.0, degraded_frac=1.0)       # thr -> 0.4


def test_admission_controller_ema():
    a = AdmissionController(ema_decay=0.5)
    a.observe(1.0)
    assert a.service_s == 1.0          # first sample seeds the EMA
    a.observe(0.0)
    assert a.service_s == 0.5


# ---------------------------------------------------------------------------
# request server: hash-thread supervision, fence timeout, chaos, shedding
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_moe():
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.core.hash_fn import init_hash_fn
    from repro.models.transformer import init_params, n_moe_layers

    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    return cfg, params, hp


def _requests(cfg, n, seed=0, max_new=3, slo=None):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (int(p),)).astype(np.int32),
            max_new_tokens=max_new, arrival_s=0.0, slo_s=slo,
        )
        for i, p in enumerate(rng.integers(4, 9, size=n))
    ]


def _serve(cfg, params, hp, reqs, lanes=2, slots=None, **kw):
    from repro.serving import RequestServer

    srv = RequestServer(
        cfg, params, hp,
        slots_per_layer=slots or cfg.moe.num_experts,
        max_lanes=lanes, max_prefill_batch=lanes, buckets=(8, 16),
        cache_len=32, **kw,
    )
    srv.run(reqs, realtime=False)
    return srv


def test_server_hash_fault_rejects_request_and_continues(tiny_moe):
    cfg, params, hp = tiny_moe
    reqs = _requests(cfg, 4, seed=0)
    srv = _serve(
        cfg, params, hp, reqs,
        faults=FaultPlan.parse("hash:fail@2"),
    )
    try:
        assert len(srv.completed) == 3
        assert len(srv.rejected) == 1
        assert srv.rejected[0].reject_reason == "hash_error"
        assert srv.telemetry.counter("hash_thread_errors").value == 1
        assert srv.summary()["rejected_hash_error"] == 1.0
    finally:
        srv.close()


def test_server_hash_thread_escape_reraises_not_spins(tiny_moe):
    """An exception escaping the per-request guard must terminate run()
    with that exception on the caller's thread — the pre-fix behavior was
    an unset hash_done event spinning the serve loop forever."""
    from repro.serving import RequestServer

    cfg, params, hp = tiny_moe
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
        max_lanes=1, max_prefill_batch=1, buckets=(8, 16), cache_len=32,
    )

    def boom(req, now):
        raise RuntimeError("admission blew up")

    srv.admit = boom
    try:
        with pytest.raises(RuntimeError, match="admission blew up"):
            srv.run(_requests(cfg, 2, seed=1), realtime=False)
    finally:
        srv.close()


def test_server_chaos_upload_faults_byte_identical(tiny_moe):
    """The acceptance differential: seeded p=0.2 upload faults with
    retry/poison/degrade supervision — the async server completes the full
    stream with token streams byte-identical to the fault-free run."""
    cfg, params, hp = tiny_moe
    n = 6
    # slots < E: every tick churns uploads through the faulty link, not
    # just the warm-up — the supervision machinery is continuously hot
    ref_srv = _serve(
        cfg, params, hp, _requests(cfg, n, seed=2), slots=2,
        prefetch_depth=2,
    )
    ref = {r.rid: list(r.generated) for r in ref_srv.completed}
    ref_srv.close()
    assert len(ref) == n

    plan = FaultPlan.parse("upload:fail,p=0.2", seed=11)
    srv = _serve(
        cfg, params, hp, _requests(cfg, n, seed=2), slots=2,
        prefetch_depth=2, faults=plan, fence_timeout_s=10.0,
    )
    try:
        got = {r.rid: list(r.generated) for r in srv.completed}
        assert got == ref, "faults must never change tokens, only timing"
        assert plan.fired("upload") >= 1, "chaos run saw no faults (vacuous)"
        s = srv.summary()
        assert s["upload_retries"] + s["upload_failures"] >= 1
        # no waiter may block past its configured fence timeout
        fence = srv.telemetry.histogram("prefetch_fence_s")
        assert not fence.samples or max(fence.samples) < 10.0
    finally:
        srv.close()


def test_server_fence_timeout_falls_back_to_sync(tiny_moe, slow_link):
    """satellite: a timed-out ticket never forwards its stale trans — the
    tick re-prepares synchronously and the outputs stay byte-identical."""
    cfg, params, hp = tiny_moe
    n = 6
    # slots < E keeps decode ticks planning fresh uploads (this stream
    # churns ~8 expert loads through 2 slots), so their fence waits
    # actually race the slowed link instead of all-hitting
    ref_srv = _serve(
        cfg, params, hp, _requests(cfg, n, seed=2), slots=2,
        prefetch_depth=2,
    )
    ref = {r.rid: list(r.generated) for r in ref_srv.completed}
    ref_srv.close()
    assert len(ref) == n

    slow_link(0.05)
    srv = _serve(
        cfg, params, hp, _requests(cfg, n, seed=2), slots=2,
        prefetch_depth=2, fence_timeout_s=0.005,
    )
    try:
        got = {r.rid: list(r.generated) for r in srv.completed}
        assert got == ref
        assert srv.telemetry.counter("prefetch_fence_timeouts").value >= 1
    finally:
        srv.close()


def test_server_overload_sheds_before_deadline_misses(tiny_moe):
    """Sustained overload (a pessimistic service-time prior makes every
    queued request a predicted SLO miss) must surface as `overloaded`
    rejections at admission — and no ADMITTED request may miss its
    deadline."""
    cfg, params, hp = tiny_moe
    n = 8
    shed = AdmissionController(margin=0.8, init_service_s=1000.0)
    srv = _serve(
        cfg, params, hp, _requests(cfg, n, seed=4, slo=300.0), lanes=1,
        shed=shed,
    )
    try:
        s = srv.summary()
        assert s["rejected_overloaded"] >= 1, "overload never shed"
        assert s["deadline_miss"] == 0, "an admitted request missed its SLO"
        assert len(srv.completed) + len(srv.rejected) == n
        for r in srv.rejected:
            assert r.reject_reason == "overloaded"
    finally:
        srv.close()


def test_server_survives_transfer_thread_crashes(tiny_moe):
    """End-to-end: transfer threads that crash mid-stream are restarted by
    their supervisor, the crashed jobs' fences poison + replan, and the
    stream still completes byte-identically."""
    cfg, params, hp = tiny_moe
    n = 4
    ref_srv = _serve(
        cfg, params, hp, _requests(cfg, n, seed=5), prefetch_depth=2,
    )
    ref = {r.rid: list(r.generated) for r in ref_srv.completed}
    ref_srv.close()

    srv = _serve(
        cfg, params, hp, _requests(cfg, n, seed=5), prefetch_depth=2,
        faults=FaultPlan.parse("thread:crash@1x2"),
        watchdog_interval_s=0.01,
    )
    try:
        got = {r.rid: list(r.generated) for r in srv.completed}
        assert got == ref
        assert srv.telemetry.counter("prefetch_thread_crashes").value >= 1
    finally:
        srv.close()
