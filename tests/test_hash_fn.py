"""Hash function (LSTM + SparseMax attention) + TKD training."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.core.hash_fn import (
    _lstm_layer,
    hash_fn_apply,
    hash_fn_apply_segmented,
    hash_fn_param_count,
    hash_hit_rate,
    init_hash_fn,
    predict_topk,
    sparsemax,
)
from repro.core.tkd import evaluate_hash_fn, tkd_loss, train_hash_fn
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, n_moe_layers

CTX = ShardingCtx()


def test_shapes_and_lightweight():
    d_model, L, E, dh = 64, 3, 8, 32
    hp = init_hash_fn(jax.random.PRNGKey(0), d_model, L, E, d_h=dh)
    emb = jax.random.normal(jax.random.PRNGKey(1), (2, 10, d_model))
    logits = hash_fn_apply(hp, emb, num_experts=E)
    assert logits.shape == (2, 10, L, E)
    # "lightweight predictor": tiny vs any real model
    assert hash_fn_param_count(hp) < 100_000


def test_predict_topk():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3, 8))
    ids, alpha = predict_topk(logits, 2)
    assert ids.shape == (3, 2, 5, 2) and alpha.shape == (3, 2, 5, 2)
    np.testing.assert_allclose(np.asarray(alpha.sum(-1)), 1.0, atol=1e-5)
    # ids are the true argmax in top-1 position
    np.testing.assert_array_equal(
        np.asarray(ids[..., 0]), np.asarray(jnp.moveaxis(logits.argmax(-1), 2, 0))
    )


def test_hit_rate_bounds():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 2, 8))
    labels = jnp.moveaxis(logits.argmax(-1), 2, 0)
    assert float(hash_hit_rate(logits, labels, top=1)) == 1.0
    assert float(hash_hit_rate(logits, labels, top=3)) == 1.0
    wrong = (labels + 1) % 8
    r = float(hash_hit_rate(logits, wrong, top=1))
    assert r < 0.5


def test_tkd_loss_structure():
    s = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 2, 16))
    t = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 4, 16))
    loss, m = tkd_loss(s, t, T=4, lam=0.5)
    assert float(loss) > 0
    # perfect student: KD ~ 0, CE small, acc = 1
    t2 = jnp.moveaxis(s, 2, 0) * 10
    loss2, m2 = tkd_loss(s * 10, t2, T=16)
    assert float(m2["acc"]) == 1.0
    assert float(m2["kd"]) < float(m["kd"])


def test_truncation_focuses_top():
    """Changing logits outside the teacher's top-T must not change L_TKD."""
    E = 16
    t = jnp.linspace(10, -10, E).reshape(1, 1, 1, E)  # teacher: sorted
    s = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, E))
    t_lbl = jnp.moveaxis(t, 2, 0)
    loss_a, ma = tkd_loss(s, t_lbl, T=4, lam=0.0)
    s_perturbed = s.at[..., 10:].add(3.0)  # outside top-4
    loss_b, mb = tkd_loss(s_perturbed, t_lbl, T=4, lam=0.0)
    # KD over top-T only depends on s via the top-T slots' *relative* logits
    assert abs(float(ma["kd"]) - float(mb["kd"])) < 1e-5


def test_hash_fn_learns_router():
    """End-to-end: train on a tiny frozen MoE's router logits; hit rate must
    beat chance decisively (paper reports up to 99%)."""
    cfg, params = reduced_params("switch-base-8")
    E = cfg.moe.num_experts
    L = n_moe_layers(cfg)
    hp = init_hash_fn(jax.random.PRNGKey(7), cfg.d_model, L, E, d_h=32)
    rng = np.random.default_rng(0)
    fixed = rng.integers(0, cfg.vocab_size, (8, 16))  # small fixed dataset

    def batches():
        while True:
            toks = jnp.asarray(fixed)
            out = forward(params, cfg, CTX, toks, collect_router_logits=True)
            emb = jnp.take(params["embed"], toks, axis=0)
            yield emb, out["router_logits"]

    hp, hist = train_hash_fn(hp, batches(), steps=120, lr=3e-3, T=E, verbose=False)
    toks = jnp.asarray(fixed)
    out = forward(params, cfg, CTX, toks, collect_router_logits=True)
    emb = jnp.take(params["embed"], toks, axis=0)
    m = evaluate_hash_fn(hp, emb, out["router_logits"], top=3)
    assert m["top1_hit"] > 2.0 / E, m   # decisively above chance (1/E)
    assert m["top3_hit"] > m["top1_hit"] - 1e-9


def test_segmented_apply_long_prompt_contract():
    """The O(S·seg) long-prompt build: identical to the one-shot predictor
    while the prompt fits one segment, and the LSTM carry threading across
    segments is exact (only the SparseMax attention is segment-local)."""
    d_model, L, E, dh = 64, 2, 8, 16
    hp = init_hash_fn(jax.random.PRNGKey(0), d_model, L, E, d_h=dh)
    emb = jax.random.normal(jax.random.PRNGKey(1), (2, 48, d_model))

    full = hash_fn_apply(hp, emb, num_experts=E)
    one = hash_fn_apply_segmented(hp, emb, E, seg_len=64)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(one))

    multi = hash_fn_apply_segmented(hp, emb, E, seg_len=16)
    assert multi.shape == (2, 48, L, E)
    assert bool(jnp.isfinite(multi).all())

    # recurrent half is exact across a segment boundary: a resumed scan
    # reproduces the unsegmented hidden sequence bit-for-bit in structure
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 48, dh))
    h_full, _ = _lstm_layer(hp["lstm1"], x)
    h_a, carry = _lstm_layer(hp["lstm1"], x[:, :20])
    h_b, _ = _lstm_layer(hp["lstm1"], x[:, 20:], carry)
    np.testing.assert_allclose(
        np.asarray(h_full),
        np.asarray(jnp.concatenate([h_a, h_b], axis=1)),
        atol=1e-6,
    )


def test_sparsemax_jnp_matches_kernel_ref():
    from repro.kernels.ref import sparsemax_ref

    z = jax.random.normal(jax.random.PRNGKey(0), (5, 9))
    np.testing.assert_allclose(
        np.asarray(sparsemax(z)), np.asarray(sparsemax_ref(z)), atol=1e-6
    )
