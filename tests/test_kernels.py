"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
asserting allclose against the pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# expert_ffn
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,C,d,F", [
    (1, 128, 128, 128),
    (4, 256, 512, 384),
    (3, 128, 256, 640),
    (8, 512, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn_shapes(E, C, d, F, dtype):
    ks = jax.random.split(KEY, 4)
    xe = jax.random.normal(ks[0], (E, C, d), dtype)
    wi = (jax.random.normal(ks[1], (E, d, F)) * 0.05).astype(dtype)
    wg = (jax.random.normal(ks[2], (E, d, F)) * 0.05).astype(dtype)
    wo = (jax.random.normal(ks[3], (E, F, d)) * 0.05).astype(dtype)
    got = ops.expert_ffn(xe, wi, wg, wo)
    want = ref.expert_ffn_ref(xe, wi, wg, wo)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("act,glu", [("silu", True), ("gelu", False), ("relu", True)])
def test_expert_ffn_acts(act, glu):
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 128, 256, 256
    xe = jax.random.normal(ks[0], (E, C, d))
    wi = jax.random.normal(ks[1], (E, d, F)) * 0.05
    wg = jax.random.normal(ks[2], (E, d, F)) * 0.05 if glu else None
    wo = jax.random.normal(ks[3], (E, F, d)) * 0.05
    got = ops.expert_ffn(xe, wi, wg, wo, act=act)
    want = ref.expert_ffn_ref(xe, wi, wg, wo, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_expert_ffn_block_sweep():
    """Different BlockSpec tilings must give identical results."""
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 256, 128, 256
    xe = jax.random.normal(ks[0], (E, C, d))
    wi = jax.random.normal(ks[1], (E, d, F)) * 0.05
    wg = jax.random.normal(ks[2], (E, d, F)) * 0.05
    wo = jax.random.normal(ks[3], (E, F, d)) * 0.05
    want = ref.expert_ffn_ref(xe, wi, wg, wo)
    for bc, bf in [(64, 64), (128, 128), (256, 256), (128, 64)]:
        got = ops.expert_ffn(xe, wi, wg, wo, bc=bc, bf=bf)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# sparsemax
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,L", [(1, 4), (37, 33), (256, 128), (300, 7)])
def test_sparsemax_shapes(rows, L):
    z = jax.random.normal(KEY, (rows, L)) * 3
    got = ops.sparsemax(z)
    want = ref.sparsemax_ref(z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@given(
    rows=st.integers(1, 20), L=st.integers(2, 40),
    scale=st.floats(0.1, 20.0), seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_sparsemax_properties(rows, L, scale, seed):
    z = jax.random.normal(jax.random.PRNGKey(seed), (rows, L)) * scale
    out = np.asarray(ops.sparsemax(z))
    # projection onto the simplex: nonneg, sums to 1
    assert (out >= 0).all()
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-4)
    # matches oracle
    np.testing.assert_allclose(out, np.asarray(ref.sparsemax_ref(z)), atol=1e-4)
    # sparsity: strictly fewer nonzeros than softmax for spread inputs
    assert ((out > 0).sum(-1) <= L).all()


def test_sparsemax_is_sparse_vs_softmax():
    z = jax.random.normal(KEY, (64, 32)) * 4
    out = np.asarray(ops.sparsemax(z))
    assert (out == 0).mean() > 0.3  # plenty of exact zeros (softmax has none)


def test_sparsemax_nd_input():
    z = jax.random.normal(KEY, (2, 5, 17))
    got = ops.sparsemax(z)
    want = ref.sparsemax_ref(z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------


def _cache(B, S, K, D, pos_vals, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = jax.random.normal(ks[0], (B, S, K, D))
    v = jax.random.normal(ks[1], (B, S, K, D))
    pos = jnp.asarray(pos_vals, jnp.int32)
    sidx = jnp.arange(S)[None, :]
    slot_pos = pos[:, None] - ((pos[:, None] - sidx) % S)
    slot_pos = jnp.where(slot_pos >= 0, slot_pos, -1)
    return k, v, slot_pos, pos


@pytest.mark.parametrize("B,H,K,D,S", [
    (1, 4, 4, 64, 256),
    (2, 8, 4, 64, 1024),
    (2, 8, 2, 128, 512),
    (3, 6, 6, 128, 256),
])
def test_flash_decode_shapes(B, H, K, D, S):
    q = jax.random.normal(KEY, (B, H, D))
    k, v, slot_pos, pos = _cache(B, S, K, D, [S // 2] * B)
    got = ops.flash_decode(q, k, v, slot_pos, pos, bs=128)
    want = ref.flash_decode_ref(q, k, v, slot_pos, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("window,cap", [(0, 0.0), (100, 0.0), (0, 30.0), (64, 50.0)])
def test_flash_decode_masking(window, cap):
    B, H, K, D, S = 2, 4, 2, 64, 512
    q = jax.random.normal(KEY, (B, H, D))
    k, v, slot_pos, pos = _cache(B, S, K, D, [300, 511])
    got = ops.flash_decode(q, k, v, slot_pos, pos, window=window, cap=cap, bs=128)
    want = ref.flash_decode_ref(q, k, v, slot_pos, pos, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,K,D,bq,bs", [
    (2, 512, 8, 4, 64, 128, 128),
    (1, 256, 4, 2, 64, 64, 64),
    (2, 256, 4, 4, 128, 128, 64),
    (1, 128, 6, 3, 64, 128, 128),
])
def test_flash_prefill_shapes(B, S, H, K, D, bq, bs):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    got = ops.flash_prefill(q, k, v, bq=bq, bs=bs)
    want = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("window,cap,causal", [
    (64, 0.0, True), (0, 50.0, True), (0, 0.0, False), (32, 30.0, True),
])
def test_flash_prefill_variants(window, cap, causal):
    B, S, H, K, D = 1, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    got = ops.flash_prefill(q, k, v, window=window, cap=cap, causal=causal,
                            bq=64, bs=64)
    want = ref.flash_prefill_ref(q, k, v, window=window, cap=cap, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@given(seed=st.integers(0, 100), pos_frac=st.floats(0.1, 1.0))
@settings(max_examples=10, deadline=None)
def test_flash_decode_property(seed, pos_frac):
    B, H, K, D, S = 1, 4, 2, 64, 256
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, H, D))
    k, v, slot_pos, pos = _cache(B, S, K, D, [int(pos_frac * (S - 1))], seed=seed)
    got = np.asarray(ops.flash_decode(q, k, v, slot_pos, pos, bs=64))
    want = np.asarray(ref.flash_decode_ref(q, k, v, slot_pos, pos))
    np.testing.assert_allclose(got, want, atol=1e-4)
    assert np.isfinite(got).all()
