"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
family runs one forward and one train step on CPU — output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.configs.base import get_config, list_configs
from repro.launch.steps import make_train_step
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, init_cache, decode_step, param_count
from repro.optim.adamw import adamw_init

ALL_ARCHS = [
    "gemma2-9b", "qwen3-moe-235b-a22b", "stablelm-12b", "hymba-1.5b",
    "qwen2-1.5b", "chameleon-34b", "seamless-m4t-medium", "xlstm-125m",
    "deepseek-moe-16b", "smollm-135m", "switch-base-8",
]

CTX = ShardingCtx()


def _inputs(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    enc = (
        jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.dtype(cfg.dtype))
        if cfg.enc_dec else None
    )
    return toks, labels, enc


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_smoke(name):
    cfg, params = reduced_params(name)
    toks, _, enc = _inputs(cfg)
    out = forward(params, cfg, CTX, toks, enc_input=enc, scan_mode="scan")
    logits = out["logits"]
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not jnp.isnan(logits.astype(jnp.float32)).any()
    assert param_count(params) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_smoke(name):
    cfg, params = reduced_params(name)
    toks, labels, enc = _inputs(cfg)
    step = jax.jit(make_train_step(cfg, CTX, lr=1e-3))
    opt = adamw_init(params)
    if cfg.enc_dec:
        new_params, opt, metrics = step(params, opt, toks, labels, enc)
    else:
        new_params, opt, metrics = step(params, opt, toks, labels)
    loss = float(metrics["total_loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_smoke(name):
    cfg, params = reduced_params(name)
    B = 2
    cache = init_cache(cfg, B, 16, enc_len=8 if cfg.enc_dec else 0)
    toks = jnp.zeros((B,), jnp.int32)
    logits, new_cache = decode_step(params, cache, toks, cfg, CTX)
    assert logits.shape == (B, cfg.vocab_size)
    assert not jnp.isnan(logits.astype(jnp.float32)).any()
    assert int(new_cache["pos"][0]) == 1
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)


def test_train_loss_decreases_tiny_lm():
    """Integration: a tiny model actually learns on the synthetic stream."""
    from repro.data.synthetic import SyntheticConfig, SyntheticLM

    cfg, params = reduced_params("smollm-135m")
    data = SyntheticLM(SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=32), seed=1)
    step = jax.jit(make_train_step(cfg, CTX, lr=3e-3))
    opt = adamw_init(params)
    losses = []
    for toks, labels in data.batches(8, 30):
        params, opt, m = step(params, opt, jnp.asarray(toks), jnp.asarray(labels))
        losses.append(float(m["lm_loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]
