"""MoE layer: dispatch strategies, capacity, overrides, aux losses."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.attention import ShardingCtx
from repro.models.moe import (
    _block_tokens,
    init_moe,
    load_balance_loss,
    moe_layer,
    router_topk,
)

CTX = ShardingCtx()


def _cfg(num_experts=4, top_k=2, cap=100.0, shared=0):
    base = get_config("deepseek-moe-16b").reduced()
    return dataclasses.replace(
        base,
        moe=dataclasses.replace(
            base.moe,
            num_experts=num_experts, top_k=top_k, capacity_factor=cap,
            num_shared_experts=shared, d_shared=base.moe.d_expert if shared else 0,
        ),
    )


def test_gather_equals_einsum():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    y1, _ = moe_layer(p, x.astype(cfg.dtype), cfg, CTX, dispatch="einsum")
    y2, _ = moe_layer(p, x.astype(cfg.dtype), cfg, CTX, dispatch="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


@given(
    B=st.integers(1, 4), S=st.sampled_from([8, 16, 24]),
    E=st.sampled_from([2, 4]), k=st.integers(1, 2),
)
@settings(max_examples=10, deadline=None)
def test_dispatch_parity_property(B, S, E, k):
    cfg = _cfg(num_experts=E, top_k=k)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(B * 100 + S), (B, S, cfg.d_model)).astype(cfg.dtype)
    y1, _ = moe_layer(p, x, cfg, CTX, dispatch="einsum")
    y2, _ = moe_layer(p, x, cfg, CTX, dispatch="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_routing_override_skips_router():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    p_norouter = {k: v for k, v in p.items() if k != "router"}
    B, S, k = 2, 8, cfg.moe.top_k
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)).astype(cfg.dtype)
    ids = jax.random.randint(jax.random.PRNGKey(3), (B, S, k), 0, cfg.moe.num_experts)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(4), (B, S, k)), -1)
    y, aux = moe_layer(p_norouter, x, cfg, CTX, routing_override=(ids, w))
    assert aux["router_logits"] is None
    assert float(aux["aux_loss"]) == 0.0
    assert not jnp.isnan(y).any()


def test_override_matches_router_when_same_routing():
    """Feeding the router's own top-k back as an override reproduces it."""
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, cfg.d_model)).astype(cfg.dtype)
    y_router, aux = moe_layer(p, x, cfg, CTX)
    logits = aux["router_logits"].reshape(-1, cfg.moe.num_experts)
    ids, w = router_topk(logits, cfg.moe.top_k)
    y_override, _ = moe_layer(
        p, x, cfg, CTX,
        routing_override=(ids.reshape(B, S, -1), w.reshape(B, S, -1)),
    )
    np.testing.assert_allclose(np.asarray(y_router), np.asarray(y_override), atol=1e-5)


def test_capacity_drops_tokens():
    cfg = _cfg(cap=0.01)  # capacity floor = 8 per block
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64, cfg.d_model)).astype(cfg.dtype)
    y_low, _ = moe_layer(p, x, cfg, CTX)
    cfg_hi = _cfg(cap=100.0)
    y_hi, _ = moe_layer(p, x, cfg_hi, CTX)
    assert float(jnp.abs(y_low - y_hi).max()) > 1e-3  # some tokens dropped


def test_shared_experts_always_active():
    cfg = _cfg(shared=1)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, cfg.d_model)).astype(cfg.dtype)
    # zero out all routed-expert weights: output must still be nonzero
    p0 = dict(p)
    for t in ("w_in", "w_gate", "w_out"):
        p0[t] = jnp.zeros_like(p0[t])
    y, _ = moe_layer(p0, x, cfg, CTX)
    assert float(jnp.abs(y).max()) > 0


def test_load_balance_loss_prefers_uniform():
    E, T = 4, 1024
    uniform = jnp.zeros((T, E))
    ids_u = jnp.arange(T)[:, None] % E
    collapsed = jnp.zeros((T, E)).at[:, 0].set(10.0)
    ids_c = jnp.zeros((T, 1), jnp.int32)
    assert float(load_balance_loss(uniform, ids_u, E)) < float(
        load_balance_loss(collapsed, ids_c, E)
    )
    assert abs(float(load_balance_loss(uniform, ids_u, E)) - 1.0) < 1e-3


@given(T=st.integers(1, 10_000))
@settings(max_examples=50, deadline=None)
def test_block_tokens_divides(T):
    blk = _block_tokens(T)
    assert T % blk == 0 and blk <= max(T, 4096)
