"""Multi-tenant serving front door: WFQ scheduling (deficit round robin
over per-tenant queues), generated-token rate budgets, per-tenant
expert-pinning quotas, the tenant-aware admission split, and the
end-to-end two-tenant server path (per-tenant telemetry + summary).

The load-bearing guarantees pinned here:
  * weighted fairness — long-run prefill service tracks tenant weight, not
    offered load;
  * starvation-freedom — a weight-1 tenant still gets batches while a
    weight-100 tenant floods the queue;
  * rate budgets defer, never drop — a throttled tenant's requests wait for
    refill and are served after, not rejected;
  * pin quotas provably cap any tenant's pinned-slot share at
    floor(quota x S) per layer, refusals counted not raised;
  * shed isolation — one tenant's overload latch sheds only that tenant.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.hash_fn import init_hash_fn
from repro.core.hash_table import HashTable
from repro.core.offload import ExpertStore
from repro.models.transformer import init_params, n_moe_layers
from repro.serving import (
    AdmissionController,
    Request,
    RequestServer,
    ServingConfig,
    TenantAdmission,
    TenantConfig,
    WFQScheduler,
    poisson_requests,
)


def _req(rid, tenant, plen=8, new=4, arrival=0.0, slo=None):
    r = Request(
        rid=rid, prompt=np.arange(plen, dtype=np.int32),
        max_new_tokens=new, arrival_s=arrival, slo_s=slo, tenant=tenant,
    )
    r.table = HashTable(rid, np.zeros((1, 1, plen, 1), np.int32),
                        np.ones((1, 1, plen, 1), np.float32))
    return r


def _drain(sched, now=0.0, max_batch=4, rounds=200):
    """Pop prefill batches until the queues drain; returns the tenant of
    each batch in service order."""
    served = []
    for _ in range(rounds):
        batch, _bucket = sched.next_prefill_batch(now, max_batch)
        if not batch:
            break
        assert len({r.tenant for r in batch}) == 1  # batches are single-tenant
        served.append(batch[0].tenant)
    return served


# ---------------------------------------------------------------------------
# WFQ / DRR units
# ---------------------------------------------------------------------------


def test_wfq_service_tracks_weight_not_load():
    """3:1 weights, equal offered load => batch count ratio approaches 3:1
    over a long horizon (DRR's long-run fairness bound)."""
    sched = WFQScheduler(
        [TenantConfig("heavy", weight=3.0), TenantConfig("light", weight=1.0)],
        quantum=4.0, buckets=(8,), use_affinity=False,
    )
    for i in range(120):
        sched.enqueue(_req(2 * i, "heavy"))
        sched.enqueue(_req(2 * i + 1, "light"))
    served = _drain(sched, max_batch=1, rounds=400)
    # look at the first 80 batches — both tenants still backlogged there,
    # so the ratio reflects the scheduler, not queue exhaustion
    window = served[:80]
    h, li = window.count("heavy"), window.count("light")
    assert li > 0, "light tenant starved"
    assert 2.0 <= h / li <= 4.0, (h, li)


def test_wfq_starvation_free_under_flood():
    """One light request behind a 100x-weight tenant's 200-request flood
    must still be served within a bounded number of batches."""
    sched = WFQScheduler(
        [TenantConfig("whale", weight=100.0), TenantConfig("minnow")],
        quantum=8.0, buckets=(8,), use_affinity=False,
    )
    for i in range(200):
        sched.enqueue(_req(i, "whale"))
    sched.enqueue(_req(999, "minnow"))
    served = _drain(sched, max_batch=1, rounds=300)
    assert "minnow" in served
    # every round credits quantum x weight to the minnow too, so its
    # (bucket 8 + 4 decode) = 12-cost head is covered within ~2 rounds
    assert served.index("minnow") < 10


def test_wfq_unknown_tenant_gets_default_contract():
    sched = WFQScheduler([TenantConfig("known")], quantum=8.0, buckets=(8,))
    sched.enqueue(_req(0, "walk-in"))
    batch, bucket = sched.next_prefill_batch(0.0, 4)
    assert [r.rid for r in batch] == [0] and bucket == 8
    assert sched.tenants["walk-in"].cfg.weight == 1.0


def test_wfq_rate_budget_defers_and_resumes():
    """token_rate exhausts -> tenant is skipped (requests KEPT queued);
    after refill the same requests are served. Never dropped."""
    sched = WFQScheduler(
        [TenantConfig("capped", token_rate=10.0, burst=10.0),
         TenantConfig("free")],
        quantum=64.0, buckets=(8,),
    )
    sched.enqueue(_req(0, "capped"))
    sched.enqueue(_req(1, "free"))
    # burn the whole budget (and then some): 30 generated tokens vs cap 10
    sched.debit("capped", 30, now=0.0)
    served_at_0 = []
    for _ in range(4):
        batch, _ = sched.next_prefill_batch(0.0, 4)
        if not batch:
            break
        served_at_0.extend(r.tenant for r in batch)
    assert served_at_0 == ["free"]
    assert sched.pending_tenant("capped") == 1  # deferred, not dropped
    # 2 seconds of refill at 10 tok/s pays back the 20-token debt
    batch, _ = sched.next_prefill_batch(2.5, 4)
    assert [r.tenant for r in batch] == ["capped"]


def test_wfq_empty_queue_forfeits_deficit():
    """The DRR no-banking rule: a tenant whose queue drains loses its
    accumulated deficit and cannot burst ahead when it returns."""
    sched = WFQScheduler(
        [TenantConfig("a"), TenantConfig("b")], quantum=8.0, buckets=(8,),
    )
    sched.enqueue(_req(0, "a"))
    _drain(sched)
    # rounds with only b in the queue must not bank credit for a
    for i in range(5):
        sched.enqueue(_req(10 + i, "b"))
    _drain(sched)
    assert sched.tenants["a"].deficit == 0.0


def test_wfq_single_tenant_batch_fills_same_bucket():
    sched = WFQScheduler([TenantConfig("a")], quantum=1000.0, buckets=(8, 16))
    for i in range(3):
        sched.enqueue(_req(i, "a", plen=8))
    sched.enqueue(_req(3, "a", plen=16))
    batch, bucket = sched.next_prefill_batch(0.0, 4)
    assert bucket == 8 and len(batch) == 3  # 16-bucket request left behind
    batch, bucket = sched.next_prefill_batch(0.0, 4)
    assert bucket == 16 and [r.rid for r in batch] == [3]


# ---------------------------------------------------------------------------
# pin quotas (core/offload.py)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    return cfg, params, hp


def test_pin_quota_caps_share(tiny):
    cfg, params, _ = tiny
    store = ExpertStore(cfg, params, slots_per_layer=4)
    store.set_pin_quota("greedy", 0.5)  # cap = floor(0.5 x 4) = 2 per layer
    granted = store.pin_experts(0, [0, 1, 2, 3], tenant="greedy")
    assert granted == {0, 1}
    assert store.pinned_count(0, "greedy") == 2
    assert store.pinned_share("greedy") <= 0.5  # the provable bound
    assert store.stats.pin_quota_refusals == 2
    # an unconstrained tenant can still pin the remaining slots... minus
    # one: the pool always keeps at least one unpinned victim slot
    granted2 = store.pin_experts(0, [2, 3], tenant="other")
    assert 2 in granted2
    assert store.pinned_share("greedy") <= 0.5


def test_pin_quota_same_expert_not_double_attributed(tiny):
    cfg, params, _ = tiny
    store = ExpertStore(cfg, params, slots_per_layer=4)
    store.set_pin_quota("t", 0.5)
    assert store.pin_experts(0, [5], tenant="t") == {5}
    # re-pinning your own expert is free (no second slot consumed)
    assert store.pin_experts(0, [5], tenant="t") == {5}
    assert store.pinned_count(0, "t") == 1
    # another tenant cannot claim (or unpin) an expert pinned by t
    assert store.pin_experts(0, [5], tenant="u") == set()
    store.unpin_experts(0, [5], tenant="u")
    assert store.pinned_count(0, "t") == 1
    store.unpin_experts(0, [5], tenant="t")
    assert store.pinned_count(0, "t") == 0


def test_pin_quota_rejects_bad_fraction(tiny):
    cfg, params, _ = tiny
    store = ExpertStore(cfg, params, slots_per_layer=2)
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError):
            store.set_pin_quota("t", bad)


def test_legacy_untenanted_pins_unchanged(tiny):
    """tenant=None keeps the pre-PR semantics: unattributed, unquota'd."""
    cfg, params, _ = tiny
    store = ExpertStore(cfg, params, slots_per_layer=4)
    store.set_pin_quota("t", 0.25)
    granted = store.pin_experts(0, [0, 1, 2])
    assert granted == {0, 1, 2}
    assert store.stats.pin_quota_refusals == 0


# ---------------------------------------------------------------------------
# tenant-aware admission split
# ---------------------------------------------------------------------------


def test_tenant_admission_isolates_shedding():
    ta = TenantAdmission(
        AdmissionController(margin=0.5),
        [TenantConfig("busy", default_slo_s=1.0),
         TenantConfig("idle", default_slo_s=1.0)],
    )
    # busy tenant has slow history + deep queue -> sheds
    ta.observe("busy", 2.0)
    assert ta.should_shed("busy", depth=8, slack_s=1.0)
    # idle tenant's controller saw nothing: independent EMA, admits
    assert not ta.should_shed("idle", depth=8, slack_s=1.0)
    assert ta.shedding  # the aggregate latch reports any tenant shedding


def test_tenant_admission_applies_contract_slo():
    ta = TenantAdmission(
        AdmissionController(margin=0.5), [TenantConfig("t", default_slo_s=1.0)]
    )
    ta.observe("t", 2.0)
    # request carries no SLO: the tenant's contract deadline still protects
    assert ta.should_shed("t", depth=8, slack_s=None)
    # unknown tenants clone the template (no default SLO -> never shed)
    ta.observe("walkin", 2.0)
    assert not ta.should_shed("walkin", depth=8, slack_s=None)


def test_admission_clone_is_independent():
    base = AdmissionController(margin=0.7, default_slo_s=3.0)
    base.observe(5.0)
    c = base.clone()
    assert c.margin == 0.7 and c.default_slo_s == 3.0
    assert c.service_s == 0.0 and not c.shedding  # fresh state
    c.observe(1.0)
    assert base.service_s == 5.0


# ---------------------------------------------------------------------------
# end-to-end: two-tenant server
# ---------------------------------------------------------------------------


def test_two_tenant_server_end_to_end(tiny):
    """Two tenants through the full server: WFQ scheduler engaged, tokens
    debited, per-tenant telemetry partitions and summaries populated, and
    both tenants complete all requests."""
    cfg, params, hp = tiny
    config = ServingConfig.from_kwargs(
        slots_per_layer=cfg.moe.num_experts, max_lanes=2, max_prefill_batch=2,
        buckets=(8, 16), cache_len=32,
        tenants=(TenantConfig("paid", weight=4.0, pin_quota=0.5),
                 TenantConfig("free", weight=1.0)),
    )
    srv = RequestServer(cfg, params, hp, config)
    assert isinstance(srv.scheduler, WFQScheduler)
    rng = np.random.default_rng(3)
    reqs = []
    for i, name in enumerate(("paid", "free")):
        reqs.extend(poisson_requests(
            rng, 4, rate_rps=50.0, vocab_size=cfg.vocab_size,
            prompt_len_range=(4, 12), max_new_range=(2, 4),
            tenant=name, rid_base=100 * i,
        ))
    srv.run(reqs, realtime=False)
    summary = srv.tenant_summary()
    assert set(summary) == {"paid", "free"}
    for name in ("paid", "free"):
        blk = summary[name]
        assert blk["arrived"] == 4 and blk["completed"] == 4
        assert blk["tokens_generated"] > 0
        assert blk["slo_attainment"] == 1.0  # no SLOs -> nothing missed
    snap = srv.telemetry.snapshot()
    assert set(snap["tenants"]) == {"paid", "free"}
    # generated tokens were debited against the WFQ rate buckets
    total = sum(summary[n]["tokens_generated"] for n in summary)
    assert total == snap["counters"]["tokens_generated"]
    srv.close()


def test_tenant_default_slo_stamped_at_admission(tiny):
    cfg, params, hp = tiny
    config = ServingConfig.from_kwargs(
        slots_per_layer=cfg.moe.num_experts, max_lanes=1, max_prefill_batch=1,
        buckets=(8,), cache_len=16,
        tenants=(TenantConfig("slo", default_slo_s=60.0),),
    )
    srv = RequestServer(cfg, params, hp, config)
    r = _req(0, "slo", plen=8, new=2)
    r.table = None
    srv.build_request_table(r)
    srv.admit(r, 0.0)
    assert r.slo_s == 60.0  # contract deadline stamped at admission
    srv.run([], realtime=False)
    assert len(srv.completed) == 1
    srv.close()
