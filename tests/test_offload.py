"""ExpertStore: host store + device slot cache with FIFO eviction."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.core.hash_table import HashTable
from repro.core.offload import ExpertStore
from repro.models.transformer import n_moe_layers


def _store(slots, name="switch-base-8"):
    cfg, params = reduced_params(name)
    return cfg, ExpertStore(cfg, params, slots_per_layer=slots)


def _table(L, E, B=2, S=8, k=1, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, E, (L, B, S, k)).astype(np.int32)
    w = rng.random((L, B, S, k)).astype(np.float32)
    return HashTable(0, ids, w)


def test_routers_are_offloaded():
    cfg, store = _store(4)
    for s in store.moe_subs:
        assert "router" not in store.serve_params["blocks"][f"sub{s}"]["moe"]


def test_prepare_loads_predicted_experts():
    cfg, store = _store(4)
    L, E = store.L, store.E
    table = _table(L, E)
    trans = store.prepare(table)
    for l in range(L):
        for e in np.unique(table.expert_ids[l]):
            assert trans[l, e] >= 0, (l, e)
    assert store.stats.loads > 0
    assert store.stats.bytes_h2d > 0


def test_slot_contents_match_host():
    cfg, store = _store(4)
    table = _table(store.L, store.E)
    trans = store.prepare(table)
    l = 0
    g, s = store.layer_to_gs(l)
    moe_p = store.serve_params["blocks"][f"sub{s}"]["moe"]
    for e in np.unique(table.expert_ids[l]):
        slot = trans[l, e]
        np.testing.assert_array_equal(
            np.asarray(moe_p["w_in"][g, slot]), store.host[f"sub{s}"]["w_in"][g, e]
        )


def test_second_prepare_hits_cache():
    cfg, store = _store(4)
    table = _table(store.L, store.E)
    store.prepare(table)
    loads_before = store.stats.loads
    store.prepare(table)  # same table: all hits
    assert store.stats.loads == loads_before
    assert store.stats.hits > 0


def test_fifo_eviction():
    cfg, store = _store(2)  # tight budget: 2 slots, 4 experts
    L, E = store.L, store.E
    t1 = HashTable(0, np.full((L, 1, 2, 1), 0, np.int32), np.ones((L, 1, 2, 1), np.float32))
    t1.expert_ids[:, 0, 1, 0] = 1
    store.prepare(t1)  # loads {0, 1}
    t2 = HashTable(1, np.full((L, 1, 2, 1), 2, np.int32), np.ones((L, 1, 2, 1), np.float32))
    t2.expert_ids[:, 0, 1, 0] = 3
    trans = store.prepare(t2)  # must evict {0,1} FIFO, load {2,3}
    assert store.stats.evictions > 0
    assert trans[0, 2] >= 0 and trans[0, 3] >= 0
    assert trans[0, 0] == -1 and trans[0, 1] == -1


def test_budget_tighter_than_active_set_drops_lowest_mass():
    cfg, store = _store(2)
    L, E = store.L, store.E
    ids = np.zeros((L, 1, 8, 1), np.int32)
    ids[:, 0, :4, 0] = np.array([0, 1, 2, 3])  # 4 distinct experts
    w = np.ones((L, 1, 8, 1), np.float32)
    w[:, 0, 2:4] = 0.01  # experts 2,3 carry tiny mass
    table = HashTable(0, ids, w)
    trans = store.prepare(table)
    # expert 0 has the most α mass (slots go to 0 and 1)
    assert trans[0, 0] >= 0
    assert (trans[0] >= 0).sum() == 2


def test_translate_masks_misses():
    cfg, store = _store(2)
    table = _table(store.L, store.E, seed=3)
    trans = store.prepare(table)
    slot_ids, w = store.translate(table, trans)
    assert slot_ids.shape == table.expert_ids.shape
    assert slot_ids.max() < store.S
    missed = np.take_along_axis(
        trans, table.expert_ids.reshape(store.L, -1), axis=1
    ).reshape(table.expert_ids.shape) < 0
    assert (w[missed] == 0).all()
    assert (w[~missed] > 0).any()


def test_memory_accounting():
    cfg, store4 = _store(4)
    _, store2 = _store(2)
    assert store2.device_bytes() < store4.device_bytes()
    assert store4.device_bytes() <= store4.full_expert_bytes()


# ---------------------------------------------------------------------------
# miss renormalization (regression: dropped experts used to shrink the
# MoE output because surviving weights were not rescaled)
# ---------------------------------------------------------------------------


def test_translate_renormalizes_surviving_weights():
    cfg, store = _store(2)
    L, E = store.L, store.E
    # make experts {0, 1} resident
    warm = HashTable(0, np.zeros((L, 1, 2, 1), np.int32),
                     np.ones((L, 1, 2, 1), np.float32))
    warm.expert_ids[:, 0, 1, 0] = 1
    trans = store.prepare(warm)
    # token routes to resident 0 (α=.7) and non-resident 3 (α=.3)
    ids = np.zeros((L, 1, 1, 2), np.int32)
    ids[..., 1] = 3
    w = np.zeros((L, 1, 1, 2), np.float32)
    w[..., 0], w[..., 1] = 0.7, 0.3
    table = HashTable(1, ids, w)
    _, got = store.translate(table, trans)
    # survivor absorbs the dropped α mass: total stays 1.0 per token
    np.testing.assert_allclose(got[..., 0], 1.0, atol=1e-6)
    np.testing.assert_allclose(got[..., 1], 0.0, atol=1e-6)
    # all-miss tokens stay zero (nothing resident to scale up)
    all_miss = HashTable(2, np.full((L, 1, 1, 1), 2, np.int32),
                         np.full((L, 1, 1, 1), 0.5, np.float32))
    _, gm = store.translate(all_miss, trans)
    assert (gm == 0).all()


# ---------------------------------------------------------------------------
# pluggable eviction + pinning
# ---------------------------------------------------------------------------


def _single(L, e, n=2):
    ids = np.full((L, 1, n, 1), e, np.int32)
    return HashTable(0, ids, np.ones((L, 1, n, 1), np.float32))


def _pair(L, a, b):
    t = _single(L, a)
    t.expert_ids[:, 0, 1, 0] = b
    return t


def test_lru_eviction_keeps_touched_expert():
    cfg, params = reduced_params("switch-base-8")
    lru = ExpertStore(cfg, params, slots_per_layer=2, eviction="lru")
    fifo = ExpertStore(cfg, params, slots_per_layer=2, eviction="fifo")
    L = lru.L
    for st in (lru, fifo):
        st.prepare(_pair(L, 0, 1))  # load {0, 1}
        st.prepare(_single(L, 0))   # touch 0
        st.prepare(_single(L, 2))   # needs an eviction
    # LRU evicts 1 (least recent); FIFO evicts 0 (oldest insertion)
    assert 0 in lru.resident[(0, lru.moe_subs[0])]
    assert 1 not in lru.resident[(0, lru.moe_subs[0])]
    assert 0 not in fifo.resident[(0, fifo.moe_subs[0])]
    assert 1 in fifo.resident[(0, fifo.moe_subs[0])]


def test_alpha_mass_eviction_keeps_heavy_expert():
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(cfg, params, slots_per_layer=2, eviction="alpha")
    L = st.L
    ids = np.zeros((L, 1, 8, 1), np.int32)
    ids[:, 0, 7, 0] = 1  # expert 0: 7 tokens of mass, expert 1: one token
    w = np.ones((L, 1, 8, 1), np.float32)
    st.prepare(HashTable(0, ids, w))
    st.prepare(_single(L, 2))  # eviction: must drop the light expert 1
    res = st.resident[(0, st.moe_subs[0])]
    assert 0 in res and 2 in res and 1 not in res


def test_pinned_expert_never_evicted():
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(cfg, params, slots_per_layer=2)
    L = st.L
    st.prepare(_pair(L, 0, 1))
    for l in range(L):
        st.pin_experts(l, [0, 1])
    trans = st.prepare(_pair(L, 2, 3))  # both loads must be dropped
    res = st.resident[(0, st.moe_subs[0])]
    assert 0 in res and 1 in res
    assert (trans[:, 2] == -1).all() and (trans[:, 3] == -1).all()
    for l in range(L):
        st.unpin_experts(l, [0, 1])
    trans = st.prepare(_pair(L, 2, 3))  # now evictable again
    assert (trans[:, 2] >= 0).all() and (trans[:, 3] >= 0).all()


def test_cache_affinity_score():
    cfg, store = _store(4)
    L = store.L
    store.prepare(_pair(L, 0, 1))
    assert store.cache_affinity(_pair(L, 0, 1)) == 1.0
    assert store.cache_affinity(_pair(L, 2, 3)) == 0.0
    assert abs(store.cache_affinity(_pair(L, 0, 2)) - 0.5) < 1e-9


# ---------------------------------------------------------------------------
# miss renormalization, part 2 (regression for the PR-1 fix): the forward
# output must not shrink when a predicted expert misses residency
# ---------------------------------------------------------------------------


def test_forced_miss_weights_sum_to_one_and_output_does_not_shrink():
    """With a forced residency miss, surviving per-token weights sum back
    to the predicted α mass, and the MoE output is EXACTLY what a
    weight-1.0 route to the surviving expert produces — no silent shrink
    toward zero (pre-fix, the survivor kept only its own 0.7)."""
    from repro.models.attention import ShardingCtx
    from repro.models.transformer import forward

    cfg, store = _store(2)
    L, E = store.L, store.E
    warm = _pair(L, 0, 1)
    trans = store.prepare(warm)  # residents: {0, 1}

    # every token routes to resident 0 (α=.7) and non-resident 3 (α=.3)
    S = 6
    ids = np.zeros((L, 1, S, 2), np.int32)
    ids[..., 1] = 3
    w = np.zeros((L, 1, S, 2), np.float32)
    w[..., 0], w[..., 1] = 0.7, 0.3
    miss_table = HashTable(1, ids, w)
    slot_ids, got_w = store.translate(miss_table, trans)

    # weights: survivor absorbs the dropped α mass, per token
    np.testing.assert_allclose(got_w.sum(axis=-1), 1.0, atol=1e-6)
    np.testing.assert_allclose(got_w[..., 1], 0.0, atol=1e-6)

    # forward parity: the miss-renormalized override equals an explicit
    # weight-1.0 route to the surviving expert — identical logits, so the
    # output norm provably did not shrink
    ctx = ShardingCtx()
    toks = np.arange(S, dtype=np.int32)[None, :] % cfg.vocab_size
    out_miss = forward(
        store.serve_params, cfg, ctx, jnp.asarray(toks),
        routing_override=(jnp.asarray(slot_ids), jnp.asarray(got_w)),
    )["logits"]
    ref_ids = np.zeros((L, 1, S, 2), np.int32)
    ref_ids[..., 1] = 3
    ref_w = np.zeros((L, 1, S, 2), np.float32)
    ref_w[..., 0] = 1.0
    ref_slots, ref_ww = store.translate(HashTable(2, ref_ids, ref_w), trans)
    out_ref = forward(
        store.serve_params, cfg, ctx, jnp.asarray(toks),
        routing_override=(jnp.asarray(ref_slots), jnp.asarray(ref_ww)),
    )["logits"]
    np.testing.assert_allclose(
        np.asarray(out_miss, np.float32), np.asarray(out_ref, np.float32),
        atol=1e-5,
    )
    # and the un-renormalized weights (the pre-fix behavior) measurably
    # shrink the output — the regression this test pins down
    shrunk_w = got_w.copy()
    shrunk_w[..., 0] = 0.7
    out_shrunk = forward(
        store.serve_params, cfg, ctx, jnp.asarray(toks),
        routing_override=(jnp.asarray(slot_ids), jnp.asarray(shrunk_w)),
    )["logits"]
    norm_ref = float(jnp.linalg.norm(out_ref.astype(jnp.float32)))
    norm_shrunk = float(jnp.linalg.norm(out_shrunk.astype(jnp.float32)))
    assert norm_shrunk != norm_ref


# eviction-policy property tests (hypothesis) live in
# tests/test_offload_properties.py so this module stays collectable when
# hypothesis is absent
