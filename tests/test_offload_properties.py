"""Eviction-policy invariants (hypothesis property tests).

Under arbitrary admit/touch/evict sequences: a pinned expert is never the
victim, the resident set never exceeds capacity, FIFO/LRU victims match
executable reference models, and α-mass eviction always picks a
minimal-score candidate. A final integration property drives
ExpertStore.plan_layer directly.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import reduced_params
from repro.core.offload import EVICTION_POLICIES, ExpertStore

N_EXPERTS = 8


class PolicyHarness:
    """Drives one EvictionPolicy exactly like ExpertStore.plan_layer:
    resident hit -> touch; miss with space -> admit; miss when full ->
    pick_victim(protected) then admit, or drop when every resident is
    protected."""

    def __init__(self, name, capacity, pinned):
        self.policy = EVICTION_POLICIES[name]()
        self.capacity = capacity
        self.pinned = frozenset(pinned)
        self.resident = set()
        self.victims = []

    def access(self, e, w=0.0):
        if e in self.resident:
            self.policy.touch(e, w)
            return None
        protected = {e} | set(self.pinned)
        if len(self.resident) < self.capacity:
            self.resident.add(e)
            self.policy.admit(e, w)
            return None
        victim = self.policy.pick_victim(protected)
        if victim is None:
            return None  # dropped: everything resident is protected
        assert victim in self.resident, "victim must be resident"
        assert victim not in self.pinned, "pinned expert evicted"
        self.resident.discard(victim)
        self.victims.append(victim)
        self.resident.add(e)
        self.policy.admit(e, w)
        return victim


ops_strategy = st.lists(
    st.tuples(
        st.integers(0, N_EXPERTS - 1),
        st.floats(0.0, 1.0, allow_nan=False, width=32),
    ),
    min_size=1, max_size=60,
)
pinned_strategy = st.sets(st.integers(0, N_EXPERTS - 1), max_size=2)
capacity_strategy = st.integers(1, 4)


@settings(max_examples=80, deadline=None)
@given(name=st.sampled_from(sorted(EVICTION_POLICIES)),
       ops=ops_strategy, capacity=capacity_strategy, pinned=pinned_strategy)
def test_policy_invariants_under_arbitrary_sequences(name, ops, capacity, pinned):
    """For every policy: resident-set size never exceeds capacity and a
    pinned expert is never the victim, under arbitrary access sequences."""
    h = PolicyHarness(name, capacity, pinned)
    for e, w in ops:
        h.access(e, w)
        assert len(h.resident) <= capacity
    assert not set(h.victims) & h.pinned


@settings(max_examples=80, deadline=None)
@given(ops=ops_strategy, capacity=capacity_strategy, pinned=pinned_strategy)
def test_fifo_victims_match_reference(ops, capacity, pinned):
    """FIFO victim = the earliest-admitted non-pinned resident (pinned
    entries are recycled without disturbing the relative order of the
    rest)."""
    h = PolicyHarness("fifo", capacity, pinned)
    order = []  # admission order of residents
    for e, w in ops:
        if e in h.resident:
            h.access(e, w)
            continue
        expect = None
        if len(h.resident) >= capacity:
            expect = next((x for x in order if x not in pinned), None)
        victim = h.access(e, w)
        assert victim == expect
        if expect is not None:
            order.remove(expect)
        if e in h.resident and e not in order:
            order.append(e)


@settings(max_examples=80, deadline=None)
@given(ops=ops_strategy, capacity=capacity_strategy, pinned=pinned_strategy)
def test_lru_victims_match_reference(ops, capacity, pinned):
    """LRU victim = the least-recently admitted-or-touched non-pinned
    resident."""
    h = PolicyHarness("lru", capacity, pinned)
    recency = []  # least-recent first
    for e, w in ops:
        if e in h.resident:
            h.access(e, w)
            recency.remove(e)
            recency.append(e)
            continue
        expect = None
        if len(h.resident) >= capacity:
            expect = next((x for x in recency if x not in pinned), None)
        victim = h.access(e, w)
        assert victim == expect
        if expect is not None:
            recency.remove(expect)
        if e in h.resident and e not in recency:
            recency.append(e)


@settings(max_examples=80, deadline=None)
@given(ops=ops_strategy, pinned=pinned_strategy)
def test_alpha_mass_victim_is_minimal_scored_resident(ops, pinned):
    """α-mass eviction always picks a non-protected resident whose decayed
    score is minimal among the candidates at eviction time."""
    capacity = 2
    h = PolicyHarness("alpha", capacity, pinned)
    for e, w in ops:
        was_resident = e in h.resident
        scores = dict(h.policy.score)
        victim = h.access(e, w)
        if victim is not None:
            assert not was_resident
            candidates = {
                x: s for x, s in scores.items()
                if x not in pinned and x != e and x in (h.resident | {victim})
            }
            assert scores[victim] == min(candidates.values())


@settings(max_examples=25, deadline=None)
@given(seqs=st.lists(
    st.lists(st.integers(0, 3), min_size=1, max_size=4),
    min_size=1, max_size=6,
))
def test_store_plan_layer_invariants(seqs):
    """Integration property: driving ExpertStore.plan_layer with arbitrary
    needed-sets keeps (a) resident count <= slots, (b) slot assignments
    unique, (c) every currently-needed expert resident after planning."""
    cfg, params = reduced_params("switch-base-8")
    store = ExpertStore(cfg, params, slots_per_layer=2, eviction="lru")
    g, s = store.layer_to_gs(0)
    for needed in seqs:
        uniq = np.unique(np.asarray(needed, np.int64))[: store.S]
        store.plan_layer(0, uniq)
        res = store.resident[(g, s)]
        assert len(res) <= store.S
        slots = list(res.values())
        assert len(slots) == len(set(slots)), "slot double-assigned"
        assert all(int(e) in res for e in uniq)
