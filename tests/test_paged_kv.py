"""Paged K/V residency (core/residency.py) + chunked prefill.

Three layers of guarantees:

  * pool bookkeeping contracts — position-ordered allocation, spill /
    page-in round-trips that restore exact bytes, pinning, the
    full-attention overcommit refusal, and the split_budget arbitration;

  * byte-identity differentials — the paged decode path (engine and
    request server, sync and async prefetch, fp and int8 slots, vanilla
    and speculative, EP=1 and EP=2) produces greedy outputs identical to
    the ring-cache path while the budget covers the working set, and a
    chunked long prefill matches a big-bucket unchunked server
    token-for-token (capacity_factor is set high so MoE capacity never
    binds — chunked prefill drops FEWER tokens than a full-S forward
    when it does, see docs/ARCHITECTURE.md);

  * kernel parity — flash_decode_paged (scalar-prefetched page table)
    against the gather-based oracle, including spilled (-1) entries and
    windowed masking.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.decode_engine import SiDADecodeEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.residency import KVPagePool, PagedKVConfig, ResidencyManager
from repro.kernels import ops, ref
from repro.launch.serve import validate_serve_args
from repro.models.transformer import init_params, n_moe_layers
from repro.serving import Request, RequestServer


def needs_devices(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} simulated devices "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count=4 "
               f"+ REPRO_MULTI_DEVICE_TESTS=1)",
    )


@pytest.fixture(scope="module")
def tiny():
    """2-layer miniature with capacity_factor high enough that MoE token
    capacity never binds — the regime where chunked prefill is exact."""
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16, draft=True,
    )
    return cfg, params, hp


# ---------------------------------------------------------------------------
# pool bookkeeping
# ---------------------------------------------------------------------------


def test_paged_config_geometry():
    p = PagedKVConfig(page_size=8, kv_pages=4)
    assert p.enabled and p.seq_len == 32 and p.pages_per_lane() == 4
    p = PagedKVConfig(page_size=8, kv_pages=4, max_seq=100)
    assert p.seq_len == 100 and p.pages_per_lane() == 13
    assert not PagedKVConfig(kv_pages=0).enabled


def test_split_budget():
    # equal masses -> ~50/50 bytes; the floor keeps both pools functional
    slots, pages = ResidencyManager.split_budget(
        1000, expert_slot_bytes=100, page_bytes=10, n_moe_layers=2,
    )
    assert slots >= 1 and pages >= 1
    assert slots * 100 * 2 + (pages + 1) * 10 <= 1000
    # mass skew moves bytes between the classes
    s_kv, p_kv = ResidencyManager.split_budget(
        1000, 100, 10, 2, expert_mass=1.0, kv_mass=3.0,
    )
    assert p_kv > pages and s_kv <= slots
    with pytest.raises(AssertionError):
        ResidencyManager.split_budget(100, 100, 10, 2)  # below floor


def _rand_kv(pool, rng, S):
    G, K, D = pool.n_groups, pool.cfg.n_kv_heads, pool.cfg.hd
    return {
        f"sub{s}": (
            rng.standard_normal((G, S, K, D)).astype(np.float32),
            rng.standard_normal((G, S, K, D)).astype(np.float32),
        )
        for s in pool.kv_subs
    }


def _page_of(cache, pool, skey, pid):
    e = cache[skey]
    return np.asarray(e["kp"][:, pid]), np.asarray(e["vp"][:, pid])


def test_pool_spill_page_in_roundtrip(tiny):
    cfg, _, _ = tiny
    pool = KVPagePool(cfg, PagedKVConfig(page_size=4, kv_pages=4), n_lanes=1)
    cache = pool.init_cache()
    rng = np.random.default_rng(0)
    kv = _rand_kv(pool, rng, 12)
    cache = pool.seed(cache, 0, kv, 12)
    assert pool.resident_pages() == 3 and pool.stats.allocs == 3
    skey = f"sub{pool.kv_subs[0]}"
    pid = int(pool.table[0, 1])
    k_before, v_before = _page_of(cache, pool, skey, pid)
    np.testing.assert_array_equal(k_before, kv[skey][0][:, 4:8])

    cache = pool.spill(cache, 0, 1)
    assert pool.table[0, 1] == -1 and pool.resident_pages() == 2
    assert pool.stats.spills == 1 and pool.stats.bytes_spilled == pool.page_bytes()

    cache = pool.page_in(cache, 0, 1)  # inline (no pipeline)
    pid2 = int(pool.table[0, 1])
    assert pid2 >= 0 and pool.stats.page_ins == 1
    k_after, v_after = _page_of(cache, pool, skey, pid2)
    np.testing.assert_array_equal(k_after, k_before)
    np.testing.assert_array_equal(v_after, v_before)

    # pinned pages refuse to spill
    pool.pin_lane(0)
    with pytest.raises(AssertionError):
        pool.spill(cache, 0, 0)
    pool.unpin_all()
    pool.release_lane(0)
    assert pool.resident_pages() == 0 and not pool._spill


def test_pool_async_page_in_commits_on_sync(tiny):
    """With a pipeline attached the H2D stage rides the transfer queue;
    bytes only land in the cache after the fence (sync)."""
    from repro.core.offload import ExpertStore, PrefetchPipeline

    cfg, params, _ = tiny
    store = ExpertStore(cfg, params, slots_per_layer=cfg.moe.num_experts)
    pipe = PrefetchPipeline(store, depth=2)
    try:
        pool = KVPagePool(cfg, PagedKVConfig(page_size=4, kv_pages=4),
                          n_lanes=1, pipeline=pipe)
        cache = pool.init_cache()
        rng = np.random.default_rng(1)
        cache = pool.seed(cache, 0, _rand_kv(pool, rng, 8), 8)
        skey = f"sub{pool.kv_subs[0]}"
        k_ref, v_ref = _page_of(cache, pool, skey, int(pool.table[0, 0]))
        cache = pool.spill(cache, 0, 0)
        cache = pool.page_in(cache, 0, 0, priority=0)
        cache = pool.sync(cache)
        k_got, v_got = _page_of(cache, pool, skey, int(pool.table[0, 0]))
        np.testing.assert_array_equal(k_got, k_ref)
        np.testing.assert_array_equal(v_got, v_ref)
        assert not pool._fences and not pool._arrived
    finally:
        pipe.close()


def test_seed_overcommit_errors_not_corrupts(tiny):
    """seed() pins its pages while it allocates and writes: a seed larger
    than the device pool raises the explicit pool-exhausted error. The
    unpinned version silently corrupted — the alloc for a later page would
    evict a just-allocated, not-yet-written page of the SAME lane, spill
    pre-write garbage to host, and drop that page's prompt K/V into the
    trash page."""
    cfg, _, _ = tiny
    pool = KVPagePool(
        cfg, PagedKVConfig(page_size=4, kv_pages=2, max_seq=16), n_lanes=1,
    )
    cache = pool.init_cache()
    rng = np.random.default_rng(5)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.seed(cache, 0, _rand_kv(pool, rng, 12), 12)
    assert not pool._pinned  # pins released even on the error path


def test_seed_pressure_spills_other_lane_losslessly(tiny):
    """Seeding lane B under pool pressure may evict lane A's pages — but
    only WRITTEN ones (lane B's own in-flight pages are pinned), so the
    spill round-trips lane A's exact bytes."""
    cfg, _, _ = tiny
    pool = KVPagePool(
        cfg, PagedKVConfig(page_size=4, kv_pages=4, max_seq=16), n_lanes=2,
    )
    cache = pool.init_cache()
    rng = np.random.default_rng(6)
    kv0 = _rand_kv(pool, rng, 8)
    cache = pool.seed(cache, 0, kv0, 8)           # 2 pages
    cache = pool.seed(cache, 1, _rand_kv(pool, rng, 12), 12)  # 3 pages: evicts
    assert sum(1 for k in pool._spill if k[0] == 0) == 1
    pool.release_lane(1)
    cache = pool.ensure(cache, 0, 8)              # pages the spill back in
    skey = f"sub{pool.kv_subs[0]}"
    for i in range(2):
        k_got, v_got = _page_of(cache, pool, skey, int(pool.table[0, i]))
        np.testing.assert_array_equal(k_got, kv0[skey][0][:, 4 * i : 4 * i + 4])
        np.testing.assert_array_equal(v_got, kv0[skey][1][:, 4 * i : 4 * i + 4])


def test_pool_full_attention_overcommit_asserts(tiny):
    """Full attention reads every allocated position: a working set larger
    than the device pool must refuse loudly, never silently attend past
    spilled pages."""
    cfg, _, _ = tiny
    pool = KVPagePool(
        cfg, PagedKVConfig(page_size=4, kv_pages=2, max_seq=32), n_lanes=1,
    )
    cache = pool.init_cache()
    cache = pool.ensure(cache, 0, 8)  # exactly the pool: fine
    with pytest.raises(AssertionError, match="full-attention working set"):
        pool.ensure(cache, 0, 12)


# ---------------------------------------------------------------------------
# engine differentials: paged == ring
# ---------------------------------------------------------------------------

_PAGED = PagedKVConfig(page_size=8, kv_pages=4)  # seq_len = 32


def _generate(tiny, paged, quantized=False, prefetch_depth=0, spec=False):
    cfg, params, hp = tiny
    eng = SiDADecodeEngine(
        cfg, params, hp, slots_per_layer=cfg.moe.num_experts, serve_top_k=1,
        quantized_slots=quantized, prefetch_depth=prefetch_depth,
        spec_mode="draft" if spec else "off", spec_k=3,
    )
    out, m = eng.generate(
        np.array([1, 2], np.int32), steps=10, cache_len=32, paged=paged,
    )
    eng.close()
    return out, m


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_engine_paged_matches_ring(tiny, quantized, prefetch_depth):
    ref_out, _ = _generate(tiny, None, quantized, prefetch_depth)
    got, _ = _generate(tiny, _PAGED, quantized, prefetch_depth)
    np.testing.assert_array_equal(ref_out, got)


def test_engine_spec_paged_matches_ring(tiny):
    ref_out, _ = _generate(tiny, None, spec=True)
    got, m = _generate(tiny, _PAGED, spec=True)
    np.testing.assert_array_equal(ref_out, got)
    assert m.tokens == 20


def test_engine_paged_wide_table_matches_ring(tiny):
    """max_seq >> resident pool: the full-attention gather is bounded by
    the pool width (position-ordered allocation means table entries past
    n_pages are always -1), so a wide addressable range must not change
    outputs — and must not be gathered per step."""
    ref_out, _ = _generate(tiny, None)
    got, _ = _generate(
        tiny, PagedKVConfig(page_size=8, kv_pages=4, max_seq=256)
    )
    np.testing.assert_array_equal(ref_out, got)


# ---------------------------------------------------------------------------
# server differentials: paged == ring, chunked == big-bucket
# ---------------------------------------------------------------------------


def _reqs(cfg, seed, n=5):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 16)),)).astype(np.int32),
            max_new_tokens=int(rng.integers(3, 8)),
        )
        for i in range(n)
    ]


def _serve(tiny, reqs, ep_shards=1, **kw):
    cfg, params, hp = tiny
    if ep_shards > 1:
        from repro.launch.mesh import make_ep_mesh
        from repro.core.offload import ShardedStoreConfig
        from repro.sharding.policy import serve_ctx

        kw["ctx"] = serve_ctx(make_ep_mesh(ep_shards))
        kw["sharded"] = ShardedStoreConfig(ep_shards=ep_shards)
    kw.setdefault("buckets", (8, 16))
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
        max_lanes=3, max_prefill_batch=3, **kw,
    )
    srv.run(reqs, realtime=False)
    srv.close()
    return srv


@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_server_paged_matches_ring(tiny, prefetch_depth):
    cfg = tiny[0]
    ring = _serve(tiny, _reqs(cfg, 1), cache_len=32,
                  prefetch_depth=prefetch_depth)
    paged = _serve(tiny, _reqs(cfg, 1), paged=PagedKVConfig(page_size=8, kv_pages=16),
                   prefetch_depth=prefetch_depth)
    assert {r.rid: r.generated for r in ring.completed} == \
           {r.rid: r.generated for r in paged.completed}
    assert paged.summary()["paged_kv"] == 1.0
    assert ring.summary()["paged_kv"] == 0.0


def test_server_spec_paged_matches_ring(tiny):
    cfg = tiny[0]
    kw = dict(spec_mode="draft", spec_k=3)
    ring = _serve(tiny, _reqs(cfg, 1), cache_len=32, **kw)
    paged = _serve(tiny, _reqs(cfg, 1),
                   paged=PagedKVConfig(page_size=8, kv_pages=16), **kw)
    assert {r.rid: r.generated for r in ring.completed} == \
           {r.rid: r.generated for r in paged.completed}


def test_server_spec_at_addressable_edge_matches_ring(tiny):
    """A request that exactly fills the addressable range (P + max_new ==
    cache_len) decodes speculatively without tripping ensure()'s range
    assert: the draft block's overdraft positions are clamped out of the
    ensure target and their writes route to the trash page."""
    cfg = tiny[0]
    prompt = np.random.default_rng(7).integers(
        0, cfg.vocab_size, (8,)
    ).astype(np.int32)
    reqs = lambda: [Request(rid=0, prompt=prompt.copy(), max_new_tokens=24)]
    kw = dict(spec_mode="draft", spec_k=3)
    ring = _serve(tiny, reqs(), cache_len=32, **kw)
    paged = _serve(tiny, reqs(),
                   paged=PagedKVConfig(page_size=8, kv_pages=4), **kw)
    assert len(paged.completed) == 1 and not paged.rejected
    assert ring.completed[0].generated == paged.completed[0].generated
    assert len(paged.completed[0].generated) == 24


def _long_prompt(cfg, P=40, seed=2):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, (P,)
    ).astype(np.int32)


def test_server_chunked_long_prefill_matches_big_bucket(tiny):
    """A 40-token prompt through buckets (8, 16) + 8-token chunks ==
    the same prompt through an unchunked 64-bucket server, token for
    token (capacity never binds — see the fixture)."""
    cfg = tiny[0]
    prompt = _long_prompt(cfg)
    big = _serve(tiny, [Request(rid=0, prompt=prompt.copy(), max_new_tokens=6)],
                 buckets=(64,), cache_len=128)
    chunked = _serve(
        tiny, [Request(rid=0, prompt=prompt.copy(), max_new_tokens=6)],
        paged=PagedKVConfig(page_size=8, kv_pages=16, prefill_chunk=8),
    )
    assert len(chunked.completed) == 1 and not chunked.rejected
    assert big.completed[0].generated == chunked.completed[0].generated
    s = chunked.summary()
    assert s["prefill_chunks"] == 5          # ceil(40 / 8)
    assert s["long_prefills_completed"] == 1
    assert chunked.completed[0].chunk_pos == 40


def test_server_long_and_short_interleave(tiny):
    cfg = tiny[0]
    rng = np.random.default_rng(3)
    mix = [Request(rid=0, prompt=_long_prompt(cfg), max_new_tokens=4)] + [
        Request(rid=1 + i,
                prompt=rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32),
                max_new_tokens=6)
        for i in range(3)
    ]
    srv = _serve(tiny, mix,
                 paged=PagedKVConfig(page_size=8, kv_pages=16, prefill_chunk=8))
    assert sorted(r.rid for r in srv.completed) == [0, 1, 2, 3]


def test_server_admission_rejections(tiny):
    cfg = tiny[0]
    # ring server, no chunking: prompt beyond the largest bucket
    srv = _serve(tiny, [Request(rid=0, prompt=_long_prompt(cfg),
                                max_new_tokens=4)], cache_len=32)
    assert srv.rejected and \
        srv.rejected[0].reject_reason == "prompt_exceeds_max_bucket"
    assert srv.telemetry.counter(
        "requests_rejected_prompt_exceeds_max_bucket").value == 1

    # paged server: prompt + decode budget beyond the page-table width
    srv = _serve(
        tiny, [Request(rid=0, prompt=_long_prompt(cfg), max_new_tokens=200)],
        paged=PagedKVConfig(page_size=8, kv_pages=16, prefill_chunk=8),
    )
    assert srv.rejected and \
        srv.rejected[0].reject_reason == "exceeds_addressable_range"
    assert srv.telemetry.counter(
        "requests_rejected_exceeds_addressable_range").value == 1


@pytest.fixture(scope="module")
def wtiny():
    """`tiny`, but windowed (window=8 sliding attention) — the regime where
    the residency span is bounded and cold pages genuinely spill."""
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
        attn=dataclasses.replace(cfg.attn, window=8,
                                 layer_pattern=("local",)),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
                      cfg.moe.num_experts, d_h=16)
    return cfg, params, hp


def _serve_windowed(wtiny, reqs, paged, lanes=2, buckets=(8, 16)):
    cfg, params, hp = wtiny
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
        max_lanes=lanes, max_prefill_batch=lanes, buckets=buckets,
        paged=paged,
    )
    try:
        srv.run(reqs, realtime=False)
    finally:
        srv.close()
    return srv


def test_server_windowed_tight_budget_pages(wtiny):
    """Windowed attention bounds the residency span, so a long prompt
    streams through a pool SMALLER than its own length — out-of-window
    pages spill to host, and the output is byte-identical to a pool that
    never spills. No page-ins here: with in-span pages pinned through each
    tick, a single lane's window advances monotonically, so only
    out-of-span pages spill and they never re-enter the span (the page-in
    path under pressure is covered by the two-lane test below)."""
    cfg = wtiny[0]
    req = lambda: Request(rid=0, prompt=_long_prompt(cfg), max_new_tokens=6)
    srv = _serve_windowed(
        wtiny, [req()],
        PagedKVConfig(page_size=4, kv_pages=6, prefill_chunk=8, max_seq=64),
    )
    assert len(srv.completed) == 1
    s = srv.summary()
    assert s["kv_page_spills"] > 0
    assert s["kv_pages_allocated"] > 6  # more pages touched than fit at once
    roomy = _serve_windowed(
        wtiny, [req()],
        PagedKVConfig(page_size=4, kv_pages=16, prefill_chunk=8, max_seq=64),
    )
    assert roomy.summary()["kv_page_spills"] == 0
    assert roomy.completed[0].generated == srv.completed[0].generated


def test_server_two_lane_pressure_pages_in(wtiny):
    """Two lanes whose combined touched pages exceed the pool ping-pong it:
    one lane's tick (pinning its own in-span pages) evicts the other's
    in-span pages, whose next tick must page them back in — the counter
    proves the server-level spill→page-in round trip runs, and both lanes'
    outputs stay byte-identical to a pool that never spills."""
    cfg = wtiny[0]
    reqs = lambda: [
        Request(rid=r, prompt=_long_prompt(cfg, seed=r), max_new_tokens=6)
        for r in range(2)
    ]
    srv = _serve_windowed(
        wtiny, reqs(),
        PagedKVConfig(page_size=4, kv_pages=8, prefill_chunk=8, max_seq=64),
    )
    assert len(srv.completed) == 2
    s = srv.summary()
    assert s["kv_page_spills"] > 0 and s["kv_page_ins"] > 0
    roomy = _serve_windowed(
        wtiny, reqs(),
        PagedKVConfig(page_size=4, kv_pages=32, prefill_chunk=8, max_seq=64),
    )
    assert roomy.summary()["kv_page_spills"] == 0
    by_rid = lambda sv: {r.rid: r.generated for r in sv.completed}
    assert by_rid(roomy) == by_rid(srv)


def test_server_chunked_unaligned_max_seq(wtiny):
    """max_seq need not be a multiple of prefill_chunk: the last chunk of a
    near-max prompt pads past the addressable range, its ensure target is
    clamped, and the pad writes route to the trash page — the request
    completes with the same tokens as an aligned-range server instead of
    killing the serve loop."""
    cfg = wtiny[0]
    prompt = _long_prompt(cfg, P=41, seed=9)
    req = lambda: Request(rid=0, prompt=prompt.copy(), max_new_tokens=1)
    srv = _serve_windowed(
        wtiny, [req()],
        PagedKVConfig(page_size=4, kv_pages=8, prefill_chunk=8, max_seq=42),
    )
    assert len(srv.completed) == 1 and not srv.rejected
    aligned = _serve_windowed(
        wtiny, [req()],
        PagedKVConfig(page_size=4, kv_pages=8, prefill_chunk=8, max_seq=48),
    )
    assert aligned.completed[0].generated == srv.completed[0].generated


def test_server_decode_overpressure_errors_not_misattends(wtiny):
    """When the combined in-span working set of the decode batch exceeds
    the page pool, the tick must raise the explicit pool-exhausted error —
    the unpinned version let lane N's ensure() evict an in-span page of an
    already-ensured lane M, and the tick silently dropped lane M's real
    keys through the -1 table entry (wrong logits, no error)."""
    cfg = wtiny[0]
    rng = np.random.default_rng(11)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32),
                max_new_tokens=6)
        for i in range(2)
    ]
    with pytest.raises(RuntimeError, match="exhausted"):
        _serve_windowed(
            wtiny, reqs,
            PagedKVConfig(page_size=4, kv_pages=3, max_seq=32), lanes=2,
            buckets=(8,),
        )


@pytest.mark.slow
def test_longctx_32k_chunked_prefill_smoke():
    """CI long-context smoke: a synthetic 32k-token prompt streams through
    chunked prefill with a device budget of 32 pages (512 resident
    positions) — thousands of cold-page spills later the request still
    decodes and completes. Windowed attention bounds the residency span,
    so the per-chunk working set is O(window + chunk), not O(32k)."""
    # NOTE: default capacity_factor — this is a completion/counter smoke,
    # not a byte-exactness differential, and a high factor would blow up
    # the per-chunk dispatch one-hot ([1, T, E, C] with C ∝ factor·T).
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        attn=dataclasses.replace(cfg.attn, window=64,
                                 layer_pattern=("local",)),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
                      cfg.moe.num_experts, d_h=16)
    P = 32 * 1024 - 8
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (P,)
    ).astype(np.int32)
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
        max_lanes=2, max_prefill_batch=2, buckets=(8, 16),
        paged=PagedKVConfig(page_size=16, kv_pages=32, prefill_chunk=256,
                            max_seq=32 * 1024),
    )
    srv.run([Request(rid=0, prompt=prompt, max_new_tokens=4)],
            realtime=False)
    srv.close()
    assert len(srv.completed) == 1
    assert len(srv.completed[0].generated) == 4
    s = srv.summary()
    assert s["prefill_chunks"] == -(-P // 256)
    assert s["long_prefills_completed"] == 1
    assert s["kv_page_spills"] > 1000  # ~2k pages through a 32-slot pool
    assert s["kv_pages_allocated"] >= P // 16


@needs_devices(2)
@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_ep2_server_paged_matches_ring(tiny, prefetch_depth):
    """Paged-vs-ring byte identity holds under EP=2 sharded serving too
    (page-ins ride shard 0's transfer queue when async)."""
    cfg = tiny[0]
    ring = _serve(tiny, _reqs(cfg, 1), ep_shards=2, cache_len=32,
                  prefetch_depth=prefetch_depth)
    paged = _serve(tiny, _reqs(cfg, 1), ep_shards=2,
                   paged=PagedKVConfig(page_size=8, kv_pages=16),
                   prefetch_depth=prefetch_depth)
    assert {r.rid: r.generated for r in ring.completed} == \
           {r.rid: r.generated for r in paged.completed}


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [0, 6])
def test_flash_decode_paged_matches_ref(window):
    rng = np.random.default_rng(0)
    B, H, K, D, page, n_pages, Mp = 2, 4, 2, 8, 4, 5, 4
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages + 1, page, K, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages + 1, page, K, D)), jnp.float32)
    # lane 0: pages 0..2 resident, page 3 unallocated; lane 1: page 0
    # spilled (-1) — its positions must contribute nothing
    table = jnp.asarray(np.array([[0, 1, 2, -1], [-1, 3, 4, -1]], np.int32))
    pos = jnp.asarray(np.array([10, 9], np.int32))
    got = ops.flash_decode_paged(q, kp, vp, table, pos, window=window)
    want = ref.flash_decode_paged_ref(q, kp, vp, table, pos, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# launcher flag validation
# ---------------------------------------------------------------------------


def _args(flags: str):
    """Parse through the REAL serve parser (launch.serve.build_parser), so
    this test can never drift from the flag surface the way a hand-rolled
    Namespace did — new flags get their argparse defaults automatically."""
    from repro.launch.serve import build_parser

    return build_parser().parse_args(["--engine", "server", *flags.split()])


def test_validate_serve_args():
    validate_serve_args(_args(""))                     # ring mode: fine
    validate_serve_args(_args("--kv-pages 8"))         # paged: fine
    validate_serve_args(_args("--kv-pages 8 --prefill-chunk 8 --max-seq 256"))
    validate_serve_args(_args("--int4-slots --quantized-slots"))

    bad = [
        "--int4-slots",                                # needs quantized slots
        # int4 tiering excludes replication
        "--int4-slots --quantized-slots --replicate-hot 1 --ep-shards 4",
        "--int4-slots --quantized-slots --tier-split 0.0",
        "--int4-slots --quantized-slots --tier-split 1.5",
        "--int4-slots --quantized-slots --quant-group 0",
        "--prefill-chunk 8",                           # chunk needs pages
        "--max-seq 64",                                # max_seq needs pages
        "--kv-pages 8 --engine sida",                  # server-only flags
        "--kv-pages 8 --max-seq 64",                   # max_seq < resident
        "--kv-pages 2 --seq 64",                       # seq > bucket, no chunk
        "--kv-pages 8 --seq 128 --new-tokens 64",      # beyond addressable
        "--kv-pages 8 --spec-mode draft --spec-k 200",
        "--replicate-hot 1",                           # needs ep_shards > 1
        "--rebalance-interval 0.5",                    # needs ep_shards > 1
        "--replicate-hot -1 --ep-shards 4",            # negative
        "--rebalance-interval 0.5 --ep-shards 4 --engine sida",
        "--shed-margin 0.5",                           # shed needs a deadline
        "--tenants a:weight=0",                        # bad tenant contract
        "--tenants a:pin=0",
        "--tenants a,a",                               # duplicate tenants
        "--wfq-quantum 0",
    ]
    for flags in bad:
        with pytest.raises(SystemExit, match="serve: invalid flags"):
            validate_serve_args(_args(flags))
