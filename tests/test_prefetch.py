"""Async prefetch pipeline: correctness under concurrency.

Covers the PrefetchPipeline protocol end to end — ready fences block only
on the experts a step needs, no consumer ever observes a half-written
slot, shutdown joins cleanly, eviction protection for outstanding
tickets, staging-buffer reuse, warm-submit backpressure, work stealing,
and sync-vs-async output equality for both batch serving and the request
server under tight slot budgets.
"""
import time

import jax
import numpy as np
import pytest

import repro.core.offload as offload
from conftest import reduced_params
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.hash_table import HashTable
from repro.core.offload import EXPERT_TENSORS, ExpertStore, PrefetchPipeline
from repro.models.transformer import n_moe_layers


def _store(slots, **kw):
    cfg, params = reduced_params("switch-base-8")
    return cfg, ExpertStore(cfg, params, slots_per_layer=slots, **kw)


def _table(L, experts, idx=0):
    """Table routing every token of one sequence to `experts` (one per
    position) at every MoE layer."""
    n = len(experts)
    ids = np.zeros((L, 1, n, 1), np.int32)
    for j, e in enumerate(experts):
        ids[:, 0, j, 0] = e
    return HashTable(idx, ids, np.ones((L, 1, n, 1), np.float32))


def _assert_resident_matches_host(store):
    for l in range(store.L):
        g, s = store.layer_to_gs(l)
        moe_p = store.serve_params["blocks"][f"sub{s}"]["moe"]
        for e, slot in store.resident[(g, s)].items():
            for t in EXPERT_TENSORS:
                np.testing.assert_array_equal(
                    np.asarray(moe_p[t][g, slot]),
                    store.host[f"sub{s}"][t][g, e],
                    err_msg=f"layer {l} expert {e} tensor {t}",
                )


# ---------------------------------------------------------------------------
# basic protocol
# ---------------------------------------------------------------------------


def test_submit_wait_release_roundtrip():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=2, staging_buffers=2)
    rng = np.random.default_rng(0)
    try:
        for it in range(8):
            experts = rng.integers(0, store.E, size=2)
            t = _table(store.L, experts, it)
            tk = pipe.submit(t)
            assert tk.wait(timeout=20), "fence timed out"
            slot_ids, w = store.translate(t, tk.trans)
            assert (w > 0).all()  # every needed expert resident
            _assert_resident_matches_host(store)
            tk.release()
    finally:
        pipe.close()
    assert pipe.stats.uploads > 0
    assert pipe.stats.submitted == 8


def test_async_matches_sync_batch_serving():
    """The flagship differential: SiDAEngine.serve with the async pipeline
    produces the same logits as synchronous uploads, under eviction."""
    cfg, params = reduced_params("switch-base-8")
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
               for _ in range(4)]
    ea = SiDAEngine(cfg, params, hp, slots_per_layer=2, prefetch_depth=2)
    ea.serve(batches, threaded=True, lookahead=2)
    got = [np.asarray(x) for x in ea.results]
    ea.close()
    es = SiDAEngine(cfg, params, hp, slots_per_layer=2)
    es.serve(batches, threaded=True, lookahead=2)
    ref = [np.asarray(x) for x in es.results]
    for i, (a, b) in enumerate(zip(got, ref)):
        err = np.abs(a - b).max() / max(np.abs(b).max(), 1e-9)
        assert err < 1e-4, (i, err)


# ---------------------------------------------------------------------------
# concurrency: slow transfers, partial fences, half-written slots
# ---------------------------------------------------------------------------


@pytest.fixture
def slow_link(monkeypatch):
    """Model a saturated H2D link: every staged put sleeps first."""

    def patch(delay):
        real = offload._staged_put

        def slow(x):
            time.sleep(delay)
            return real(x)

        monkeypatch.setattr(offload, "_staged_put", slow)

    return patch


def test_fence_blocks_only_on_needed_experts(slow_link):
    slow_link(0.15)
    cfg, store = _store(4)
    pipe = PrefetchPipeline(store, depth=2)
    try:
        warm = pipe.submit(_table(store.L, [0, 1]))
        warm.wait(timeout=60)
        warm.release()
        tk = pipe.submit(_table(store.L, [2]))  # slow upload in flight
        t0 = time.perf_counter()
        tk.wait_experts(0, [0, 1])  # resident, no pending upload
        fast = time.perf_counter() - t0
        t0 = time.perf_counter()
        tk.wait_experts(0, [2])  # must wait for the slow transfer
        slow = time.perf_counter() - t0
        assert fast < 0.1, f"fence on resident experts blocked {fast:.3f}s"
        assert slow >= 0.05 or pipe.stats.uploads >= 3, (
            "fence on the in-flight expert should block until its upload"
        )
        tk.wait(timeout=60)
        tk.release()
    finally:
        pipe.close()


def test_no_half_written_slot_is_observable(slow_link):
    """The ready fence fires only after ALL expert tensors are committed:
    with a slow per-tensor link, waiting the fences and then reading every
    needed expert's three tensors must always match the host copy."""
    slow_link(0.02)
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=2)
    rng = np.random.default_rng(1)
    try:
        for it in range(5):
            t = _table(store.L, rng.integers(0, store.E, size=2), it)
            tk = pipe.submit(t)
            # fence-only wait (no work stealing): exercises the async commit
            for l, ids in tk.needed.items():
                tk.wait_experts(l, ids)
            _assert_resident_matches_host(store)
            tk.release()
    finally:
        pipe.close()


def test_shutdown_drains_and_joins(slow_link):
    slow_link(0.05)
    cfg, store = _store(4)
    pipe = PrefetchPipeline(store, depth=4)
    tk = pipe.submit(_table(store.L, [0, 1, 2]))
    pipe.close()  # must drain the queued upload, then join
    assert not pipe._thread.is_alive()
    assert tk.wait(timeout=0.1), "all fences must be set after close()"
    _assert_resident_matches_host(store)
    assert store._prefetcher is None  # detached: store reusable


def test_close_is_idempotent():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=1)
    pipe.close()
    pipe.close()
    assert not pipe._thread.is_alive()


# ---------------------------------------------------------------------------
# eviction protection + consume-time refresh
# ---------------------------------------------------------------------------


def test_outstanding_ticket_protects_experts_from_planning():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=2)
    try:
        t1 = pipe.submit(_table(store.L, [0, 1]))
        t1.wait(timeout=20)
        # t1 unreleased: its experts cannot be planned out by a new submit
        t2 = pipe.submit(_table(store.L, [2, 3]))
        assert (t2.trans[0][[2, 3]] < 0).all(), (
            "t2's loads must be dropped at plan time while t1 is live"
        )
        res = store.resident[(0, store.moe_subs[0])]
        assert 0 in res and 1 in res
        # release t1: t2's consume-time refresh now re-plans and loads
        t1.release()
        t2.wait(timeout=20)
        assert t2.trans[0][2] >= 0 and t2.trans[0][3] >= 0
        _assert_resident_matches_host(store)
        t2.release()
    finally:
        pipe.close()


def test_refresh_reloads_expert_evicted_after_planning():
    """An expert evicted between a ticket's plan and its consumption is
    re-uploaded at wait() — the translation snapshot self-heals."""
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=4)
    try:
        t1 = pipe.submit(_table(store.L, [0, 1]))
        t1.wait(timeout=20)
        t1.release()
        t2 = pipe.submit(_table(store.L, [0]))
        t2.wait(timeout=20)
        # consume-time priority: a later consumer may displace t2's expert
        t3 = pipe.submit(_table(store.L, [2, 3]))
        t2.release()
        t3.wait(timeout=20)
        t3.release()
        # t2's expert 0 was evicted by t3's refresh; a new consumer of 0
        # reloads it with a fresh slot assignment
        t4 = pipe.submit(_table(store.L, [0]))
        t4.wait(timeout=20)
        assert t4.trans[0][0] >= 0
        _assert_resident_matches_host(store)
        t4.release()
    finally:
        pipe.close()


def test_pinned_experts_survive_async_planning():
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=2)
    try:
        t1 = pipe.submit(_table(store.L, [0, 1]))
        t1.wait(timeout=20)
        t1.release()
        for l in range(store.L):
            store.pin_experts(l, [0, 1])
        t2 = pipe.submit(_table(store.L, [2, 3]))
        t2.wait(timeout=20)
        res = store.resident[(0, store.moe_subs[0])]
        assert 0 in res and 1 in res, "pinned experts were evicted"
        assert (t2.trans[0][[2, 3]] < 0).all()
        t2.release()
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# staging buffers, warm submits, work stealing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_staging", [1, 2, 3])
def test_staging_buffer_counts(n_staging):
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=2, staging_buffers=n_staging)
    rng = np.random.default_rng(2)
    try:
        for it in range(6):
            t = _table(store.L, rng.integers(0, store.E, size=2), it)
            tk = pipe.submit(t)
            tk.wait(timeout=20)
            _assert_resident_matches_host(store)
            tk.release()
    finally:
        pipe.close()


def test_warm_submit_is_fire_and_forget(slow_link):
    slow_link(0.1)
    cfg, store = _store(4)
    pipe = PrefetchPipeline(store, depth=1)
    try:
        tickets = [pipe.submit(_table(store.L, [i % 4]), protect=False)
                   for i in range(6)]
        # backpressure: with depth=1 and a slow link, some warming submits
        # must be skipped instead of queueing behind the backlog
        assert any(t is None for t in tickets) or pipe.stats.warm_skipped > 0
        # warm tickets hold no protection: a consumer can take every slot
        tk = pipe.submit(_table(store.L, [0, 1, 2, 3]))
        tk.wait(timeout=60)
        assert (tk.trans[0][[0, 1, 2, 3]] >= 0).all()
        _assert_resident_matches_host(store)
        tk.release()
    finally:
        pipe.close()


def test_fence_steals_queued_job_from_starved_thread(slow_link):
    """If the transfer thread has not started a ticket's job by fence
    time, the consumer commits it inline — async is never slower than the
    synchronous path because of a starved background thread."""
    slow_link(0.3)
    cfg, store = _store(4)
    pipe = PrefetchPipeline(store, depth=4)
    try:
        # occupy the transfer thread with a slow job
        t1 = pipe.submit(_table(store.L, [0]))
        time.sleep(0.05)  # let the thread take t1's job
        t2 = pipe.submit(_table(store.L, [1]))  # sits queued behind t1
        t2.wait(timeout=60)
        assert pipe.stats.stolen >= 1, "queued job should have been stolen"
        t2.release()
        t1.wait(timeout=60)  # t1's slow upload is still the thread's to finish
        t1.release()
        _assert_resident_matches_host(store)
    finally:
        pipe.close()


def test_steal_wakes_blocked_producer(slow_link):
    """Regression: stealing a queued job frees a queue slot — a producer
    parked in submit() backpressure (depth=1) must be woken, or the
    producer/consumer/transfer trio deadlocks."""
    import threading

    slow_link(0.2)
    cfg, store = _store(4)
    pipe = PrefetchPipeline(store, depth=1)
    try:
        t1 = pipe.submit(_table(store.L, [0]))
        time.sleep(0.05)  # transfer thread takes t1's job
        t2 = pipe.submit(_table(store.L, [1]))  # fills the depth-1 queue
        produced = []

        def producer():
            produced.append(pipe.submit(_table(store.L, [2])))  # blocks

        th = threading.Thread(target=producer)
        th.start()
        time.sleep(0.05)  # let the producer park in backpressure
        t2.wait(timeout=60)  # steals t2's queued job -> must notify
        th.join(timeout=10)
        assert not th.is_alive(), "producer never woke after steal"
        t2.release()
        t1.wait(timeout=60)
        t1.release()
        t3 = produced[0]
        t3.wait(timeout=60)
        t3.release()
        _assert_resident_matches_host(store)
    finally:
        pipe.close()


def test_switch_interval_restored_after_close():
    import sys

    before = sys.getswitchinterval()
    cfg, store = _store(2)
    pipe = PrefetchPipeline(store, depth=1)
    assert sys.getswitchinterval() <= PrefetchPipeline.SWITCH_INTERVAL_S
    pipe.close()
    assert sys.getswitchinterval() == before


def test_int8_quantized_async_uploads():
    cfg, store = _store(2, host_quant="int8")
    pipe = PrefetchPipeline(store, depth=2)
    rng = np.random.default_rng(3)
    try:
        for it in range(4):
            t = _table(store.L, rng.integers(0, store.E, size=2), it)
            tk = pipe.submit(t)
            tk.wait(timeout=20)
            tk.release()
        # dequantised slot contents match host dequantisation
        g, s = store.layer_to_gs(0)
        moe_p = store.serve_params["blocks"][f"sub{s}"]["moe"]
        for e, slot in store.resident[(g, s)].items():
            q = store.host[f"sub{s}"]["w_in"][g, e].astype(np.float32)
            scale = store.host_scale[f"sub{s}"]["w_in"][g, e]
            np.testing.assert_allclose(
                np.asarray(moe_p["w_in"][g, slot], np.float32),
                (q * scale).astype(np.float32), rtol=1e-2, atol=1e-2,
            )
    finally:
        pipe.close()


def test_inflight_cache_affinity_credits_uploads(slow_link):
    slow_link(0.2)
    cfg, store = _store(4)
    pipe = PrefetchPipeline(store, depth=2)
    try:
        t = _table(store.L, [0, 1])
        tk = pipe.submit(t)
        # uploads still in flight: pipeline affinity credits them, the
        # bare store does not
        assert pipe.cache_affinity(t) == 1.0
        assert store.cache_affinity(t) <= 1.0  # may complete quickly
        tk.wait(timeout=60)
        tk.release()
        assert store.cache_affinity(t) == 1.0
    finally:
        pipe.close()
