"""Int8 device-resident expert slots + fused-dequant expert FFN.

Three layers of guarantees:
  * kernel contract — the fused-dequant Pallas kernel (interpret mode on
    CPU) matches the pure-jnp dequantize-then-compute oracle bit-tight;
  * quantization contract — int8 round-trip error is bounded by scale/2
    per element (symmetric round-to-nearest), for both scale granularities;
  * system contract — an int8-resident ExpertStore serves decode logits
    close to the fp-resident store on the E8 miniature config, at 2–4×
    the resident-expert capacity per slot byte.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.core.hash_table import HashTable
from repro.core.offload import (
    ExpertStore,
    PrefetchPipeline,
    pack_nibbles,
    quantize_expert,
    quantize_expert_q4,
    unpack_nibbles,
)
from repro.kernels import ops, ref
from repro.models.attention import ShardingCtx
from repro.models.moe import apply_expert_stack_blocked
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    n_moe_layers,
)

KEY = jax.random.PRNGKey(0)
CTX = ShardingCtx()


def _quantized(w, granularity="channel"):
    q, s = quantize_expert(np.asarray(w), granularity)
    return jnp.asarray(q), jnp.asarray(s)


# ---------------------------------------------------------------------------
# quantization round-trip bound
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("granularity", ["channel", "tensor"])
def test_quantize_roundtrip_error_bound(granularity):
    w = np.asarray(jax.random.normal(KEY, (2, 64, 48))) * 0.3
    q, s = quantize_expert(w, granularity)
    assert q.dtype == np.int8
    assert s.shape == (2, 1, 48)
    err = np.abs(w - q.astype(np.float32) * s)
    # symmetric round-to-nearest: elementwise error <= scale/2 (+ float eps)
    assert (err <= s / 2 + 1e-7).all()
    # channel scales are tighter than (or equal to) the per-tensor scale
    if granularity == "tensor":
        np.testing.assert_array_equal(s, np.broadcast_to(s[..., :1], s.shape))


def test_channel_scales_no_looser_than_tensor():
    w = np.array(jax.random.normal(KEY, (1, 32, 16)))
    w[..., 3] *= 100.0  # one hot channel dominates the tensor absmax
    _, s_ch = quantize_expert(w, "channel")
    _, s_tn = quantize_expert(w, "tensor")
    assert (s_ch <= s_tn + 1e-12).all()
    # per-channel round-trip is strictly better on the quiet channels
    q_ch, _ = quantize_expert(w, "channel")
    q_tn, _ = quantize_expert(w, "tensor")
    err_ch = np.abs(w - q_ch * s_ch).mean()
    err_tn = np.abs(w - q_tn * s_tn).mean()
    assert err_ch < err_tn


# ---------------------------------------------------------------------------
# fused-dequant kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,C,d,F", [
    (1, 128, 128, 128),
    (3, 128, 256, 384),
    (4, 256, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn_q_matches_oracle(E, C, d, F, dtype):
    ks = jax.random.split(KEY, 4)
    xe = jax.random.normal(ks[0], (E, C, d), dtype)
    wi_q, wi_s = _quantized(jax.random.normal(ks[1], (E, d, F)) * 0.05)
    wg_q, wg_s = _quantized(jax.random.normal(ks[2], (E, d, F)) * 0.05)
    wo_q, wo_s = _quantized(jax.random.normal(ks[3], (E, F, d)) * 0.05)
    got = ops.expert_ffn_q(xe, wi_q, wi_s, wg_q, wg_s, wo_q, wo_s)
    want = ref.expert_ffn_q_ref(xe, wi_q, wi_s, wg_q, wg_s, wo_q, wo_s)
    assert got.dtype == xe.dtype
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("act,glu", [("silu", True), ("gelu", False), ("relu", True)])
def test_expert_ffn_q_acts(act, glu):
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 128, 256, 256
    xe = jax.random.normal(ks[0], (E, C, d))
    wi_q, wi_s = _quantized(jax.random.normal(ks[1], (E, d, F)) * 0.05)
    wg_q, wg_s = (None, None)
    if glu:
        wg_q, wg_s = _quantized(jax.random.normal(ks[2], (E, d, F)) * 0.05)
    wo_q, wo_s = _quantized(jax.random.normal(ks[3], (E, F, d)) * 0.05)
    got = ops.expert_ffn_q(xe, wi_q, wi_s, wg_q, wg_s, wo_q, wo_s, act=act)
    want = ref.expert_ffn_q_ref(xe, wi_q, wi_s, wg_q, wg_s, wo_q, wo_s, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_expert_ffn_q_block_sweep():
    """Different BlockSpec tilings must agree (scale epilogue is per-tile)."""
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 256, 128, 256
    xe = jax.random.normal(ks[0], (E, C, d))
    wi_q, wi_s = _quantized(jax.random.normal(ks[1], (E, d, F)) * 0.05)
    wg_q, wg_s = _quantized(jax.random.normal(ks[2], (E, d, F)) * 0.05)
    wo_q, wo_s = _quantized(jax.random.normal(ks[3], (E, F, d)) * 0.05)
    want = ref.expert_ffn_q_ref(xe, wi_q, wi_s, wg_q, wg_s, wo_q, wo_s)
    for bc, bf in [(64, 64), (128, 128), (256, 256), (128, 64)]:
        got = ops.expert_ffn_q(xe, wi_q, wi_s, wg_q, wg_s, wo_q, wo_s,
                               bc=bc, bf=bf)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_expert_ffn_q_close_to_fp():
    """The fused-dequant output tracks the *unquantized* fp FFN within the
    quantization error budget (the end-to-end accuracy contract)."""
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 128, 256, 256
    xe = jax.random.normal(ks[0], (E, C, d))
    wi = jax.random.normal(ks[1], (E, d, F)) * 0.05
    wg = jax.random.normal(ks[2], (E, d, F)) * 0.05
    wo = jax.random.normal(ks[3], (E, F, d)) * 0.05
    got = ops.expert_ffn_q(xe, *_quantized(wi), *_quantized(wg), *_quantized(wo))
    fp = ref.expert_ffn_ref(xe, wi, wg, wo)
    rel = float(jnp.abs(got - fp).max() / jnp.abs(fp).max())
    assert rel < 0.05, rel


def test_apply_expert_stack_blocked_quantized_pallas_vs_jnp():
    """models/moe.py threading: the quantized param dict routes through the
    fused kernel (use_pallas) and the inline-dequant einsum identically."""
    cfg, _ = reduced_params("switch-base-8")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, d_expert=128))
    ks = jax.random.split(KEY, 4)
    E, d, F = 4, cfg.d_model, 128
    xe = jax.random.normal(ks[0], (2, E, 128, d))
    p = {}
    for t, shape in [("w_in", (E, d, F)), ("w_gate", (E, d, F)),
                     ("w_out", (E, F, d))]:
        q, s = _quantized(jax.random.normal(ks[3], shape) * 0.05)
        p[t], p[t + "_scale"] = q, s
    a = apply_expert_stack_blocked(p, xe, cfg, use_pallas=False)
    b = apply_expert_stack_blocked(p, xe, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# ---------------------------------------------------------------------------
# int4: nibble packing + per-group quantization (warm-tier format)
# ---------------------------------------------------------------------------


def _quantized4(w, group=64):
    q, s = quantize_expert_q4(np.asarray(w), group)
    return jnp.asarray(q), jnp.asarray(s)


@pytest.mark.parametrize("k", [1, 7, 8, 15, 64])
def test_nibble_pack_unpack_exact(k):
    """Pack/unpack is exact for every int4 value, including ODD contraction
    dims (the last byte's high nibble is zero padding, sliced off)."""
    rng = np.random.default_rng(0)
    q = rng.integers(-8, 8, (3, k, 5)).astype(np.int8)
    p = pack_nibbles(q)
    assert p.dtype == np.uint8 and p.shape == (3, (k + 1) // 2, 5)
    np.testing.assert_array_equal(unpack_nibbles(p, k), q)
    # the jnp oracle unpack (the kernel's contract) agrees bit-for-bit
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_int4_ref(jnp.asarray(p), k)), q
    )


@pytest.mark.parametrize("group", [16, 32, 64])
def test_quantize_q4_roundtrip_error_bound(group):
    w = np.asarray(jax.random.normal(KEY, (2, 64, 48))) * 0.3
    q, s = quantize_expert_q4(w, group)
    assert q.dtype == np.uint8 and q.shape == (2, 32, 48)
    assert s.shape == (2, 64 // group, 48)
    deq = np.asarray(ref.dequantize_q4_ref(jnp.asarray(q), jnp.asarray(s), 64))
    # symmetric round-to-nearest over 15 levels: error <= group scale / 2
    s_full = np.repeat(s, group, axis=-2)
    assert (np.abs(w - deq) <= s_full / 2 + 1e-7).all()


def test_quantize_q4_odd_contraction_dim():
    """Odd k: one zero row pads the last byte; dequant restores exactly k
    rows and the pad never leaks into the scales."""
    w = np.asarray(jax.random.normal(KEY, (1, 33, 8))) * 0.1
    q, s = quantize_expert_q4(w, group=64)  # 33 % 64 != 0 -> one group
    assert q.shape == (1, 17, 8) and s.shape == (1, 1, 8)
    deq = np.asarray(ref.dequantize_q4_ref(jnp.asarray(q), jnp.asarray(s), 33))
    assert (np.abs(w - deq) <= np.repeat(s, 33, axis=-2) / 2 + 1e-7).all()


def test_q4_group_sweep_vs_int8():
    """Group-size sweep: finer int4 groups are monotonically no looser in
    mean round-trip error, and per-channel int8 beats every int4 group
    (the precision each tier trades for capacity)."""
    w = np.asarray(jax.random.normal(KEY, (2, 128, 32))) * 0.3
    w[:, 5] *= 10.0  # an outlier row: coarse groups absorb it, fine ones don't
    errs = {}
    for group in (128, 64, 32):
        q, s = quantize_expert_q4(w, group)
        deq = np.asarray(
            ref.dequantize_q4_ref(jnp.asarray(q), jnp.asarray(s), 128)
        )
        errs[group] = np.abs(w - deq).mean()
    assert errs[32] <= errs[64] <= errs[128]
    q8, s8 = quantize_expert(w, "channel")
    err8 = np.abs(w - q8.astype(np.float32) * s8).mean()
    assert err8 < errs[32]


# ---------------------------------------------------------------------------
# fused-dequant int4 kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,C,d,F", [
    (1, 128, 128, 128),
    (3, 128, 256, 384),
    (2, 256, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn_q4_matches_oracle(E, C, d, F, dtype):
    ks = jax.random.split(KEY, 4)
    xe = jax.random.normal(ks[0], (E, C, d), dtype)
    wi = _quantized4(jax.random.normal(ks[1], (E, d, F)) * 0.05)
    wg = _quantized4(jax.random.normal(ks[2], (E, d, F)) * 0.05)
    wo = _quantized4(jax.random.normal(ks[3], (E, F, d)) * 0.05)
    got = ops.expert_ffn_q4(xe, *wi, *wg, *wo)
    want = ref.expert_ffn_q4_ref(xe, *wi, *wg, *wo)
    assert got.dtype == xe.dtype
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("act,glu", [("silu", True), ("gelu", False), ("relu", True)])
def test_expert_ffn_q4_acts(act, glu):
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 128, 256, 256
    xe = jax.random.normal(ks[0], (E, C, d))
    wi = _quantized4(jax.random.normal(ks[1], (E, d, F)) * 0.05)
    wg = (None, None)
    if glu:
        wg = _quantized4(jax.random.normal(ks[2], (E, d, F)) * 0.05)
    wo = _quantized4(jax.random.normal(ks[3], (E, F, d)) * 0.05)
    got = ops.expert_ffn_q4(xe, *wi, *wg, *wo, act=act)
    want = ref.expert_ffn_q4_ref(xe, *wi, *wg, *wo, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("group", [32, 64, 128])
def test_expert_ffn_q4_group_sweep(group):
    """Per-group scales do NOT commute with the contraction: each group
    size exercises a different partial-dot split in the kernel epilogue,
    and every one must match the materialized-dequant oracle."""
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 128, 256, 128
    xe = jax.random.normal(ks[0], (E, C, d))
    wi = _quantized4(jax.random.normal(ks[1], (E, d, F)) * 0.05, group)
    wg = _quantized4(jax.random.normal(ks[2], (E, d, F)) * 0.05, group)
    wo = _quantized4(jax.random.normal(ks[3], (E, F, d)) * 0.05, group)
    got = ops.expert_ffn_q4(xe, *wi, *wg, *wo, bf=128)
    want = ref.expert_ffn_q4_ref(xe, *wi, *wg, *wo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_expert_ffn_q4_block_sweep():
    """Different BlockSpec tilings must agree (the per-group epilogue is
    applied per f-tile, so bf must stay a multiple of the w_out group)."""
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 256, 128, 256
    xe = jax.random.normal(ks[0], (E, C, d))
    wi = _quantized4(jax.random.normal(ks[1], (E, d, F)) * 0.05)
    wg = _quantized4(jax.random.normal(ks[2], (E, d, F)) * 0.05)
    wo = _quantized4(jax.random.normal(ks[3], (E, F, d)) * 0.05)
    want = ref.expert_ffn_q4_ref(xe, *wi, *wg, *wo)
    for bc, bf in [(64, 64), (128, 128), (256, 256), (128, 64)]:
        got = ops.expert_ffn_q4(xe, *wi, *wg, *wo, bc=bc, bf=bf)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_expert_ffn_q4_close_to_fp():
    """End-to-end accuracy contract: int4 with per-group scales tracks the
    unquantized fp FFN within the (documented) ~2x-int8 error budget."""
    ks = jax.random.split(KEY, 4)
    E, C, d, F = 2, 128, 256, 256
    xe = jax.random.normal(ks[0], (E, C, d))
    wi = jax.random.normal(ks[1], (E, d, F)) * 0.05
    wg = jax.random.normal(ks[2], (E, d, F)) * 0.05
    wo = jax.random.normal(ks[3], (E, F, d)) * 0.05
    got = ops.expert_ffn_q4(
        xe, *_quantized4(wi), *_quantized4(wg), *_quantized4(wo)
    )
    fp = ref.expert_ffn_ref(xe, wi, wg, wo)
    rel = float(jnp.abs(got - fp).max() / jnp.abs(fp).max())
    assert rel < 0.15, rel


def test_apply_expert_stack_blocked_tiered_pallas_vs_jnp():
    """models/moe.py threading: a TIERED param dict (int8 hot stack + int4
    warm stack) routes each block through its format's kernel (use_pallas)
    and the inline-dequant einsums identically, concatenated back into the
    combined slot order."""
    cfg, _ = reduced_params("switch-base-8")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, d_expert=128))
    ks = jax.random.split(KEY, 6)
    S8, S4, d, F = 2, 2, cfg.d_model, 128
    xe = jax.random.normal(ks[0], (2, S8 + S4, 128, d))
    p = {}
    for i, (t, shape) in enumerate([("w_in", (S8, d, F)), ("w_gate", (S8, d, F)),
                                    ("w_out", (S8, F, d))]):
        q, s = _quantized(jax.random.normal(ks[i + 1], shape) * 0.05)
        p[t], p[t + "_scale"] = q, s
    for i, (t, shape) in enumerate([("w_in", (S4, d, F)), ("w_gate", (S4, d, F)),
                                    ("w_out", (S4, F, d))]):
        q4, s4 = _quantized4(jax.random.normal(ks[i + 3], shape) * 0.05)
        p[t + "_q4"], p[t + "_q4_scale"] = q4, s4
    a = apply_expert_stack_blocked(p, xe, cfg, use_pallas=False)
    b = apply_expert_stack_blocked(p, xe, cfg, use_pallas=True)
    assert a.shape == xe.shape
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# ---------------------------------------------------------------------------
# int8-resident ExpertStore
# ---------------------------------------------------------------------------


def _table(L, E, B=2, S=8, k=1, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, E, (L, B, S, k)).astype(np.int32)
    w = rng.random((L, B, S, k)).astype(np.float32)
    return HashTable(0, ids, w)


def test_quantized_store_slots_are_int8():
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(cfg, params, slots_per_layer=4, quantized_slots=True)
    assert st.quant == "int8"  # implied host tier
    for s in st.moe_subs:
        moe_p = st.serve_params["blocks"][f"sub{s}"]["moe"]
        for t in ("w_in", "w_gate", "w_out"):
            assert moe_p[t].dtype == jnp.int8
            assert moe_p[t + "_scale"].dtype == jnp.float32
            assert moe_p[t + "_scale"].shape[:2] == moe_p[t].shape[:2]


def test_quantized_slot_contents_match_host_no_dequant():
    """Slot rows must be the host int8 rows verbatim — the residency format
    is the transfer format (tentpole invariant: no dequant hop)."""
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(cfg, params, slots_per_layer=4, quantized_slots=True)
    table = _table(st.L, st.E)
    trans = st.prepare(table)
    l = 0
    g, s = st.layer_to_gs(l)
    moe_p = st.serve_params["blocks"][f"sub{s}"]["moe"]
    for e in np.unique(table.expert_ids[l]):
        slot = trans[l, e]
        np.testing.assert_array_equal(
            np.asarray(moe_p["w_in"][g, slot]), st.host[f"sub{s}"]["w_in"][g, e]
        )
        np.testing.assert_array_equal(
            np.asarray(moe_p["w_in_scale"][g, slot]),
            st.host_scale[f"sub{s}"]["w_in"][g, e],
        )


def test_quantized_capacity_at_equal_bytes():
    """≥2× resident-expert capacity per slot byte (the headline win; ~3.8×
    here because the reduced configs keep weights in f32)."""
    cfg, params = reduced_params("switch-base-8")
    st_fp = ExpertStore(cfg, params, slots_per_layer=2)
    st_q = ExpertStore(cfg, params, slots_per_layer=2, quantized_slots=True)
    assert st_fp.expert_slot_bytes() >= 2 * st_q.expert_slot_bytes()
    assert st_q.device_bytes() < st_fp.device_bytes()


def test_prefetch_pipeline_uploads_quantized_slabs():
    """Async path: the transfer thread commits int8 slabs + scale planes
    directly (no dequant hop), and fenced consumers see exact host rows."""
    cfg, params = reduced_params("switch-base-8")
    st = ExpertStore(cfg, params, slots_per_layer=4, quantized_slots=True)
    with PrefetchPipeline(st, depth=2) as pf:
        table = _table(st.L, st.E, seed=1)
        ticket = pf.submit(table)
        assert ticket.wait(timeout=30.0)
        l = 0
        g, s = st.layer_to_gs(l)
        moe_p = st.serve_params["blocks"][f"sub{s}"]["moe"]
        for e in np.unique(table.expert_ids[l]):
            slot = ticket.trans[l, e]
            assert slot >= 0
            np.testing.assert_array_equal(
                np.asarray(moe_p["w_in"][g, slot]),
                st.host[f"sub{s}"]["w_in"][g, e],
            )
        ticket.release()


# ---------------------------------------------------------------------------
# differential: quantized-slot serving vs fp-slot serving (E8 config)
# ---------------------------------------------------------------------------


def _e8_system():
    """Miniature E8 Switch (8 experts — reduced() caps at 4, so rebuild)."""
    cfg, _ = reduced_params("switch-base-8")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=8, d_expert=64)
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_forward_quant_slots_close_to_fp_slots():
    cfg, params = _e8_system()
    st_fp = ExpertStore(cfg, params, slots_per_layer=8)
    st_q = ExpertStore(cfg, params, slots_per_layer=8, quantized_slots=True)
    L, E = st_fp.L, st_fp.E
    table = _table(L, E, B=2, S=8, seed=2)
    s_fp, w_fp = st_fp.translate(table, st_fp.prepare(table))
    table2 = HashTable(0, table.expert_ids.copy(), table.weights.copy())
    s_q, w_q = st_q.translate(table2, st_q.prepare(table2))
    np.testing.assert_array_equal(w_fp, w_q)  # same residency plan
    toks = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    out_fp = forward(st_fp.serve_params, cfg, CTX, jnp.asarray(toks),
                     routing_override=(jnp.asarray(s_fp), jnp.asarray(w_fp)))["logits"]
    out_q = forward(st_q.serve_params, cfg, CTX, jnp.asarray(toks),
                    routing_override=(jnp.asarray(s_q), jnp.asarray(w_q)))["logits"]
    rel = float(jnp.abs(out_fp - out_q).max() / jnp.abs(out_fp).max())
    assert rel < 2e-2, rel


def test_decode_quant_slots_close_to_fp_slots():
    """Token-by-token decode (the moe_decode path) with int8 slots pins to
    the fp-slot logits within the quantization budget on the E8 config."""
    cfg, params = _e8_system()
    st_fp = ExpertStore(cfg, params, slots_per_layer=8)
    st_q = ExpertStore(cfg, params, slots_per_layer=8, quantized_slots=True)
    L, E = st_fp.L, st_fp.E
    B, steps = 2, 4
    rng = np.random.default_rng(0)
    caches = {
        "fp": init_cache(cfg, B, 16),
        "q": init_cache(cfg, B, 16),
    }
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
    worst = 0.0
    for step in range(steps):
        ids = rng.integers(0, E, (L, B, 1)).astype(np.int32)
        w = np.ones((L, B, 1), np.float32)
        table = HashTable(step, ids[:, :, None, :], w[:, :, None, :])
        outs = {}
        for name, st in (("fp", st_fp), ("q", st_q)):
            t = HashTable(step, table.expert_ids.copy(), table.weights.copy())
            slot_ids, ww = st.translate(t, st.prepare(t))
            logits, caches[name] = decode_step(
                st.serve_params, caches[name], toks, cfg, CTX,
                routing_override=(jnp.asarray(slot_ids[:, :, 0, :]),
                                  jnp.asarray(ww[:, :, 0, :])),
            )
            outs[name] = logits
        rel = float(jnp.abs(outs["fp"] - outs["q"]).max()
                    / jnp.abs(outs["fp"]).max())
        worst = max(worst, rel)
        # both lanes advance on the SAME token stream so caches stay aligned
        toks = jnp.argmax(outs["fp"], -1).astype(jnp.int32)
    assert worst < 2e-2, worst
