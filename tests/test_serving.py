"""Request-level serving subsystem: scheduler units (bucketing, lane
join/leave, deadline ordering, affinity tie-break), telemetry, and the
end-to-end consistency of the request server against the batch engines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.decode_engine import hash_fn_step
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.hash_table import HashTable
from repro.core.offload import ExpertStore
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, init_params, n_moe_layers
from repro.serving import (
    LaneTable,
    Request,
    RequestServer,
    RequestState,
    Scheduler,
    Telemetry,
    bucket_len,
    poisson_requests,
)

CTX = ShardingCtx()


# ---------------------------------------------------------------------------
# scheduler units
# ---------------------------------------------------------------------------


def _req(rid, plen=8, arrival=0.0, slo=None, table="dummy"):
    r = Request(
        rid=rid,
        prompt=np.arange(plen, dtype=np.int32),
        max_new_tokens=4,
        arrival_s=arrival,
        slo_s=slo,
    )
    if table == "dummy":  # scheduler only needs presence, not content
        r.table = HashTable(rid, np.zeros((1, 1, plen, 1), np.int32),
                            np.ones((1, 1, plen, 1), np.float32))
    return r


def test_bucket_len():
    assert bucket_len(1, (8, 16)) == 8
    assert bucket_len(8, (8, 16)) == 8
    assert bucket_len(9, (8, 16)) == 16
    with pytest.raises(ValueError):
        bucket_len(17, (8, 16))


def test_lane_table_join_leave():
    lanes = LaneTable(2)
    a, b = _req(0), _req(1)
    la, lb = lanes.assign(a), lanes.assign(b)
    assert lanes.free_count() == 0 and sorted([la, lb]) == [0, 1]
    assert a.lane == la
    with pytest.raises(IndexError):
        lanes.assign(_req(2))  # full
    assert lanes.release(la) is a and a.lane == -1
    assert lanes.free_count() == 1
    c = _req(3)
    assert lanes.assign(c) == la  # freed lane is reused
    assert set(lanes.active()) == {la, lb}


def test_scheduler_deadline_ordering():
    s = Scheduler(buckets=(8, 16))
    # same bucket, deadlines out of arrival order
    s.enqueue(_req(0, arrival=0.0, slo=9.0))
    s.enqueue(_req(1, arrival=0.0, slo=1.0))
    s.enqueue(_req(2, arrival=0.0, slo=5.0))
    batch, bucket = s.next_prefill_batch(now=0.0, max_batch=2)
    assert bucket == 8
    assert [r.rid for r in batch] == [1, 2]  # earliest deadlines first
    assert all(r.state == RequestState.PREFILL for r in batch)
    assert s.pending() == 1


def test_scheduler_buckets_by_anchor_length():
    s = Scheduler(buckets=(8, 16))
    s.enqueue(_req(0, plen=12, slo=1.0))   # most urgent -> anchors bucket 16
    s.enqueue(_req(1, plen=4, slo=2.0))    # bucket 8: left behind
    s.enqueue(_req(2, plen=16, slo=3.0))   # bucket 16: rides along
    batch, bucket = s.next_prefill_batch(now=0.0, max_batch=4)
    assert bucket == 16
    assert [r.rid for r in batch] == [0, 2]
    assert s.pending() == 1


def test_scheduler_waits_for_hash_ahead():
    s = Scheduler(buckets=(8,))
    s.enqueue(_req(0, table=None))  # admitted but hash table not built yet
    batch, _ = s.next_prefill_batch(now=0.0, max_batch=1)
    assert batch == []
    assert s.pending() == 1


def test_scheduler_pop_expired():
    s = Scheduler(buckets=(8,))
    s.enqueue(_req(0, arrival=0.0, slo=1.0))
    s.enqueue(_req(1, arrival=0.0, slo=None))  # no SLO: never expires
    dropped = s.pop_expired(now=5.0)
    assert [r.rid for r in dropped] == [0]
    assert dropped[0].state == RequestState.REJECTED
    assert s.pending() == 1


def test_scheduler_affinity_orders_within_band(tiny_moe):
    cfg, params, hp = tiny_moe
    store = ExpertStore(cfg, params, slots_per_layer=2)
    L, E = store.L, store.E
    resident = HashTable(9, np.zeros((L, 1, 4, 1), np.int32),
                         np.ones((L, 1, 4, 1), np.float32))
    store.prepare(resident)  # expert 0 resident everywhere

    def req_with_experts(rid, e):
        r = _req(rid, plen=4)
        r.table = HashTable(rid, np.full((L, 1, 4, 1), e, np.int32),
                            np.ones((L, 1, 4, 1), np.float32))
        return r

    s = Scheduler(buckets=(8,))
    s.enqueue(req_with_experts(0, 1))  # cold
    s.enqueue(req_with_experts(1, 0))  # fully resident
    batch, _ = s.next_prefill_batch(now=0.0, max_batch=1, store=store)
    assert [r.rid for r in batch] == [1]  # affinity wins inside the band


def test_chunk_urgent_deadline_accounting():
    """Chunked prefill runs before decode only when the remaining slack no
    longer covers the remaining chunks at the observed per-chunk rate plus
    one slack band; SLO-less requests always yield to decode."""
    s = Scheduler(buckets=(8,), slack_band_s=0.25)
    r = _req(0, arrival=0.0, slo=10.0)          # deadline at t=10
    # 4 chunks * 1s + 0.25 band = 4.25s needed
    assert not s.chunk_urgent(r, now=0.0, remaining_chunks=4, chunk_s=1.0)
    assert not s.chunk_urgent(r, now=5.0, remaining_chunks=4, chunk_s=1.0)
    assert s.chunk_urgent(r, now=6.0, remaining_chunks=4, chunk_s=1.0)
    # an unmeasured chunk rate is floored, not treated as free
    assert s.chunk_urgent(r, now=9.9, remaining_chunks=1, chunk_s=0.0)
    # no SLO: never urgent, however far along the clock is
    assert not s.chunk_urgent(
        _req(1), now=1e9, remaining_chunks=100, chunk_s=1.0
    )


def test_scheduler_affinity_memoized_per_epoch():
    """cache_affinity is an O(L·E) scan under the store lock: the
    scheduler scans each queued table once per residency epoch, not once
    per tick, and rescans when the epoch moves."""

    class CountingStore:
        affinity_epoch = 0
        calls = 0

        def cache_affinity(self, table):
            self.calls += 1
            return 0.5

    st = CountingStore()
    s = Scheduler(buckets=(8,))
    s.enqueue(_req(0, slo=1.0))
    s.enqueue(_req(1, slo=2.0))
    s._order(list(s._queue), 0.0, st)
    s._order(list(s._queue), 0.0, st)     # second tick, same residency
    assert st.calls == 2, "one scan per request, not per tick"
    st.affinity_epoch = 1                 # residency moved
    s._order(list(s._queue), 0.0, st)
    assert st.calls == 4


def test_histogram_percentile_nearest_rank_errs_high():
    """Ceil-based nearest rank: at least a q-fraction of the samples lie
    at or below the reported value, so small-count SLO tails err high
    (banker's rounding would pick the lower neighbor for p50 of an even
    count and understate latency)."""
    from repro.serving.telemetry import Histogram

    h = Histogram()
    for v in [1.0, 2.0, 3.0, 4.0]:
        h.observe(v)
    assert h.percentile(50) == 3.0        # not 2.0
    assert h.percentile(33) == 2.0
    assert h.percentile(0) == 1.0
    assert h.percentile(100) == 4.0
    single = Histogram()
    single.observe(5.0)
    assert single.percentile(99) == 5.0


def test_telemetry_snapshot_roundtrip():
    import json

    t = Telemetry()
    t.counter("a").inc(3)
    t.gauge("g").set(2)
    t.gauge("g").set(1)
    for v in [1.0, 2.0, 3.0, 4.0]:
        t.histogram("h").observe(v)
    snap = json.loads(t.to_json())
    assert snap["counters"]["a"] == 3
    assert snap["gauges"]["g"] == {"last": 1, "max": 2}
    assert snap["histograms"]["h"]["count"] == 4
    assert snap["histograms"]["h"]["p50"] == pytest.approx(3.0, abs=1.0)
    assert snap["histograms"]["h"]["p99"] == 4.0


def test_poisson_requests_monotone_arrivals():
    rng = np.random.default_rng(0)
    reqs = poisson_requests(rng, 20, rate_rps=10.0, vocab_size=100, slo_s=5.0)
    arr = [r.arrival_s for r in reqs]
    assert all(b >= a for a, b in zip(arr, arr[1:]))
    assert all(r.deadline_s == r.arrival_s + 5.0 for r in reqs)
    assert all(0 <= r.prompt.min() and r.prompt.max() < 100 for r in reqs)


# ---------------------------------------------------------------------------
# request server end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_moe():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    return cfg, params, hp


def _serve(cfg, params, hp, reqs, lanes, **kw):
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
        max_lanes=lanes, max_prefill_batch=lanes, buckets=(8, 16),
        cache_len=32, **kw,
    )
    srv.run(reqs, realtime=False)
    return srv


def test_server_prefill_matches_engine_serve(tiny_moe):
    """Identical batch composition => the request server's prefill logits
    equal SiDAEngine.serve's (one prefill batch of 4 same-length prompts)."""
    cfg, params, hp = tiny_moe
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
               for _ in range(4)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=1) for i, p in enumerate(prompts)]
    srv = RequestServer(
        cfg, params, hp, slots_per_layer=cfg.moe.num_experts,
        max_lanes=4, max_prefill_batch=4, buckets=(8, 16), cache_len=32,
        keep_prefill_logits=True,
    )
    # pre-admit everything so one prefill batch carries all four requests
    for r in reqs:
        srv.build_request_table(r)
        srv.admit(r, 0.0)
    srv.run([], realtime=False)
    assert len(srv.completed) == 4
    assert srv.telemetry.counter("prefill_batches").value == 1

    eng = SiDAEngine(cfg, params, hp, slots_per_layer=cfg.moe.num_experts)
    eng.serve([np.stack(prompts)], threaded=False)
    ref = eng.results[0]  # [4, 8, V]
    for i, p in enumerate(prompts):
        got = next(r for r in srv.completed if r.rid == i).prefill_logits
        err = np.abs(got - ref[i]).max() / np.abs(ref[i]).max()
        assert err < 1e-4, (i, err)


def test_server_decode_matches_teacher_forced_forward(tiny_moe):
    """The decode lanes (prefill-seeded KV cache + incremental hash routing)
    must reproduce a teacher-forced full forward over the final sequence
    with the equivalent routing override."""
    cfg, params, hp = tiny_moe
    rng = np.random.default_rng(1)
    P, G = 8, 5
    prompt = rng.integers(0, cfg.vocab_size, (P,)).astype(np.int32)
    reqs = [Request(rid=0, prompt=prompt, max_new_tokens=G)]
    srv = _serve(cfg, params, hp, reqs, lanes=1)
    gen = srv.completed[0].generated
    assert len(gen) == G

    # replay: bidirectional table over the prompt (what prefill used) +
    # incremental causal predictions for each generated position
    E, k, L = cfg.moe.num_experts, srv.k, srv.L
    table = srv.engine.build_table(0, prompt[None, :])
    seq = np.concatenate([prompt, np.asarray(gen[:-1], np.int32)])
    ids = np.zeros((L, 1, len(seq), k), np.int32)
    w = np.zeros((L, 1, len(seq), k), np.float32)
    ids[:, :, :P] = table.expert_ids
    w[:, :, :P] = table.weights
    state = srv._hash_prefill(
        hp, params["embed"], jnp.asarray(prompt[None, :]),
        jnp.asarray(np.array([P], np.int32)),
    )
    for j, tok in enumerate(gen[:-1]):
        emb = jnp.take(params["embed"], jnp.asarray([tok]), axis=0)
        logits_h, state = hash_fn_step(hp, emb, state, E)
        vals, top = jax.lax.top_k(logits_h, k)              # [1, L, k]
        ids[:, 0, P + j] = np.asarray(top)[0]
        w[:, 0, P + j] = np.asarray(jax.nn.softmax(vals, axis=-1))[0]

    store = ExpertStore(cfg, params, slots_per_layer=E)
    full = HashTable(0, ids, w)
    slot_ids, ww = store.translate(full, store.prepare(full))
    out = forward(
        store.serve_params, cfg, CTX, jnp.asarray(seq[None, :]),
        routing_override=(jnp.asarray(slot_ids), jnp.asarray(ww)),
    )
    pred = np.argmax(np.asarray(out["logits"])[0, P - 1:], axis=-1)
    np.testing.assert_array_equal(pred, np.asarray(gen))


def test_server_interleaving_is_transparent(tiny_moe):
    """Continuous batching must not change any request's tokens: serving a
    stream through 3 lanes (join/leave mid-flight) equals serving the same
    requests one at a time."""
    cfg, params, hp = tiny_moe
    rng = np.random.default_rng(2)
    reqs = poisson_requests(
        rng, 6, rate_rps=1e6, vocab_size=cfg.vocab_size,
        prompt_len_range=(4, 14), max_new_range=(4, 8),
    )

    def clone(rs):
        return [dataclasses.replace(r, generated=[], table=None) for r in rs]

    s_multi = _serve(cfg, params, hp, clone(reqs), lanes=3)
    s_one = _serve(cfg, params, hp, clone(reqs), lanes=1)
    got_multi = {r.rid: r.generated for r in s_multi.completed}
    got_one = {r.rid: r.generated for r in s_one.completed}
    assert got_multi == got_one
    # and the multi-lane run actually interleaved decode with joins
    assert s_multi.telemetry.gauge("active_lanes").max > 1


def test_server_slo_drop_expired(tiny_moe):
    """Admission control: a request whose deadline passed before prefill is
    rejected, the rest are served."""
    cfg, params, hp = tiny_moe
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    reqs = [
        Request(rid=0, prompt=prompt, max_new_tokens=2, arrival_s=0.0,
                slo_s=-1.0),  # already expired at arrival
        Request(rid=1, prompt=prompt, max_new_tokens=2, arrival_s=0.0,
                slo_s=1e6),
    ]
    srv = _serve(cfg, params, hp, reqs, lanes=2, drop_expired=True)
    assert [r.rid for r in srv.rejected] == [0]
    assert srv.rejected[0].state == RequestState.REJECTED
    # the drop goes through _reject like every other rejection path, so
    # the reason and its per-reason counter are populated
    assert srv.rejected[0].reject_reason == "deadline_expired"
    assert srv.telemetry.counter("requests_rejected_deadline_expired").value == 1
    assert [r.rid for r in srv.completed] == [1]
    assert srv.summary()["rejected"] == 1
