"""ServingConfig API: the consolidated config object must be a pure
re-packaging of RequestServer's historical kwargs surface and of the CLI
flag namespace — same validation, same behaviour, byte-identical serving.

Three contracts are pinned here:
  * flag -> config round-trip: every CLI flag that maps 1:1 onto a
    ServingConfig field (SERVE_FLAGS' `path`) lands on that field through
    `build_parser()` + `ServingConfig.from_args`;
  * kwargs shim: `ServingConfig.from_kwargs` covers exactly the legacy
    keyword names (KWARG_PATHS), rejects unknown names with TypeError like
    a real signature, and mixing `config=` with kwargs is an error;
  * equivalence differential: a server built from flat kwargs and one built
    from the equivalent ServingConfig produce byte-identical token streams
    and identical telemetry counters on the same workload.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.hash_fn import init_hash_fn
from repro.models.transformer import init_params, n_moe_layers
from repro.serving import (
    RequestServer,
    ServingConfig,
    TenantConfig,
    parse_tenants,
    poisson_requests,
)
from repro.serving.config import (
    KWARG_PATHS,
    SERVE_FLAGS,
    ServingConfigError,
    resolve_path,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# flag -> config round-trip
# ---------------------------------------------------------------------------

# every 1:1 flag with a non-default sample value; extra flags satisfy
# cross-field validation (e.g. --rebalance-interval needs --ep-shards > 1)
FLAG_SAMPLES = {
    "--slots": (["--slots", "3"], 3),
    "--eviction": (["--eviction", "lru"], "lru"),
    "--prefetch-depth": (["--prefetch-depth", "2"], 2),
    "--staging-buffers": (["--staging-buffers", "3"], 3),
    "--host-quant": (["--host-quant", "int8"], "int8"),
    "--quantized-slots": (["--quantized-slots"], True),
    "--scale-granularity": (["--scale-granularity", "tensor"], "tensor"),
    "--spec-mode": (["--spec-mode", "draft"], "draft"),
    "--spec-k": (["--spec-k", "2"], 2),
    "--rebalance-interval": (
        ["--ep-shards", "2", "--rebalance-interval", "1.5"], 1.5),
    "--lanes": (["--lanes", "2"], 2),
    "--prefill-batch": (["--prefill-batch", "2"], 2),
    "--drop-expired": (["--drop-expired"], True),
    "--wfq-quantum": (["--wfq-quantum", "32"], 32.0),
}


def _parse(extra):
    from repro.launch.serve import build_parser

    return build_parser().parse_args(["--engine", "server", *extra])


def test_flag_roundtrip_full_matrix():
    """Every SERVE_FLAGS entry with a dotted path round-trips a non-default
    CLI value onto exactly that ServingConfig field — and the test fails if
    a new 1:1 flag is added without a sample here."""
    pathful = {s.flag for s in SERVE_FLAGS if s.path is not None}
    assert pathful == set(FLAG_SAMPLES), (
        "add a FLAG_SAMPLES entry for every pathful SERVE_FLAGS spec"
    )
    for spec in SERVE_FLAGS:
        if spec.path is None:
            continue
        extra, want = FLAG_SAMPLES[spec.flag]
        cfg = ServingConfig.from_args(_parse(extra))
        assert resolve_path(cfg, spec.path) == want, spec.flag


def test_flag_defaults_build_valid_config():
    cfg = ServingConfig.from_args(_parse([]))
    assert cfg.slots_per_layer == 2
    assert cfg.eviction == "fifo"
    assert cfg.batching.buckets == (8, 16, 32)  # ladder from --seq 32
    assert not cfg.multitenant and cfg.tenants == ()


def test_composite_flags_build_subobjects():
    cfg = ServingConfig.from_args(_parse(
        ["--kv-pages", "16", "--page-size", "8", "--prefill-chunk", "16",
         "--quantized-slots", "--int4-slots", "--tier-split", "0.5",
         "--fault-plan", "upload:fail@1", "--fence-timeout", "0.5"]))
    assert cfg.paged is not None and cfg.paged.kv_pages == 16
    assert cfg.quant.tier is not None and cfg.quant.tier.int4_slots
    assert cfg.faults.plan is not None
    assert cfg.prefetch.fence_timeout_s == 0.5


def test_tenant_flag_parses_registry():
    cfg = ServingConfig.from_args(_parse(
        ["--tenants", "paid:weight=4:pin=0.5,free:rate=200:burst=50"]))
    assert cfg.multitenant and len(cfg.tenants) == 2
    paid, free = cfg.tenants
    assert (paid.name, paid.weight, paid.pin_quota) == ("paid", 4.0, 0.5)
    assert (free.token_rate, free.burst) == (200.0, 50.0)
    assert cfg.tenant("paid") is paid
    assert cfg.tenant("nobody") is None


def test_parse_tenants_grammar_errors():
    for bad in ["a:weight=0", "a:pin=1.5", "a,a", "a:bogus=1",
                "a:weight=x", ":weight=1", "a:weight"]:
        with pytest.raises(ServingConfigError):
            parse_tenants(bad)
    assert parse_tenants("") == ()  # empty spec = single-tenant
    t = parse_tenants("solo")[0]
    assert t == TenantConfig(name="solo")  # all budgets default to unlimited


# ---------------------------------------------------------------------------
# kwargs shim
# ---------------------------------------------------------------------------


def test_from_kwargs_covers_legacy_surface():
    # every legacy name resolves to a real field on a default config
    cfg = ServingConfig()
    for name, path in KWARG_PATHS.items():
        resolve_path(cfg, path)  # raises AttributeError on drift
    got = ServingConfig.from_kwargs(
        slots_per_layer=5, max_lanes=3, buckets=[16, 8], prefetch_depth=2,
        quantized_slots=True, drop_expired=True,
    )
    assert got.slots_per_layer == 5
    assert got.batching.max_lanes == 3
    assert got.batching.buckets == (8, 16)  # normalised like the old server
    assert got.prefetch.depth == 2
    assert got.quant.quantized_slots and got.batching.drop_expired


def test_from_kwargs_rejects_unknown_names():
    with pytest.raises(TypeError, match="unexpected keyword"):
        ServingConfig.from_kwargs(slotz_per_layer=2)


def test_server_rejects_config_plus_kwargs(tiny):
    cfg, params, hp = tiny
    with pytest.raises(TypeError, match="either"):
        RequestServer(cfg, params, hp, ServingConfig(), max_lanes=2)


# ---------------------------------------------------------------------------
# equivalence differential
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16,
    )
    return cfg, params, hp


def _workload(cfg, n=6):
    rng = np.random.default_rng(7)
    return poisson_requests(
        rng, n, rate_rps=50.0, vocab_size=cfg.vocab_size,
        prompt_len_range=(4, 16), max_new_range=(2, 6),
    )


# counters fully determined by the workload (cache/tick counters like
# expert_hits or h2d_bytes legitimately vary with hash-ahead thread
# interleaving even between two runs of the SAME config — the repo-wide
# invariant is that token streams don't)
STABLE_COUNTERS = (
    "requests_admitted", "requests_completed", "requests_rejected",
    "tokens_generated",
)


def _run(cfg, params, hp, *, config=None, **kw):
    if config is not None:
        srv = RequestServer(cfg, params, hp, config)
    else:
        srv = RequestServer(cfg, params, hp, **kw)
    srv.run(_workload(cfg), realtime=False)
    tokens = {r.rid: list(r.generated) for r in srv.completed}
    all_counters = srv.telemetry.snapshot()["counters"]
    counters = {k: all_counters.get(k, 0) for k in STABLE_COUNTERS}
    srv.close()
    return tokens, counters, srv


def test_kwargs_vs_config_byte_identical(tiny):
    """The acceptance bar for the API redesign: a server configured through
    the legacy flat kwargs and one configured through the equivalent
    ServingConfig produce byte-identical token streams and identical
    (non-timing) telemetry counters on the same workload."""
    cfg, params, hp = tiny
    kwargs = dict(
        slots_per_layer=2, eviction="lru", max_lanes=2, max_prefill_batch=2,
        buckets=(8, 16), cache_len=32,
    )
    config = ServingConfig.from_kwargs(**kwargs)
    tok_a, cnt_a, srv_a = _run(cfg, params, hp, **kwargs)
    tok_b, cnt_b, srv_b = _run(cfg, params, hp, config=config)
    assert tok_a == tok_b  # byte-identical generated tokens per request
    assert cnt_a == cnt_b
    # single-tenant structural identity: no tenant partitions materialise
    assert "tenants" not in srv_a.telemetry.snapshot()
    assert "tenants" not in srv_b.telemetry.snapshot()
    assert not srv_b.multitenant and srv_b.tenant_summary() == {}


def test_degenerate_single_tenant_config_is_identity(tiny):
    """A ServingConfig that names no tenants must run the exact pre-tenant
    scheduler (plain Scheduler, no WFQ layer, no per-tenant shed clones)."""
    from repro.serving.scheduler import Scheduler, WFQScheduler

    cfg, params, hp = tiny
    srv = RequestServer(cfg, params, hp, ServingConfig(
        batching=dataclasses.replace(
            ServingConfig().batching, max_lanes=2, max_prefill_batch=2,
            buckets=(8, 16), cache_len=32),
    ))
    assert type(srv.scheduler) is Scheduler
    assert not isinstance(srv.scheduler, WFQScheduler)
    assert srv._shed_mt is None
    srv.close()


def test_legacy_positional_slots_still_works(tiny):
    cfg, params, hp = tiny
    srv = RequestServer(cfg, params, hp, 3)  # historical 4th positional
    assert srv.config.slots_per_layer == 3
    srv.close()


# ---------------------------------------------------------------------------
# the public surface itself is snapshot-checked
# ---------------------------------------------------------------------------


def test_api_snapshot_is_current():
    """tools/check_api.py against the committed snapshot — the same gate CI
    runs, so a local `pytest` catches API drift before push."""
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_api.py")],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_readme_flag_table_is_current():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "gen_flags.py"),
         "--check"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
