"""Distribution tests: sharding policy specs + an 8-fake-device mini dry-run
in a subprocess (the main test process must keep its single-device backend)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_policy_specs_divisibility():
    script = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import get_config
    from repro.launch.mesh import make_mesh
    from repro.sharding import policy

    mesh = make_mesh((2, 4), ("data", "model"))
    for name in ["smollm-135m", "qwen3-moe-235b-a22b"]:
        cfg = get_config(name).reduced()
        specs = policy.param_specs(cfg, mesh)
        # every spec axis must divide its dim
        import jax.numpy as jnp
        from functools import partial
        from repro.models.transformer import init_params
        shapes = jax.eval_shape(partial(init_params, cfg=cfg), jax.random.PRNGKey(0))
        for (path, spec), (_, shp) in zip(
            jax.tree_util.tree_flatten_with_path(specs, is_leaf=lambda x: isinstance(x, P))[0],
            jax.tree_util.tree_flatten_with_path(shapes)[0],
        ):
            for dim, ax in zip(shp.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                ext = 1
                for a in axes:
                    ext *= mesh.shape[a]
                assert dim % ext == 0, (path, shp.shape, spec)
    print("SPECS-OK")
    """
    assert "SPECS-OK" in _run(script)


def test_mini_mesh_train_and_decode_lower():
    """Reduced config x tiny shapes on a (2,4) mesh: train + decode must
    lower, compile, and produce collectives (the sharding is real)."""
    script = """
    import jax
    from repro.configs.base import get_config, InputShape
    from repro.launch.dryrun import build_lowering
    from repro.launch.mesh import make_mesh
    from repro.launch.hlo_analysis import analyse_hlo

    mesh = make_mesh((2, 4), ("data", "model"))
    for name in ["switch-base-8", "gemma2-9b", "xlstm-125m", "seamless-m4t-medium"]:
        cfg = get_config(name).reduced()
        for shape in [InputShape("t", 64, 8, "train"), InputShape("d", 64, 8, "decode")]:
            lowered, _ = build_lowering(cfg, shape, mesh)
            compiled = lowered.compile()
            a = analyse_hlo(compiled.as_text())
            assert a["flops"] > 0, (name, shape.kind)
            print(f"{name} {shape.kind} OK coll={a['collective_total_bytes']>0}")
    print("MINI-MESH-OK")
    """
    out = _run(script)
    assert "MINI-MESH-OK" in out


def test_multipod_mesh_lowering():
    """(2,2,2) pod mesh: the pod axis must shard (multi-pod proof at test scale)."""
    script = """
    import jax
    from repro.configs.base import get_config, InputShape
    from repro.launch.dryrun import build_lowering
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("deepseek-moe-16b").reduced()
    lowered, _ = build_lowering(cfg, InputShape("t", 32, 8, "train"), mesh)
    compiled = lowered.compile()
    assert compiled.memory_analysis().temp_size_in_bytes > 0
    print("MULTIPOD-OK")
    """
    assert "MULTIPOD-OK" in _run(script)


def test_flash_decode_sharded_matches_local():
    """shard_map partial-softmax merge == single-device decode attention."""
    script = """
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.models.attention import ShardingCtx, decode_attention
    from repro.kernels.ref import flash_decode_ref

    mesh = make_mesh((2, 4), ("data", "model"))
    B, H, K, D, S = 2, 4, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    pos = jnp.array([40, 63], jnp.int32)
    sidx = jnp.arange(S)[None, :]
    slot_pos = pos[:, None] - ((pos[:, None] - sidx) % S)
    ctx = ShardingCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                      decode_seq_axis=("model",))
    got = jax.jit(lambda *a: decode_attention(*a, window=0, cap=0.0, ctx=ctx))(
        q, k, v, slot_pos, pos
    )
    want = flash_decode_ref(q, k, v, slot_pos, pos)
    err = float(jnp.abs(got - want).max())
    assert err < 1e-4, err
    print("FLASH-SHARD-OK", err)
    """
    assert "FLASH-SHARD-OK" in _run(script)
