"""Sparsity analyses: Eq. 2, ĉ estimation, sentence-level sparsity."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sparsity import (
    effective_memory_utilization,
    estimate_c,
    expected_phat,
    sentence_sparsity,
)
from repro.configs.base import get_config


def test_eq2_boundaries():
    # p=0: no corruption => no change; p=1: always hits a critical token
    assert expected_phat(0.0, 2, 512) == pytest.approx(0.0, abs=1e-9)
    assert expected_phat(1.0, 1, 512) == pytest.approx(1.0, abs=1e-6)


@given(
    c=st.integers(1, 8), L=st.sampled_from([128, 512]),
    p1=st.floats(0.05, 0.4), dp=st.floats(0.05, 0.5),
)
@settings(max_examples=30, deadline=None)
def test_eq2_monotone_in_p(c, L, p1, dp):
    assert expected_phat(p1 + dp, c, L) >= expected_phat(p1, c, L) - 1e-9


@given(p=st.floats(0.05, 0.9), L=st.sampled_from([128, 512]), c=st.integers(1, 7))
@settings(max_examples=30, deadline=None)
def test_eq2_monotone_in_c(p, L, c):
    assert expected_phat(p, c + 1, L) >= expected_phat(p, c, L) - 1e-9


@given(c_true=st.integers(1, 10))
@settings(max_examples=10, deadline=None)
def test_estimate_c_inverts_eq2(c_true):
    L = 512
    ps = [0.05, 0.1, 0.2, 0.4, 0.6, 0.8]
    phats = [expected_phat(p, c_true, L) for p in ps]
    assert estimate_c(ps, phats, L) == c_true


def test_sentence_sparsity():
    L, B, S, E = 2, 3, 16, 8
    rng = np.random.default_rng(0)
    # sentence 0 uses only expert 0; sentence 1 uses all experts
    ids = np.zeros((L, B, S), np.int64)
    ids[:, 1] = rng.integers(0, E, (L, S))
    ids[:, 2] = np.arange(S) % E
    r = sentence_sparsity(ids, E)
    assert r[0] == pytest.approx(1 - 1 / E)
    assert r[2] == pytest.approx(0.0)
    assert r[0] > r[1] > r[2] - 1e-9


def test_effective_memory_utilization_fig2():
    cfg = get_config("switch-base-128")
    full = effective_memory_utilization(cfg, idle_ratio=0.0)
    sparse = effective_memory_utilization(cfg, idle_ratio=0.8)
    assert full["effective_utilization"] == pytest.approx(1.0)
    assert sparse["effective_utilization"] < 0.35  # MoE dominates switch-128
    assert sparse["ineffective_gb"] > 0
