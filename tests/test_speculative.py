"""Speculative decode differentials + superset-ticket properties.

Greedy equivalence is the hard invariant: with every predicted expert
resident, the speculative engine must emit token-for-token the same output
as vanilla greedy decode — `verify_step` IS k sequential decode_steps under
one jit, so any divergence is a bug, not a tolerance question. Covered for
sync and async prefetch and for fp and int8-resident slots, at the engine
and at the continuous-batching request server (per-lane acceptance at mixed
positions). `verify_step`'s rollback is checked directly against running
only the accepted prefix, including recurrent (mamba) state rollback on a
hybrid arch, and a hypothesis property pins the superset-ticket claim: the
k-step ticket's expert set always contains each per-step ticket's set.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.decode_engine import SiDADecodeEngine
from repro.core.hash_fn import init_draft_head, init_hash_fn
from repro.core.hash_table import HashTable
from repro.models.attention import ShardingCtx
from repro.models.transformer import (
    decode_step,
    init_cache,
    init_params,
    n_moe_layers,
    verify_step,
)

CTX = ShardingCtx()


def _sys(E=8, seed=0):
    cfg = get_config("switch-base-8").reduced()
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, num_experts=E))
    params = init_params(jax.random.PRNGKey(seed), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg), E,
        d_h=16, draft=True,
    )
    return cfg, params, hp


# ---------------------------------------------------------------------------
# greedy-equivalence differentials
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefetch_depth", [0, 2])
@pytest.mark.parametrize("quantized", [False, True])
def test_spec_equals_vanilla_greedy(prefetch_depth, quantized):
    """Spec output == vanilla greedy output, byte for byte, with all
    predicted experts resident (slots == E), across sync/async prefetch and
    fp/int8-resident slots."""
    cfg, params, hp = _sys()
    E = cfg.moe.num_experts
    start = np.arange(3, dtype=np.int32) + 1
    steps = 10

    van = SiDADecodeEngine(
        cfg, params, hp, slots_per_layer=E, serve_top_k=1,
        prefetch_depth=prefetch_depth, quantized_slots=quantized,
    )
    out_ref, m_ref = van.generate(start, steps=steps, cache_len=32)
    van.close()

    spec = SiDADecodeEngine(
        cfg, params, hp, slots_per_layer=E, serve_top_k=1,
        prefetch_depth=prefetch_depth, quantized_slots=quantized,
        spec_mode="draft", spec_k=3,
    )
    out_spec, m_spec = spec.generate(start, steps=steps, cache_len=32)
    spec.close()

    np.testing.assert_array_equal(out_ref, out_spec)
    assert m_ref.tokens == start.shape[0] * steps
    assert m_spec.tokens == start.shape[0] * steps


def test_decode_metrics_count_accepted_tokens():
    """tokens counts *accepted* (emitted) tokens — never B·steps·k — and
    loads are attributed one entry per verify block."""
    cfg, params, hp = _sys()
    start = np.arange(2, dtype=np.int32) + 1
    steps, K = 9, 3

    van = SiDADecodeEngine(cfg, params, hp, slots_per_layer=4, serve_top_k=1)
    _, m = van.generate(start, steps=steps, cache_len=32)
    assert m.tokens == m.proposed == 2 * steps
    assert m.steps == steps
    assert m.acceptance_rate == 1.0
    assert len(m.loads_per_step) == m.steps
    assert m.accepted_per_step == [1.0] * steps

    spec = SiDADecodeEngine(
        cfg, params, hp, slots_per_layer=cfg.moe.num_experts, serve_top_k=1,
        spec_mode="draft", spec_k=K,
    )
    _, ms = spec.generate(start, steps=steps, cache_len=32)
    assert ms.tokens == 2 * steps          # exactly what was emitted
    assert ms.proposed == 2 * K * ms.steps  # every position verified counts
    assert ms.tokens <= ms.proposed
    assert len(ms.loads_per_step) == ms.steps == len(ms.accepted_per_step)
    assert 0.0 < ms.acceptance_rate <= 1.0


@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_server_spec_matches_vanilla_server(prefetch_depth):
    """Continuous-batching server: speculative mode emits identical token
    streams per request, with lanes at staggered positions accepting
    different amounts per tick. depth=2 exercises the pipelined pre-unroll
    (next block's superset ticket submitted at the end of each tick, redone
    urgently when lanes join in between)."""
    from repro.serving import Request, RequestServer

    cfg, params, hp = _sys()
    E = cfg.moe.num_experts

    def mkreqs():
        rng = np.random.default_rng(5)
        plens, gens = [5, 9, 13], [7, 5, 4]
        return [
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, (p,)).astype(np.int32),
                max_new_tokens=g,
            )
            for i, (p, g) in enumerate(zip(plens, gens))
        ]

    outs = {}
    for name, kw in (("off", {}), ("spec", dict(spec_mode="draft", spec_k=4))):
        srv = RequestServer(
            cfg, params, hp, slots_per_layer=E, max_lanes=3,
            max_prefill_batch=2, buckets=(8, 16), cache_len=32,
            prefetch_depth=prefetch_depth, **kw,
        )
        srv.run(mkreqs(), realtime=False)
        srv.close()
        assert len(srv.completed) == 3
        outs[name] = {r.rid: list(r.generated) for r in srv.completed}
        if name == "spec":
            s = srv.summary()
            assert s["spec_k"] == 4
            assert 0.0 < s["spec_acceptance_rate"] <= 1.0
            assert s["spec_accepted_per_step"] >= 1.0
    assert outs["off"] == outs["spec"]


# ---------------------------------------------------------------------------
# verify_step rollback
# ---------------------------------------------------------------------------


def _routing_for(cfg, store, hp, params, tokens_blk):
    """Per-position routing overrides for a draft block via the store's
    device translate (all experts resident, so weights == α exactly)."""
    from repro.core.decode_engine import hash_fn_step, hash_state_init

    B, kb = tokens_blk.shape
    E = cfg.moe.num_experts
    state = hash_state_init(hp, B)
    ids_l, a_l = [], []
    for i in range(kb):
        emb = jnp.take(params["embed"], jnp.asarray(tokens_blk[:, i]), axis=0)
        logits, state = hash_fn_step(hp, emb, state, E)
        vals, ids = jax.lax.top_k(logits, 1)
        ids_l.append(jnp.moveaxis(ids, 1, 0))
        a_l.append(jnp.moveaxis(jax.nn.softmax(vals, -1), 1, 0))
    ids = jnp.stack(ids_l, axis=2)                      # [L, B, kb, 1]
    alpha = jnp.stack(a_l, axis=2)
    table = HashTable(0, np.asarray(ids), np.asarray(alpha))
    trans = store.prepare(table)
    slot_ids, w = store.translate_device(ids, alpha, trans)
    return jnp.moveaxis(slot_ids, 2, 0), jnp.moveaxis(w, 2, 0)


def test_verify_step_rollback_matches_accepted_prefix():
    """new_cache after verify == cache after running ONLY the accepted
    prefix through vanilla decode_step (ring K/V slots of rejected positions
    restored exactly, pos advanced by n_acc)."""
    from repro.core.offload import ExpertStore

    cfg, params, hp = _sys()
    E = cfg.moe.num_experts
    store = ExpertStore(cfg, params, slots_per_layer=E)
    B, kb = 2, 4
    rng = np.random.default_rng(3)
    # draft tokens are arbitrary (not the model's argmax) => forced rejects
    blk = rng.integers(0, cfg.vocab_size, (B, kb)).astype(np.int32)
    ro = _routing_for(cfg, store, hp, params, blk)

    cache0 = init_cache(cfg, B, 16)
    out, n_acc, logits, new_cache = verify_step(
        store.serve_params, cache0, jnp.asarray(blk), cfg, CTX,
        routing_override=ro,
    )
    out, n_acc = np.asarray(out), np.asarray(n_acc)
    assert logits.shape[0] == kb
    # recompute expected acceptance on host
    for b in range(B):
        exp = 1
        while exp < kb and out[b, exp - 1] == blk[b, exp]:
            exp += 1
        assert n_acc[b] == exp

    # reference: per-lane replay of only the accepted prefix
    ref_cache = init_cache(cfg, B, 16)
    for i in range(int(n_acc.max())):
        _, stepped = decode_step(
            store.serve_params, ref_cache, jnp.asarray(blk[:, i]), cfg, CTX,
            routing_override=(ro[0][i], ro[1][i]),
        )
        act = jnp.asarray(i < n_acc)

        def merge(nw, od):
            if nw.ndim >= 2 and nw.shape[1] == B:   # [G, B, ...] entries
                m = act.reshape((1, B) + (1,) * (nw.ndim - 2))
            else:                                    # pos is [B]
                m = act
            return jnp.where(m, nw, od)

        ref_cache = jax.tree.map(merge, stepped, ref_cache)
    for a, b in zip(jax.tree.leaves(ref_cache), jax.tree.leaves(new_cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_verify_step_recurrent_state_rollback():
    """Hybrid arch (mamba branch): rejected positions' recurrent state
    updates roll back to the snapshot after the accepted prefix. No MoE =>
    no routing override; drafts are deliberately wrong."""
    cfg = get_config("hymba-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, kb = 2, 3
    rng = np.random.default_rng(7)
    blk = rng.integers(0, cfg.vocab_size, (B, kb)).astype(np.int32)

    cache0 = init_cache(cfg, B, 16)
    out, n_acc, _, new_cache = verify_step(
        params, cache0, jnp.asarray(blk), cfg, CTX,
    )
    out, n_acc = np.asarray(out), np.asarray(n_acc)

    ref_cache = init_cache(cfg, B, 16)
    for i in range(int(n_acc.max())):
        _, stepped = decode_step(
            params, ref_cache, jnp.asarray(blk[:, i]), cfg, CTX,
        )
        act = jnp.asarray(i < n_acc)

        def merge(nw, od):
            if nw.ndim >= 2 and nw.shape[1] == B:
                m = act.reshape((1, B) + (1,) * (nw.ndim - 2))
            else:  # pos is [B]
                m = act
            return jnp.where(m, nw, od)

        ref_cache = jax.tree.map(merge, stepped, ref_cache)
    for a, b in zip(jax.tree.leaves(ref_cache), jax.tree.leaves(new_cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_verify_step_inactive_lane_fully_rolled_back():
    """active=False => n_acc == 0, pos unchanged, cache bit-identical."""
    cfg, params, hp = _sys()
    from repro.core.offload import ExpertStore

    store = ExpertStore(cfg, params, slots_per_layer=cfg.moe.num_experts)
    B, kb = 2, 3
    rng = np.random.default_rng(11)
    blk = rng.integers(0, cfg.vocab_size, (B, kb)).astype(np.int32)
    ro = _routing_for(cfg, store, hp, params, blk)
    cache0 = init_cache(cfg, B, 16)
    active = jnp.asarray(np.array([True, False]))
    _, n_acc, _, new_cache = verify_step(
        store.serve_params, cache0, jnp.asarray(blk), cfg, CTX,
        routing_override=ro, active=active,
    )
    n_acc = np.asarray(n_acc)
    assert n_acc[1] == 0 and n_acc[0] >= 1
    assert np.asarray(new_cache["pos"])[1] == 0
    for a, b in zip(jax.tree.leaves(cache0), jax.tree.leaves(new_cache)):
        a, b = np.asarray(a), np.asarray(b)
        if a.ndim >= 2 and a.shape[1] == B:
            np.testing.assert_array_equal(a[:, 1], b[:, 1])


# ---------------------------------------------------------------------------
# superset-ticket property
# ---------------------------------------------------------------------------


def test_superset_ticket_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(
        data=st.data(),
        L=st.integers(1, 3),
        B=st.integers(1, 3),
        K=st.integers(1, 5),
        topk=st.integers(1, 3),
    )
    def prop(data, L, B, K, topk):
        ids = data.draw(
            st.lists(
                st.integers(0, 7), min_size=L * B * K * topk,
                max_size=L * B * K * topk,
            )
        )
        ids = np.asarray(ids, np.int32).reshape(L, B, K, topk)
        w = np.abs(np.asarray(
            data.draw(st.lists(
                st.floats(0, 1, allow_nan=False),
                min_size=L * B * K * topk, max_size=L * B * K * topk,
            )), np.float32,
        )).reshape(L, B, K, topk)
        union = HashTable(0, ids, w)
        for i in range(K):
            step = HashTable(0, ids[:, :, i : i + 1], w[:, :, i : i + 1])
            for l in range(L):
                assert set(step.active_experts(l)) <= set(
                    union.active_experts(l)
                ), (i, l)

    prop()
