"""Data pipeline, optimizer, checkpointing, HLO analysis."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.hlo_analysis import analyse_hlo
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import linear_warmup_cosine


def test_synthetic_deterministic():
    cfg = SyntheticConfig(vocab_size=256, seq_len=32, n_domains=4)
    a = SyntheticLM(cfg, seed=7).sample(4)
    b = SyntheticLM(cfg, seed=7).sample(4)
    np.testing.assert_array_equal(a[0], b[0])
    c = SyntheticLM(cfg, seed=8).sample(4)
    assert not np.array_equal(a[0], c[0])


def test_synthetic_labels_are_shifted_tokens():
    cfg = SyntheticConfig(vocab_size=256, seq_len=16)
    toks, labels, _ = SyntheticLM(cfg, seed=0).sample(2)
    np.testing.assert_array_equal(labels[:, :-1], toks[:, 1:])
    assert (labels[:, -1] == -100).all()


def test_synthetic_domain_structure():
    """Domains must have distinguishable token distributions (what makes
    expert routing predictable from inputs)."""
    cfg = SyntheticConfig(vocab_size=1024, seq_len=64, n_domains=4, shared_frac=0.1)
    data = SyntheticLM(cfg, seed=0)
    toks, _, domains = data.sample(64)
    # tokens from different domains overlap rarely
    sets = [set(toks[domains == d].ravel()) - {0} for d in range(4)]
    inter = len(sets[0] & sets[1]) / max(1, len(sets[0]))
    assert inter < 0.5


def test_length_profiles():
    for prof, (lo, hi, _) in [("sst2", (4, 60, 0)), ("multirc", (150, 480, 0))]:
        cfg = SyntheticConfig(vocab_size=128, seq_len=512, profile=prof)
        toks, labels, _ = SyntheticLM(cfg, seed=0).sample(16)
        lens = (toks != 0).sum(1)
        assert lens.min() >= lo - 1 and lens.max() <= hi + 1


def test_adamw_optimizes():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw_update(grads, params, state, lr=0.1)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_grad_clip():
    params = {"w": jnp.array([1.0])}
    state = adamw_init(params)
    grads = {"w": jnp.array([1e9])}
    p2, _ = adamw_update(grads, params, state, lr=0.1, grad_clip=1.0)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_schedule():
    f = linear_warmup_cosine(1.0, warmup=10, total=110)
    assert f(0) < f(9) <= 1.0
    assert f(10) == pytest.approx(1.0)
    assert f(110) == pytest.approx(0.1, abs=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    params = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "c": [jnp.zeros((2, 2))]},
    }
    save_checkpoint(str(tmp_path / "ck"), params, step=7, extra={"note": "x"})
    restored, manifest = load_checkpoint(str(tmp_path / "ck"), like=params)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_hlo_analysis_trip_counts():
    def f_scan(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=10)[0]

    def f_unroll(x, w):
        for _ in range(10):
            x = x @ w
        return x

    args = (jax.ShapeDtypeStruct((64, 64), jnp.float32),) * 2
    a = analyse_hlo(jax.jit(f_scan).lower(*args).compile().as_text())
    b = analyse_hlo(jax.jit(f_unroll).lower(*args).compile().as_text())
    expected = 2 * 64**3 * 10
    assert a["flops"] == pytest.approx(expected, rel=0.01)
    assert b["flops"] == pytest.approx(expected, rel=0.01)
