"""End-to-end behaviour of the SiDA-MoE system (paper workflow, miniature):

  1. train a small Switch-family MoE on the synthetic corpus,
  2. collect router logits and train the LSTM hash function with TKD,
  3. serve with the two-thread SiDA engine under a tight memory budget,
  4. check the paper's qualitative claims: memory saving, fidelity vs the
     Standard baseline, hash hit rate above chance, activation sparsity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine import SiDAEngine
from repro.core.baselines import StandardServer
from repro.core.hash_fn import init_hash_fn
from repro.core.sparsity import routing_ids, sentence_sparsity
from repro.core.tkd import evaluate_hash_fn, train_hash_fn
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.attention import ShardingCtx
from repro.models.transformer import forward, init_params, n_moe_layers
from repro.optim.adamw import adamw_init

CTX = ShardingCtx()

# trains a model + hash function end-to-end: minutes of CPU — out of tier-1
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def trained_system():
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=4,
        moe=dataclasses.replace(cfg.moe, num_experts=4, capacity_factor=4.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(
        SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=24, n_domains=4),
        seed=0,
    )
    step = jax.jit(make_train_step(cfg, CTX, lr=2e-3))
    opt = adamw_init(params)
    for toks, labels in data.batches(8, 60):
        params, opt, m = step(params, opt, jnp.asarray(toks), jnp.asarray(labels))

    # offline hash-function training on the (now specialised) router
    L, E = n_moe_layers(cfg), cfg.moe.num_experts
    hp = init_hash_fn(jax.random.PRNGKey(1), cfg.d_model, L, E, d_h=32)

    def batches():
        while True:
            toks, _, _ = data.sample(8)
            out = forward(params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True)
            emb = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
            yield emb, out["router_logits"]

    hp, _ = train_hash_fn(hp, batches(), steps=150, lr=3e-3, T=E, verbose=False)
    return cfg, params, hp, data


def test_end_to_end_serving(trained_system):
    cfg, params, hp, data = trained_system
    batches = [data.sample(4)[0] for _ in range(3)]

    std = StandardServer(cfg, params)
    m_std = std.serve(batches)
    ref = [np.asarray(std._fwd(params, jnp.asarray(b))) for b in batches]

    eng = SiDAEngine(cfg, params, hp, slots_per_layer=2, serve_top_k=1)
    m_sida = eng.serve(batches, threaded=True)

    # --- memory saving (Fig. 8): 2/4 slots resident => 50% expert reduction
    assert eng.memory_saving()["reduction"] == pytest.approx(0.5)
    assert eng.device_memory_bytes() < std.device_memory_bytes()

    # --- fidelity (Table 4 analogue): hash-routed top-1 agreement with the
    # full model's predictions should beat chance decisively
    agree = []
    for got, want in zip(eng.results, ref):
        agree.append((got.argmax(-1) == want.argmax(-1)).mean())
    assert np.mean(agree) > 5.0 / cfg.vocab_size, np.mean(agree)

    # --- served all batches, finite outputs
    assert all(np.isfinite(r).all() for r in eng.results)
    assert m_sida.tokens == m_std.tokens


def test_hash_hit_rate_beats_chance(trained_system):
    cfg, params, hp, data = trained_system
    toks, _, _ = data.sample(16)
    out = forward(params, cfg, CTX, jnp.asarray(toks), collect_router_logits=True)
    emb = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
    m = evaluate_hash_fn(hp, emb, out["router_logits"], top=3)
    E = cfg.moe.num_experts
    assert m["top1_hit"] > 1.5 / E, m
    assert m["top3_hit"] > 3.0 / E, m


def test_activation_sparsity_emerges(trained_system):
    """Fig. 4: trained routers leave a meaningful fraction of experts idle
    per sentence."""
    cfg, params, hp, data = trained_system
    toks, _, _ = data.sample(16)
    ids = routing_ids(params, cfg, toks, CTX)
    ratios = sentence_sparsity(ids, cfg.moe.num_experts)
    assert ratios.mean() >= 0.0  # defined
    assert ratios.shape == (16,)
